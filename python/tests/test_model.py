"""L2 correctness: the DEQ model's entry points.

Checks that every artifact-bound function computes what the Rust
coordinator assumes it computes: VJPs match jax.vjp on the monolithic
model, the fixed-point map is well-behaved, the pretrain gradient matches
autodiff of the unrolled loss, shapes agree with the manifest generator.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model

CFG = model.VARIANTS["tiny"]


def make_all(seed=0):
    key = jax.random.PRNGKey(seed)
    params = model.init_params(CFG, key)
    p, _ = model.cfg_dims(CFG)
    b, c = CFG["batch"], CFG["c"]
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed + 1), 3)
    x = jax.random.normal(k1, (b, CFG["h"] * CFG["w"] * CFG["c_in"]), jnp.float32)
    z = jax.random.normal(k2, (b, p, c), jnp.float32)
    v = jax.random.normal(k3, (b, p, c), jnp.float32)
    return params, x, z, v


def fparams(params):
    return tuple(params[n] for n in model.F_PARAM_NAMES)


def test_entry_points_shapes_match_specs():
    eps = model.make_entry_points(CFG)
    for name, (fn, specs) in eps.items():
        lowered = jax.jit(fn).lower(*specs)
        for out in lowered.out_info:
            assert all(dim > 0 for dim in out.shape), f"{name}: bad out shape"


def test_f_fwd_kernel_equals_ref_path():
    params, x, z, _ = make_all()
    u = model.inject(params["wemb"], params["bemb"], x, CFG)
    a = model.f_theta(fparams(params), z, u, use_kernel=True)
    b = model.f_theta(fparams(params), z, u, use_kernel=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_f_vjp_z_matches_jax_vjp():
    params, x, z, v = make_all(1)
    u = model.inject(params["wemb"], params["bemb"], x, CFG)
    eps = model.make_entry_points(CFG)
    fn, _ = eps["f_vjp_z"]
    got = fn(*fparams(params), z, u, v)[0]
    _, pullback = jax.vjp(lambda zz: model.f_theta(fparams(params), zz, u, use_kernel=False), z)
    want = pullback(v)[0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_f_vjp_params_u_matches_jax_vjp():
    params, x, z, v = make_all(2)
    u = model.inject(params["wemb"], params["bemb"], x, CFG)
    eps = model.make_entry_points(CFG)
    fn, _ = eps["f_vjp_params_u"]
    outs = fn(*fparams(params), z, u, v)
    _, pullback = jax.vjp(
        lambda fps, uu: model.f_theta(fps, z, uu, use_kernel=False), fparams(params), u
    )
    dfp, du = pullback(v)
    for got, want in zip(outs[:6], dfp):
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(outs[6], du, rtol=1e-5, atol=1e-5)


def test_f_jvp_consistent_with_vjp():
    # <v, J w> == <J^T v, w> for random v, w.
    params, x, z, v = make_all(3)
    u = model.inject(params["wemb"], params["bemb"], x, CFG)
    w = jax.random.normal(jax.random.PRNGKey(9), z.shape, jnp.float32)
    eps = model.make_entry_points(CFG)
    jvp = eps["f_jvp"][0](*fparams(params), z, u, w)[0]
    vjp = eps["f_vjp_z"][0](*fparams(params), z, u, v)[0]
    lhs = jnp.vdot(v, jvp)
    rhs = jnp.vdot(vjp, w)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-4)


def test_inject_vjp_matches_autodiff():
    params, x, z, _ = make_all(4)
    du = jax.random.normal(jax.random.PRNGKey(11), z.shape, jnp.float32)
    eps = model.make_entry_points(CFG)
    dwe, dbe = eps["inject_vjp"][0](params["wemb"], params["bemb"], x, du)
    _, pullback = jax.vjp(
        lambda we, be: model.inject(we, be, x, CFG), params["wemb"], params["bemb"]
    )
    want_we, want_be = pullback(du)
    np.testing.assert_allclose(dwe, want_we, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dbe, want_be, rtol=1e-5, atol=1e-5)


def test_head_loss_grad_matches_autodiff():
    params, x, z, _ = make_all(5)
    b, k = CFG["batch"], CFG["n_classes"]
    labels = jax.nn.one_hot(jnp.arange(b) % k, k, dtype=jnp.float32)
    eps = model.make_entry_points(CFG)
    loss, dz, dwh, dbh = eps["head_loss_grad"][0](params["whead"], params["bhead"], z, labels)
    want_loss, grads = jax.value_and_grad(model.head_loss, argnums=(0, 1, 2))(
        params["whead"], params["bhead"], z, labels
    )
    np.testing.assert_allclose(loss[0], want_loss, rtol=1e-5)
    np.testing.assert_allclose(dwh, grads[0], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dbh, grads[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dz, grads[2], rtol=1e-5, atol=1e-6)


def test_head_loss_is_mean_ce():
    # Uniform logits -> loss == log(K).
    params, _, z, _ = make_all(6)
    k = CFG["n_classes"]
    zero_head = jnp.zeros_like(params["whead"])
    zero_b = jnp.zeros_like(params["bhead"])
    labels = jax.nn.one_hot(jnp.zeros(CFG["batch"], jnp.int32), k, dtype=jnp.float32)
    loss = model.head_loss(zero_head, zero_b, z, labels)
    np.testing.assert_allclose(loss, np.log(k), rtol=1e-5)


def test_pretrain_grads_match_autodiff():
    params, x, _, _ = make_all(7)
    b, k = CFG["batch"], CFG["n_classes"]
    labels = jax.nn.one_hot(jnp.arange(b) % k, k, dtype=jnp.float32)
    eps = model.make_entry_points(CFG)
    outs = eps["pretrain_grads"][0](*(params[n] for n in model.PARAM_NAMES), x, labels)
    loss = outs[0][0]
    want_loss, want_grads = jax.value_and_grad(
        lambda pp: model.unrolled_loss(pp, x, labels, CFG, use_kernel=False)
    )(params)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-5)
    for name, got in zip(model.PARAM_NAMES, outs[1:]):
        np.testing.assert_allclose(
            got, want_grads[name], rtol=1e-4, atol=1e-5, err_msg=name
        )


def test_patchify_is_a_permutation():
    # Patchify must preserve every pixel exactly once.
    params, x, _, _ = make_all(8)
    patches = model.patchify(x, CFG)
    assert patches.shape == (
        CFG["batch"],
        (CFG["h"] // CFG["patch"]) * (CFG["w"] // CFG["patch"]),
        CFG["patch"] * CFG["patch"] * CFG["c_in"],
    )
    np.testing.assert_allclose(
        np.sort(np.asarray(patches).ravel()), np.sort(np.asarray(x).ravel()), rtol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fixed_point_iteration_is_stable(seed):
    # Damped Picard on f_theta must not blow up (LayerNorm bounds the output);
    # the residual after a few steps must be finite and bounded.
    params, x, z, _ = make_all(seed % 1000)
    u = model.inject(params["wemb"], params["bemb"], x, CFG)
    fp = fparams(params)
    zz = jnp.zeros_like(z)
    for _ in range(12):
        zz = 0.5 * zz + 0.5 * model.f_theta(fp, zz, u, use_kernel=False)
    res = jnp.linalg.norm(model.f_theta(fp, zz, u, use_kernel=False) - zz)
    assert bool(jnp.isfinite(res))
    assert float(res) < 1e3
