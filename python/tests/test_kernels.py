"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, block sizes and seeds; every case asserts
allclose against ref.py — the CORE correctness signal for the kernels
that end up inside the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.deq_block import deq_block, mxu_utilization_estimate, vmem_bytes
from compile.kernels.lowrank_apply import lowrank_apply
from compile.kernels.ref import deq_block_ref, layer_norm_ref, lowrank_apply_ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), dtype=jnp.float32)


# ---------------------------------------------------------------------------
# deq_block
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    p=st.integers(1, 33),
    c=st.sampled_from([4, 8, 16, 32]),
    block_rows=st.sampled_from([8, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_deq_block_matches_ref(b, p, c, block_rows, seed):
    rng = np.random.default_rng(seed)
    z = _rand(rng, b, p, c)
    u = _rand(rng, b, p, c)
    w1 = _rand(rng, c, c)
    b1 = _rand(rng, c)
    w2 = _rand(rng, c, c)
    b2 = _rand(rng, c)
    out = deq_block(z, u, w1, b1, w2, b2, block_rows=block_rows)
    ref = deq_block_ref(z, u, w1, b1, w2, b2)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_deq_block_non_divisible_rows_are_padded_correctly():
    # rows = b*p = 2*37 = 74, block 16 -> padding path must be exact.
    rng = np.random.default_rng(7)
    z = _rand(rng, 2, 37, 8)
    u = _rand(rng, 2, 37, 8)
    w1 = _rand(rng, 8, 8)
    b1 = _rand(rng, 8)
    w2 = _rand(rng, 8, 8)
    b2 = _rand(rng, 8)
    out = deq_block(z, u, w1, b1, w2, b2, block_rows=16)
    ref = deq_block_ref(z, u, w1, b1, w2, b2)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


def test_deq_block_relu_actually_gates():
    # With a large negative bias the branch must be exactly b2 (ReLU kills h).
    c = 8
    z = jnp.ones((1, 4, c), jnp.float32)
    u = jnp.zeros((1, 4, c), jnp.float32)
    w1 = jnp.eye(c, dtype=jnp.float32)
    b1 = -100.0 * jnp.ones((c,), jnp.float32)
    w2 = jnp.eye(c, dtype=jnp.float32)
    b2 = 3.0 * jnp.ones((c,), jnp.float32)
    out = deq_block(z, u, w1, b1, w2, b2, block_rows=8)
    np.testing.assert_allclose(out, 3.0 * jnp.ones_like(z), rtol=1e-6)


def test_vmem_estimate_under_budget():
    # The production tile config must sit far below the 16 MB VMEM budget.
    assert vmem_bytes(128, 64) < 16 * 2**20 / 8


def test_mxu_estimate_monotone_in_c():
    # Fuller channel tiles -> better MXU utilization.
    assert mxu_utilization_estimate(128, 64) > mxu_utilization_estimate(128, 16)


# ---------------------------------------------------------------------------
# lowrank_apply
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(3, 500),
    m=st.integers(1, 31),
    block_d=st.sampled_from([16, 64, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lowrank_apply_matches_ref(d, m, block_d, seed):
    rng = np.random.default_rng(seed)
    v = _rand(rng, d)
    us = _rand(rng, m, d)
    vs = _rand(rng, m, d)
    out = lowrank_apply(v, us, vs, block_d=block_d)
    ref = lowrank_apply_ref(v, us, vs)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_lowrank_identity_when_factors_zero():
    d, m = 64, 5
    v = jnp.arange(d, dtype=jnp.float32)
    z = jnp.zeros((m, d), jnp.float32)
    np.testing.assert_allclose(lowrank_apply(v, z, z), v)


def test_lowrank_rank_one_analytic():
    # H = I + u v^T: H x = x + u (v.x).
    d = 10
    u = jnp.arange(1.0, d + 1, dtype=jnp.float32).reshape(1, d)
    vv = jnp.ones((1, d), jnp.float32)
    x = jnp.ones((d,), jnp.float32)
    out = lowrank_apply(x, u, vv, block_d=4)
    expected = x + u[0] * float(d)
    np.testing.assert_allclose(out, expected, rtol=1e-5)


# ---------------------------------------------------------------------------
# layer_norm ref sanity (it is part of f_theta's artifact path)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_layer_norm_normalizes(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 2, 5, 16)
    gamma = jnp.ones((16,), jnp.float32)
    beta = jnp.zeros((16,), jnp.float32)
    y = layer_norm_ref(x, gamma, beta)
    np.testing.assert_allclose(np.asarray(y).mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y).std(axis=-1), 1.0, atol=1e-3)
