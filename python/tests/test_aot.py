"""AOT pipeline checks: lowering produces parseable HLO text whose entry
signature matches the manifest, for every entry point of the tiny variant.
(The cifar/imagenet variants use the same code paths with different static
shapes; the rust integration tests exercise those artifacts end-to-end.)
"""

import json
import os
import subprocess
import sys
import tempfile

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_dir():
    with tempfile.TemporaryDirectory() as td:
        argv = sys.argv
        sys.argv = ["aot", "--out-dir", td, "--variants", "tiny"]
        try:
            aot.main()
        finally:
            sys.argv = argv
        yield td


def test_manifest_structure(lowered_dir):
    man = json.load(open(os.path.join(lowered_dir, "manifest.json")))
    assert man["version"] == 1
    assert "tiny" in man["variants"]
    v = man["variants"]["tiny"]
    assert v["fixed_point_dim"] == v["batch"] * v["pixels"] * v["c"]
    assert v["param_names"] == model.PARAM_NAMES
    for name in model.PARAM_NAMES:
        assert name in v["param_shapes"]
    # one artifact per entry point + the lowrank kernel
    entries = model.make_entry_points(model.VARIANTS["tiny"])
    for ename in entries:
        assert f"tiny_{ename}" in man["artifacts"]
    assert "tiny_lowrank_apply" in man["artifacts"]


def test_hlo_files_exist_and_are_text(lowered_dir):
    man = json.load(open(os.path.join(lowered_dir, "manifest.json")))
    for rec in man["artifacts"].values():
        path = os.path.join(lowered_dir, rec["file"])
        assert os.path.exists(path), rec["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{rec['file']} does not look like HLO text"


def test_manifest_shapes_match_entry_specs(lowered_dir):
    man = json.load(open(os.path.join(lowered_dir, "manifest.json")))
    entries = model.make_entry_points(model.VARIANTS["tiny"])
    for ename, (fn, specs) in entries.items():
        rec = man["artifacts"][f"tiny_{ename}"]
        assert rec["inputs"] == [list(s.shape) for s in specs], ename
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        assert rec["outputs"] == [list(o.shape) for o in lowered.out_info], ename


def test_hlo_parameter_count_matches_manifest(lowered_dir):
    # The HLO entry computation must declare exactly len(inputs) parameters.
    import re

    man = json.load(open(os.path.join(lowered_dir, "manifest.json")))
    for key, rec in man["artifacts"].items():
        text = open(os.path.join(lowered_dir, rec["file"])).read()
        # Parameters after the ENTRY header: `%x = f32[...] parameter(N)`.
        entry_pos = text.find("ENTRY")
        assert entry_pos >= 0, key
        ids = set(re.findall(r"parameter\((\d+)\)", text[entry_pos:]))
        assert len(ids) == len(rec["inputs"]), f"{key}: {sorted(ids)} vs {rec['inputs']}"


def test_deterministic_lowering(lowered_dir):
    # Lowering twice produces identical HLO (the sha in the manifest is
    # meaningful for caching).
    entries = model.make_entry_points(model.VARIANTS["tiny"])
    fn, specs = entries["f_fwd"]
    t1 = aot.to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))
    assert t1 == t2
