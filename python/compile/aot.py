"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

Run once at build time (`make artifacts`); the Rust coordinator then loads
`artifacts/<variant>_<entry>.hlo.txt` through the PJRT C API and Python
never appears on the experiment hot path.

HLO TEXT, not serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's XLA (xla_extension 0.5.1)
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

The manifest (artifacts/manifest.json) records for every artifact its
input/output shapes plus the per-variant model config and parameter
layout — the ABI rust/src/deq/model.rs programs against.

Usage:
    python -m compile.aot --out-dir ../artifacts [--variants tiny,cifar,...]
"""

import argparse
import hashlib
import json
import os
import sys

import jax

from compile import model


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, specs):
    return jax.jit(fn, keep_unused=True).lower(*specs)


def shape_list(specs):
    return [list(s.shape) for s in specs]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variants",
        default="tiny,cifar,imagenet",
        help="comma-separated subset of VARIANTS to lower",
    )
    ap.add_argument("--out", default=None, help="(compat) ignored")
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "variants": {}, "artifacts": {}}

    for vname in args.variants.split(","):
        vname = vname.strip()
        if not vname:
            continue
        cfg = model.VARIANTS[vname]
        p, cp = model.cfg_dims(cfg)
        vrec = dict(cfg)
        vrec.update(
            pixels=p,
            patch_channels=cp,
            fixed_point_dim=cfg["batch"] * p * cfg["c"],
            param_names=model.PARAM_NAMES,
            f_param_names=model.F_PARAM_NAMES,
            param_shapes={k: list(v) for k, v in model.param_shapes(cfg).items()},
        )
        manifest["variants"][vname] = vrec

        entries = model.make_entry_points(cfg)
        for ename, (fn, specs) in entries.items():
            lowered = lower_entry(fn, specs)
            text = to_hlo_text(lowered)
            fname = f"{vname}_{ename}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            out_shapes = [list(s.shape) for s in lowered.out_info]
            manifest["artifacts"][f"{vname}_{ename}"] = {
                "file": fname,
                "inputs": shape_list(specs),
                "outputs": out_shapes,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
            print(f"lowered {vname}/{ename}: {len(text)} chars", file=sys.stderr)

        # The standalone L1 lowrank artifact, sized to this variant's
        # flattened fixed point with the paper's memory (m = 30).
        d = vrec["fixed_point_dim"]
        fn, specs = model.make_lowrank_entry(d, m=30)
        lowered = lower_entry(fn, specs)
        text = to_hlo_text(lowered)
        fname = f"{vname}_lowrank_apply.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][f"{vname}_lowrank_apply"] = {
            "file": fname,
            "inputs": shape_list(specs),
            "outputs": [[d]],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"lowered {vname}/lowrank_apply: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {out_dir}/manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()
