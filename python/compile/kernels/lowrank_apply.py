"""L1 Pallas kernel: Sherman-Morrison low-rank inverse application.

This is the SHINE backward operation itself (eq. 4): applying the forward
pass's quasi-Newton inverse estimate

    H v = (I + sum_i u_i v_i^T) v = v + U^T (V v)

where U, V are the (m, d) stacks of rank-one factors (m <= 30 in the paper's
setting). Two skinny matvecs, fused so the (m,) intermediate stays in VMEM.

Tiling: d is split into `block_d` columns per program. Each program computes
a partial (m,) contraction V[:, tile] @ v[tile]; a second pass adds
U[:, tile]^T @ s to the output tile. Because the (m,) intermediate is tiny,
we phrase the whole thing as a two-stage grid with an SMEM-sized carry —
in interpret mode this is executed as-is; on a real TPU the same structure
maps to one VMEM-resident reduction plus a broadcast pass.

The Rust coordinator uses its native implementation for small problems (the
PJRT call overhead dominates below d ~ 10^4) and can route large DEQ
backwards through this artifact; the `micro_qn` bench compares both.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage1(v_ref, vs_ref, s_ref):
    # Partial contraction over this d-tile: s += V_tile @ v_tile.
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    s_ref[...] += vs_ref[...] @ v_ref[...]


def _stage2(v_ref, us_ref, s_ref, o_ref):
    # o_tile = v_tile + U_tile^T @ s.
    o_ref[...] = v_ref[...] + us_ref[...].T @ s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d",))
def lowrank_apply(v, us, vs, block_d=4096):
    """Compute v + U^T (V v) with U=us, V=vs of shape (m, d), v of shape (d,)."""
    (d,) = v.shape
    m, d2 = us.shape
    assert d2 == d and vs.shape == (m, d)
    block_d = min(block_d, d)
    padded = ((d + block_d - 1) // block_d) * block_d
    if padded != d:
        v = jnp.pad(v, (0, padded - d))
        us = jnp.pad(us, ((0, 0), (0, padded - d)))
        vs = jnp.pad(vs, ((0, 0), (0, padded - d)))
    grid = (padded // block_d,)
    # Stage 1: reduce s = V v across d-tiles (sequential grid, carry in out).
    s = pl.pallas_call(
        _stage1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), v.dtype),
        interpret=True,
    )(v, vs)
    # Stage 2: out = v + U^T s, tile-parallel over d.
    out = pl.pallas_call(
        _stage2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((m, block_d), lambda i: (0, i)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), v.dtype),
        interpret=True,
    )(v, us, s)
    return out[:d]


def vmem_bytes(block_d, m, dtype_bytes=4):
    """Per-program VMEM estimate: v tile + two (m, block_d) factor tiles."""
    return (block_d + 2 * m * block_d + m) * dtype_bytes
