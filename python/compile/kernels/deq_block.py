"""L1 Pallas kernel: fused DEQ residual-block core.

The DEQ layer's hot-spot is the channel-mixing residual branch

    out = relu(z @ W1 + u + b1) @ W2 + b2

over a (B, P, C) activation tensor (P = H*W pixels). On GPU the original
MDEQ does this with cuDNN convs; the TPU adaptation (DESIGN.md
§Hardware-Adaptation) phrases it as dense matmuls so the MXU systolic array
is the compute engine, and fuses the two matmuls, the bias/injection adds
and the ReLU into one kernel so the intermediate (B, P, C) activation stays
in VMEM and never round-trips to HBM.

Grid/tiling: the (B*P, C) row-space is tiled by `block_rows` rows per
program; both weight matrices are small (C <= 64 here) and are kept fully
resident per program. VMEM per program =
    block_rows * C * 3 (z, u, h tiles) + 2 * C * C + 2 * C  floats,
which for block_rows=128, C=64 is ~0.2 MB — far under the ~16 MB VMEM
budget, leaving room for the pipeline's double buffering.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO (see /opt/xla-example/README.md). The BlockSpec structure is
still the TPU schedule; EXPERIMENTS.md §Perf estimates MXU utilisation
from it.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, u_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    # One program handles a (block_rows, C) tile of the flattened row space.
    z = z_ref[...]
    u = u_ref[...]
    h = jnp.maximum(z @ w1_ref[...] + u + b1_ref[...], 0.0)
    o_ref[...] = h @ w2_ref[...] + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def deq_block(z, u, w1, b1, w2, b2, block_rows=128):
    """Fused residual-branch core via Pallas.

    z, u: (B, P, C); w1, w2: (C, C); b1, b2: (C,).
    Returns relu(z @ w1 + u + b1) @ w2 + b2 with shape (B, P, C).
    """
    b, p, c = z.shape
    rows = b * p
    z2 = z.reshape(rows, c)
    u2 = u.reshape(rows, c)
    block_rows = min(block_rows, rows)
    # Pad the row space up to a multiple of block_rows.
    padded = ((rows + block_rows - 1) // block_rows) * block_rows
    if padded != rows:
        pad = padded - rows
        z2 = jnp.pad(z2, ((0, pad), (0, 0)))
        u2 = jnp.pad(u2, ((0, pad), (0, 0)))
    grid = (padded // block_rows,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),  # z tile
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),  # u tile
            pl.BlockSpec((c, c), lambda i: (0, 0)),  # w1 resident
            pl.BlockSpec((c,), lambda i: (0,)),  # b1 resident
            pl.BlockSpec((c, c), lambda i: (0, 0)),  # w2 resident
            pl.BlockSpec((c,), lambda i: (0,)),  # b2 resident
        ],
        out_specs=pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, c), z.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(z2, u2, w1, b1, w2, b2)
    return out[:rows].reshape(b, p, c)


def vmem_bytes(block_rows, c, dtype_bytes=4):
    """VMEM footprint estimate per program (see module docstring)."""
    tiles = 3 * block_rows * c  # z, u, out tiles (h reuses registers)
    weights = 2 * c * c + 2 * c
    return (tiles + weights) * dtype_bytes


def mxu_utilization_estimate(block_rows, c):
    """Fraction of MXU 128x128 tiles doing useful work for the two matmuls.

    The MXU processes 128x128 systolic tiles; a (block_rows, c) @ (c, c)
    matmul uses ceil(block_rows/128)*ceil(c/128)*ceil(c/128) tiles of which
    the useful fraction is (block_rows*c*c) / (tiles * 128^3).
    """
    import math

    tiles = (
        math.ceil(block_rows / 128) * math.ceil(c / 128) * math.ceil(c / 128)
    )
    useful = block_rows * c * c
    return useful / (tiles * 128**3)
