"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

These are the ground truth the pytest suite checks the kernels against
(`assert_allclose`), and also what the JAX model (L2) falls back to when a
kernel is disabled — both paths lower to the same artifact interface, so the
Rust coordinator is oblivious to which implementation produced the HLO.
"""

import jax.numpy as jnp


def deq_block_ref(z, u, w1, b1, w2, b2):
    """Reference for the fused DEQ residual-block core.

    z:  (B, P, C)  current fixed-point estimate (P = H*W pixels)
    u:  (B, P, C)  input injection
    w1: (C, C), b1: (C,), w2: (C, C), b2: (C,)

    Returns relu(z @ w1 + u + b1) @ w2 + b2  — the pre-norm residual branch.
    """
    h = jnp.maximum(jnp.einsum("bpc,cd->bpd", z, w1) + u + b1, 0.0)
    return jnp.einsum("bpc,cd->bpd", h, w2) + b2


def lowrank_apply_ref(v, us, vs):
    """Reference for the Sherman-Morrison low-rank inverse application.

    The SHINE backward operation: (I + sum_i u_i v_i^T) v = v + U^T (V v).

    v:  (d,)     input vector
    us: (m, d)   row-major stack of the u_i factors
    vs: (m, d)   row-major stack of the v_i factors
    """
    return v + us.T @ (vs @ v)


def layer_norm_ref(x, gamma, beta, eps=1e-5):
    """Per-position layer norm over the channel axis (last dim)."""
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
