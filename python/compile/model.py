"""L2: the DEQ model in JAX (build-time only; lowered to HLO by aot.py).

Architecture (the TPU adaptation of the MDEQ block, DESIGN.md
Hardware-Adaptation): a single-scale channel-mixing DEQ over patch
embeddings,

    u          = patchify(x) @ Wemb + bemb                (injection)
    f_theta(z) = LayerNorm(z + relu(z @ W1 + u + b1) @ W2 + b2; gamma, beta)
    z*         : z* = f_theta(z*)   (equivalently g(z) = z - f_theta(z) = 0)
    logits     = mean_P(z*) @ Whead + bhead

The fixed point z* has shape (B, P, C); with the CIFAR-proxy config the
flattened dimension B*P*C = 32*64*32 = 65,536 — the paper's CIFAR MDEQ is
d = 50k. Everything the Rust coordinator needs at run time is exported as a
separate jitted entry point (see make_entry_points) so the forward solver,
the backward strategies and the optimizer can call exactly the piece they
need. Parameter order is fixed by PARAM_NAMES and mirrored in
rust/src/deq/model.rs via the manifest.
"""

import jax
import jax.numpy as jnp

from compile.kernels.deq_block import deq_block
from compile.kernels.ref import deq_block_ref, layer_norm_ref

# ---------------------------------------------------------------------------
# Variants (shapes are AOT-fixed; the manifest records them for Rust)
# ---------------------------------------------------------------------------

VARIANTS = {
    # CIFAR-proxy: fixed-point dim 32*64*32 = 65,536 (paper CIFAR: 50k)
    "cifar": dict(batch=32, h=16, w=16, c_in=3, patch=2, c=32, n_classes=10, unroll=6),
    # ImageNet-proxy: 32*144*40 = 184,320 (paper ImageNet: 190k)
    "imagenet": dict(batch=32, h=24, w=24, c_in=3, patch=2, c=40, n_classes=100, unroll=6),
    # Tiny: fast CI / integration-test variant
    "tiny": dict(batch=4, h=8, w=8, c_in=3, patch=2, c=8, n_classes=4, unroll=4),
}

PARAM_NAMES = [
    "wemb", "bemb",  # injection
    "w1", "b1", "w2", "b2", "gamma", "beta",  # DEQ block
    "whead", "bhead",  # classification head
]

# Parameters that f_theta (the fixed-point map) depends on.
F_PARAM_NAMES = ["w1", "b1", "w2", "b2", "gamma", "beta"]


def cfg_dims(cfg):
    """Derived dims: (P pixels, Cp patch channels)."""
    p = (cfg["h"] // cfg["patch"]) * (cfg["w"] // cfg["patch"])
    cp = cfg["c_in"] * cfg["patch"] * cfg["patch"]
    return p, cp


def param_shapes(cfg):
    """Ordered dict name -> shape, the ABI shared with Rust."""
    _, cp = cfg_dims(cfg)
    c, k = cfg["c"], cfg["n_classes"]
    return {
        "wemb": (cp, c),
        "bemb": (c,),
        "w1": (c, c),
        "b1": (c,),
        "w2": (c, c),
        "b2": (c,),
        "gamma": (c,),
        "beta": (c,),
        "whead": (c, k),
        "bhead": (k,),
    }


def init_params(cfg, key):
    """He-style init; gamma=1, biases/beta=0. Only used by python tests —
    the Rust coordinator owns parameter state at run time (same shapes)."""
    shapes = param_shapes(cfg)
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        if name == "gamma":
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.startswith("b") or name == "beta":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0]
            std = (2.0 / fan_in) ** 0.5
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Model pieces
# ---------------------------------------------------------------------------


def patchify(x, cfg):
    """(B, h*w*c_in) -> (B, P, patch*patch*c_in) non-overlapping patches."""
    b = x.shape[0]
    h, w, c_in, s = cfg["h"], cfg["w"], cfg["c_in"], cfg["patch"]
    x = x.reshape(b, h, w, c_in)
    x = x.reshape(b, h // s, s, w // s, s, c_in)
    x = x.transpose(0, 1, 3, 2, 4, 5)  # (b, h/s, w/s, s, s, c_in)
    return x.reshape(b, (h // s) * (w // s), s * s * c_in)


def inject(wemb, bemb, x, cfg):
    """Input injection u = patchify(x) @ Wemb + bemb, shape (B, P, C)."""
    return patchify(x, cfg) @ wemb + bemb


def f_theta(fparams, z, u, use_kernel=True):
    """The fixed-point map f_theta(z; u). fparams = (w1,b1,w2,b2,gamma,beta)."""
    w1, b1, w2, b2, gamma, beta = fparams
    block = deq_block if use_kernel else deq_block_ref
    branch = block(z, u, w1, b1, w2, b2)
    return layer_norm_ref(z + branch, gamma, beta)


def head_logits(whead, bhead, z):
    """Mean-pool over pixels then linear head: (B, P, C) -> (B, K)."""
    pooled = z.mean(axis=1)
    return pooled @ whead + bhead


def head_loss(whead, bhead, z, labels_onehot):
    """Mean softmax cross-entropy."""
    logits = head_logits(whead, bhead, z)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(labels_onehot * logp, axis=-1))


def unrolled_loss(params, x, labels_onehot, cfg, use_kernel=True):
    """Weight-tied unrolled forward (the DEQ pre-training phase, App. D):
    z_{t+1} = f_theta(z_t; u), z_0 = 0, `unroll` steps, then the head loss."""
    u = inject(params["wemb"], params["bemb"], x, cfg)
    p, _ = cfg_dims(cfg)
    z = jnp.zeros((cfg["batch"], p, cfg["c"]), jnp.float32)
    fparams = tuple(params[n] for n in F_PARAM_NAMES)
    for _ in range(cfg["unroll"]):
        z = f_theta(fparams, z, u, use_kernel=use_kernel)
    return head_loss(params["whead"], params["bhead"], z, labels_onehot)


# ---------------------------------------------------------------------------
# AOT entry points (each lowered to one artifact per variant)
# ---------------------------------------------------------------------------


def make_entry_points(cfg, use_kernel=True):
    """Return name -> (fn, example_args) for every artifact of a variant.

    All fns take/return flat tuples of f32 arrays — the PJRT ABI the Rust
    runtime speaks. Tuples are returned even for single outputs (the Rust
    side unwraps with to_tuple*).
    """
    p, cp = cfg_dims(cfg)
    b, c, k = cfg["batch"], cfg["c"], cfg["n_classes"]
    zs = jax.ShapeDtypeStruct((b, p, c), jnp.float32)
    us = zs
    xs = jax.ShapeDtypeStruct((b, cfg["h"] * cfg["w"] * cfg["c_in"]), jnp.float32)
    ys = jax.ShapeDtypeStruct((b, k), jnp.float32)
    wembs = jax.ShapeDtypeStruct((cp, c), jnp.float32)
    bembs = jax.ShapeDtypeStruct((c,), jnp.float32)
    wcc = jax.ShapeDtypeStruct((c, c), jnp.float32)
    wc = jax.ShapeDtypeStruct((c,), jnp.float32)
    wheads = jax.ShapeDtypeStruct((c, k), jnp.float32)
    bheads = jax.ShapeDtypeStruct((k,), jnp.float32)
    fparam_specs = (wcc, wc, wcc, wc, wc, wc)

    def fp(args):
        return tuple(args[:6])

    # ---- forward pieces
    def inject_fn(wemb, bemb, x):
        return (inject(wemb, bemb, x, cfg),)

    def f_fwd(*args):
        z, u = args[6], args[7]
        return (f_theta(fp(args), z, u, use_kernel=use_kernel),)

    # ---- VJPs for the backward pass.
    # NOTE: pallas_call(interpret=True) has no autodiff rule, so every
    # *differentiated* entry point traces the pure-jnp reference block —
    # which pytest asserts is numerically identical to the kernel
    # (tests/test_kernels.py). Only f_fwd (the forward hot loop) routes
    # through the Pallas kernel.
    def f_vjp_z(*args):
        z, u, v = args[6], args[7], args[8]
        _, pullback = jax.vjp(
            lambda zz: f_theta(fp(args), zz, u, use_kernel=False), z
        )
        return (pullback(v)[0],)

    def f_vjp_params_u(*args):
        z, u, v = args[6], args[7], args[8]
        _, pullback = jax.vjp(
            lambda fparams, uu: f_theta(fparams, z, uu, use_kernel=False),
            fp(args),
            u,
        )
        dfp, du = pullback(v)
        return (*dfp, du)

    def f_jvp(*args):
        z, u, v = args[6], args[7], args[8]
        _, tangent = jax.jvp(
            lambda zz: f_theta(fp(args), zz, u, use_kernel=False), (z,), (v,)
        )
        return (tangent,)

    def inject_vjp(wemb, bemb, x, du):
        _, pullback = jax.vjp(lambda we, be: inject(we, be, x, cfg), wemb, bemb)
        dwe, dbe = pullback(du)
        return (dwe, dbe)

    # ---- head
    def head_logits_fn(whead, bhead, z):
        return (head_logits(whead, bhead, z),)

    def head_loss_grad(whead, bhead, z, y):
        loss, grads = jax.value_and_grad(head_loss, argnums=(0, 1, 2))(
            whead, bhead, z, y
        )
        dwhead, dbhead, dz = grads
        return (jnp.reshape(loss, (1,)), dz, dwhead, dbhead)

    # ---- unrolled pre-training step (loss + all 10 param grads)
    def pretrain_grads(*args):
        params = dict(zip(PARAM_NAMES, args[:10]))
        x, y = args[10], args[11]
        loss, grads = jax.value_and_grad(
            lambda pp: unrolled_loss(pp, x, y, cfg, use_kernel=False)
        )(params)
        return (jnp.reshape(loss, (1,)), *(grads[n] for n in PARAM_NAMES))

    all_param_specs = (wembs, bembs, *fparam_specs, wheads, bheads)
    return {
        "inject": (inject_fn, (wembs, bembs, xs)),
        "f_fwd": (f_fwd, (*fparam_specs, zs, us)),
        "f_vjp_z": (f_vjp_z, (*fparam_specs, zs, us, zs)),
        "f_vjp_params_u": (f_vjp_params_u, (*fparam_specs, zs, us, zs)),
        "f_jvp": (f_jvp, (*fparam_specs, zs, us, zs)),
        "inject_vjp": (inject_vjp, (wembs, bembs, xs, us)),
        "head_logits": (head_logits_fn, (wheads, bheads, zs)),
        "head_loss_grad": (head_loss_grad, (wheads, bheads, zs, ys)),
        "pretrain_grads": (pretrain_grads, (*all_param_specs, xs, ys)),
    }


def make_lowrank_entry(d, m=30):
    """The L1 lowrank_apply kernel as a standalone artifact (see
    kernels/lowrank_apply.py for when Rust routes through it)."""
    from compile.kernels.lowrank_apply import lowrank_apply

    vspec = jax.ShapeDtypeStruct((d,), jnp.float32)
    fspec = jax.ShapeDtypeStruct((m, d), jnp.float32)

    def fn(v, us, vsf):
        return (lowrank_apply(v, us, vsf),)

    return fn, (vspec, fspec, fspec)
