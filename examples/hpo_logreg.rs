//! Hyperparameter optimization on sparse logistic regression — the Fig. 1
//! workload at example scale. Compares HOAG (full iterative inversion),
//! SHINE, and the Jacobian-Free method on wall-clock time to a given
//! held-out test loss.
//!
//! Run: cargo run --release --example hpo_logreg

use shine::bilevel::hoag::{hoag_run, HoagOptions};
use shine::data::split::split_logreg;
use shine::data::synth_text::{synth_text, TextConfig};
use shine::hypergrad::Strategy;
use shine::problems::logreg::{LogRegInner, LogRegOuter};
use shine::util::rng::Rng;

fn main() {
    let mut cfg = TextConfig::news20_like();
    cfg.n_docs = 600;
    cfg.n_features = 2000;
    cfg.n_informative = 100;
    let data = synth_text(&cfg, 0);
    let mut rng = Rng::new(1);
    let (train, val, test) = split_logreg(&data, &mut rng);
    println!(
        "dataset: n_train={} d={} (sparse, 20news-like)",
        train.n(),
        train.x.cols
    );
    let prob = LogRegInner { train };
    let outer = LogRegOuter { val, test };

    for (name, strategy) in [
        (
            "hoag (original)",
            Strategy::Full {
                tol: 1e-8,
                max_iters: usize::MAX,
            },
        ),
        ("shine", Strategy::Shine),
        ("jacobian-free", Strategy::JacobianFree),
    ] {
        let accelerated = !matches!(strategy, Strategy::Full { .. });
        let opts = HoagOptions {
            outer_iters: 25,
            strategy,
            tol_decrease: if accelerated { 0.78 } else { 0.99 },
            inner_memory: if accelerated { 30 } else { 10 },
            ..Default::default()
        };
        let res = hoag_run(&prob, &outer, &[-4.0], &opts);
        let last = res.trace.last().unwrap();
        println!(
            "{name:<16}: {:>6.2}s total, final test loss {:.4}, theta {:+.3}",
            res.total_time, last.test_loss, last.theta[0]
        );
        // time to reach a fixed "acceptable" test loss
        let target = 0.35;
        let hit = res.trace.iter().find(|p| p.test_loss <= target);
        match hit {
            Some(p) => println!("{:<18} reached test loss {target} at t={:.2}s", "", p.time),
            None => println!("{:<18} never reached test loss {target}", ""),
        }
    }
}
