//! Quickstart: the SHINE idea in 60 lines on a problem with a closed-form
//! answer.
//!
//! We build a quadratic bi-level problem (inner: ridge-regularized
//! quadratic; outer: distance to a validation target), solve the inner
//! problem with L-BFGS, and compare three hypergradients:
//!   * exact           (closed form, available because the problem is tiny)
//!   * Original (HOAG) (iterative CG inversion of the inner Hessian)
//!   * SHINE           (reuse the forward L-BFGS inverse estimate — free!)
//!
//! Run: cargo run --release --example quickstart

use shine::hypergrad::{hypergrad, ForwardArtifacts, Strategy};
use shine::problems::quadratic::{QuadraticBilevel, QuadraticOuter};
use shine::problems::InnerProblem;
use shine::solvers::minimize::{lbfgs_minimize, MinimizeOptions};
use shine::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let n = 50;
    let prob = QuadraticBilevel::random(n, &mut rng);
    let outer = QuadraticOuter {
        target: prob.target.clone(),
    };
    let theta = [0.3]; // log-regularization

    // ---- forward pass: L-BFGS on the inner problem
    let obj = (n, |z: &[f64]| {
        (prob.inner_value(&theta, z).unwrap(), prob.g(&theta, z))
    });
    let fwd = lbfgs_minimize(
        &obj,
        &vec![0.0; n],
        &MinimizeOptions {
            tol: 1e-10,
            memory: 30,
            ..Default::default()
        },
        None,
        None,
    );
    println!(
        "inner solve: {} iterations, |grad r| = {:.2e}",
        fwd.iters, fwd.grad_norm
    );

    // ---- backward pass, three ways
    let arts = ForwardArtifacts {
        z: &fwd.z,
        inv: Some(&fwd.qn),
        low_rank: None,
    };
    let exact = prob.exact_hypergrad(&theta);
    let full = hypergrad(
        &prob,
        &outer,
        &theta,
        &arts,
        Strategy::Full {
            tol: 1e-12,
            max_iters: usize::MAX,
        },
        None,
    );
    let shine_hg = hypergrad(&prob, &outer, &theta, &arts, Strategy::Shine, None);
    let jf = hypergrad(&prob, &outer, &theta, &arts, Strategy::JacobianFree, None);

    println!("\nhypergradient dL/dtheta:");
    println!("  exact          : {exact:+.6}");
    println!(
        "  original (full): {:+.6}   ({} Hessian-vector products)",
        full.grad_theta[0], full.backward_matvecs
    );
    println!(
        "  SHINE          : {:+.6}   (0 products -- reuses the forward estimate)",
        shine_hg.grad_theta[0]
    );
    println!(
        "  Jacobian-Free  : {:+.6}   (0 products -- pretends J^-1 = I)",
        jf.grad_theta[0]
    );
    let rel = |x: f64| (x - exact).abs() / exact.abs();
    println!(
        "\nrelative error: full {:.2e}, SHINE {:.2e}, JF {:.2e}",
        rel(full.grad_theta[0]),
        rel(shine_hg.grad_theta[0]),
        rel(jf.grad_theta[0])
    );
}
