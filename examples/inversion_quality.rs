//! Inversion-quality scatter (Fig. 2-right): how well does the L-BFGS+OPA
//! inverse estimate match the exact inverse Hessian in (a) the prescribed
//! OPA direction, (b) a Krylov direction, (c) a random direction?
//!
//! Run: cargo run --release --example inversion_quality

use shine::coordinator::{run_experiment, ExpCtx};

fn main() -> anyhow::Result<()> {
    let ctx = ExpCtx {
        seed: 0,
        quick: true, // 10 seeds; flip to false for the paper's 100
        out_dir: "results".into(),
        ..Default::default()
    };
    let out = run_experiment("fig2-right", &ctx)?;
    println!("\nmedian cosine similarity to the exact inverse direction:");
    for kind in ["prescribed", "krylov", "random"] {
        let med = out
            .at(&[kind, "median_cos"])
            .and_then(|j| j.as_f64())
            .unwrap_or(f64::NAN);
        println!("  {kind:<11}: {med:.3}");
    }
    println!("\n(the OPA update direction is inverted almost exactly — eq. 5 at work)");
    Ok(())
}
