//! HPO on regularized *nonlinear least squares* (eq. 12, Fig. E.2) — the
//! non-convex inner problem where the Hessian inverse is genuinely hard to
//! approximate and OPA's extra secant updates pay off.
//!
//! Run: cargo run --release --example nls_hpo

use shine::bilevel::hoag::{hoag_run, HoagOptions};
use shine::data::split::{logreg_to_nls, split_nls};
use shine::data::synth_text::{synth_text, TextConfig};
use shine::hypergrad::Strategy;
use shine::problems::nls::{NlsInner, NlsOuter};
use shine::qn::lbfgs::OpaConfig;
use shine::util::rng::Rng;

fn main() {
    let mut cfg = TextConfig::news20_like();
    cfg.n_docs = 500;
    cfg.n_features = 1500;
    cfg.n_informative = 80;
    let data = logreg_to_nls(&synth_text(&cfg, 3));
    let mut rng = Rng::new(4);
    let (train, val, test) = split_nls(&data, &mut rng);
    println!("NLS dataset: n_train={} d={}", train.n(), train.x.cols);
    let prob = NlsInner { train };
    let outer = NlsOuter { val, test };

    for (name, strategy, opa) in [
        (
            "hoag",
            Strategy::Full {
                tol: 1e-8,
                max_iters: usize::MAX,
            },
            false,
        ),
        ("shine", Strategy::Shine, false),
        ("shine-opa", Strategy::Shine, true),
        ("jacobian-free", Strategy::JacobianFree, false),
    ] {
        let opts = HoagOptions {
            outer_iters: 25,
            strategy,
            inner_memory: if opa { 60 } else { 30 },
            opa: opa.then_some(OpaConfig { freq: 5, t0: 1.0 }),
            ..Default::default()
        };
        let res = hoag_run(&prob, &outer, &[-4.0], &opts);
        let last = res.trace.last().unwrap();
        println!(
            "{name:<14}: {:>6.2}s, final test loss {:.5}, theta {:+.3}",
            res.total_time, last.test_loss, last.theta[0]
        );
    }
}
