//! End-to-end driver (DESIGN.md e2e): train the DEQ image classifier through
//! the full three-layer stack — Rust Broyden forward solver calling the
//! AOT-compiled JAX/Pallas artifacts via PJRT, SHINE backward pass, Adam.
//!
//! Logs the pretraining + equilibrium loss curves and final accuracy; the
//! run is recorded in EXPERIMENTS.md.
//!
//! Requires `make artifacts`. Run: cargo run --release --example deq_train
//! Env: DEQ_STEPS / DEQ_PRETRAIN / DEQ_VARIANT override the defaults.

use shine::coordinator::{run_experiment, ExpCtx};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("DEQ_QUICK").is_ok();
    let ctx = ExpCtx {
        seed: 0,
        quick,
        out_dir: "results".into(),
        ..Default::default()
    };
    let out = run_experiment("e2e", &ctx)?;
    let acc = out.get("top1_accuracy").and_then(|j| j.as_f64()).unwrap();
    let fwd = out.get("median_fwd_ms").and_then(|j| j.as_f64()).unwrap();
    let bwd = out.get("median_bwd_ms").and_then(|j| j.as_f64()).unwrap();
    println!("\n=== end-to-end DEQ training (SHINE backward) ===");
    println!("fixed-point dim : {}", out.get("fixed_point_dim").unwrap().to_string());
    println!("parameters      : {}", out.get("n_params").unwrap().to_string());
    println!("test top-1      : {acc:.3}");
    println!("median fwd pass : {fwd:.1} ms");
    println!("median bwd pass : {bwd:.1} ms  (SHINE: no iterative inversion)");
    println!("loss curve in results/e2e.json");
    Ok(())
}
