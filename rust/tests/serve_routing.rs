//! Multi-model routing invariants (ISSUE 5 satellite):
//!
//! 1. interleaved requests for ≥2 [`ModelKey`]s through ONE
//!    [`KeyedScheduler`] are never cross-batched — every released batch is
//!    single-key and each key's answers match its own model's sequential
//!    reference;
//! 2. a parameter-version bump invalidates only that key's cached
//!    calibration estimate (the other model's estimate survives bit-for-bit);
//! 3. the trip-rate re-calibration policy evicts and re-captures a stale
//!    estimate through the [`Router`] while serving continues;
//! 4. (ISSUE 8) the §3 fallback guard + [`RecalibPolicy`] protect the
//!    reduced-precision panel path: a deliberately degraded estimate served
//!    from bf16 storage trips the guard, is flagged stale, and a
//!    re-calibration restores full-precision-grade backward answers.

use shine::linalg::vecops::{Bf16, Elem};
use shine::qn::{LowRank, MemoryPolicy};
use shine::serve::{
    run_routed_closed_loop, BatchReport, EngineConfig, KeyedScheduler, ModelKey, RecalibPolicy,
    RoutedLoadConfig, Router, Scheduler, SchedulerConfig, ServeEngine, SynthDeq,
};
use shine::solvers::fixed_point::{picard_solve, ColStats};
use shine::solvers::session::{EstimateHandle, SolverSpec};
use shine::util::rng::Rng;

fn cfg(max_batch: usize, tol: f64) -> EngineConfig {
    EngineConfig {
        max_batch,
        solver: SolverSpec::picard(1.0).with_tol(tol).with_max_iters(200),
        calib: SolverSpec::broyden(20).with_tol(tol).with_max_iters(40),
        fallback_ratio: None,
        recalib: None,
        col_budget: None,
        breaker: None,
    }
}

#[test]
fn interleaved_keys_never_cross_batch() {
    // Two models with different parameters behind one keyed scheduler.
    // Requests arrive interleaved A,B,A,B,…; every drained batch must be
    // single-key, and each served answer must equal the sequential solve
    // against THAT key's model (a cross-batched request would converge to
    // the wrong model's fixed point).
    let d = 40;
    let tol = 1e-5;
    let ka = ModelKey::new(0, 0);
    let kb = ModelKey::new(1, 0);
    let model_a: SynthDeq<f32> = SynthDeq::new(d, 8, 100);
    let model_b: SynthDeq<f32> = SynthDeq::new(d, 8, 200);
    let mut router: Router<f32> = Router::new(cfg(4, tol));
    router.register(ka, Box::new(SynthDeq::<f32>::new(d, 8, 100)));
    router.register(kb, Box::new(SynthDeq::<f32>::new(d, 8, 200)));

    let mut sched: KeyedScheduler<u32> = KeyedScheduler::new(SchedulerConfig {
        max_batch: 4,
        max_wait: 0.0, // release whatever the oldest key has queued
        queue_cap: 64,
    });
    let total = 14u32;
    for i in 0..total {
        let key = if i % 2 == 0 { ka } else { kb };
        sched.push(i as f64 * 0.01, key, i).unwrap();
    }
    // Per-model sequential references (all requests start from z0 = 0, so
    // each model has ONE reference fixed point).
    let reference = |m: &SynthDeq<f32>| {
        picard_solve(
            |z: &[f32], out: &mut [f32]| m.residual_batch(z, 1, out),
            &vec![0.0f32; d],
            1.0,
            tol,
            200,
        )
        .0
    };
    let ref_a = reference(&model_a);
    let ref_b = reference(&model_b);
    assert!(ref_a != ref_b, "distinct models must have distinct fixed points");

    let mut served = 0u32;
    let mut items: Vec<(f64, u32)> = Vec::new();
    while served < total {
        let (key, n) = sched.ready(1e9).expect("work outstanding");
        items.clear();
        sched.drain_key(key, n, 1e9, &mut items);
        assert!(!items.is_empty());
        // The batch is single-key by construction of drain_key; check the
        // payload parity (we enqueued evens on A, odds on B).
        for &(_, payload) in &items {
            assert_eq!(
                payload % 2 == 0,
                key == ka,
                "request {payload} routed into a {key} batch"
            );
        }
        let b = items.len();
        let mut zs = vec![0.0f32; b * d];
        let cots = vec![0.0f32; b * d];
        let mut w = vec![0.0f32; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = router.process(key, &mut zs, &cots, &mut w, &mut stats).unwrap();
        assert!(rep.all_converged);
        let want = if key == ka { &ref_a } else { &ref_b };
        for j in 0..b {
            assert!(
                zs[j * d..(j + 1) * d] == want[..],
                "batch for {key} solved against the wrong model"
            );
        }
        served += b as u32;
    }
    assert_eq!(served, total);
}

#[test]
fn version_bump_invalidates_only_that_key() {
    let d = 36;
    let mut router: Router<f64> = Router::new(cfg(4, 1e-7));
    let m0v0 = ModelKey::new(0, 0);
    let m1v0 = ModelKey::new(1, 0);
    router.register(m0v0, Box::new(SynthDeq::<f64>::new(d, 6, 11)));
    router.register(m1v0, Box::new(SynthDeq::<f64>::new(d, 6, 22)));
    let mut rng = Rng::new(4);
    let probe = rng.normal_vec(d);
    let m1_before = router
        .engine(m1v0)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);
    let m0_before = router
        .engine(m0v0)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);

    // Roll model 0 to version 1 (new parameters → new key).
    let m0v1 = ModelKey::new(0, 1);
    router.register(m0v1, Box::new(SynthDeq::<f64>::new(d, 6, 33)));

    // Exactly (0,0) was evicted; (0,1) has a FRESH estimate; (1,0) kept its
    // cached estimate bit-for-bit.
    assert!(router.engine(m0v0).is_none(), "old version must be evicted");
    let m0_after = router
        .engine(m0v1)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);
    assert!(m0_after != m0_before, "new version must re-calibrate");
    let m1_after = router
        .engine(m1v0)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);
    assert_eq!(m1_before, m1_after, "unrelated key's cache must survive");
    assert_eq!(router.keys(), vec![m1v0, m0v1]);
}

#[test]
fn routed_closed_loop_with_recalibration_policy() {
    // End-to-end routed serving with an aggressive staleness policy: a
    // pathological fallback ratio trips the guard on every cotangent, so
    // the router must evict + re-calibrate mid-run and still serve every
    // request to convergence.
    let d = 32;
    let mut config = cfg(3, 1e-4);
    config.fallback_ratio = Some(1e-6); // everything "blows up" → trips
    config.recalib = Some(RecalibPolicy {
        trip_rate: 0.5,
        min_cols: 4,
    });
    let mut router: Router<f32> = Router::new(config);
    let ka = ModelKey::new(0, 0);
    let kb = ModelKey::new(1, 0);
    router.register(ka, Box::new(SynthDeq::<f32>::new(d, 8, 7)));
    router.register(kb, Box::new(SynthDeq::<f32>::new(d, 8, 8)));
    let lc = RoutedLoadConfig {
        clients_per_model: 3,
        total: 24,
        max_batch: 3,
        max_wait: 1e-4,
    };
    let rep = run_routed_closed_loop(&mut router, &[ka, kb], &lc, 3);
    assert_eq!(rep.requests, 24);
    assert!(rep.all_converged);
    assert!(
        rep.recalibrations > 0,
        "the trip-rate policy must have re-calibrated at least once"
    );
    // Re-calibration restores a live estimate per key.
    assert!(router.engine(ka).unwrap().estimate().is_some());
    assert!(router.engine(kb).unwrap().estimate().is_some());
    assert!(router.engine(ka).unwrap().calibrations() >= 2 || router.engine(kb).unwrap().calibrations() >= 2);
}

/// Drive one already-calibrated engine over a fresh zero-initialized batch
/// and hand back the backward answers plus the batch report. Generic over
/// the panel storage so the bf16 engine and its f32 reference share the
/// exact same serving code path.
fn serve_once<EU: Elem, EV: Elem>(
    engine: &mut ServeEngine<f32, EU, EV>,
    model: &SynthDeq<f32>,
    d: usize,
    cots: &[f32],
) -> (Vec<f32>, BatchReport) {
    let b = cots.len() / d;
    let mut zs = vec![0.0f32; b * d];
    let mut w = vec![0.0f32; b * d];
    let mut stats = vec![ColStats::default(); b];
    let rep = engine.process(
        |block: &[f32], _ids: &[usize], out: &mut [f32]| {
            model.residual_batch(block, block.len() / d, out)
        },
        &mut zs,
        cots,
        &mut w,
        &mut stats,
    );
    (w, rep)
}

#[test]
fn degraded_bf16_estimate_trips_guard_and_recalibration_restores_accuracy() {
    // The §3 fallback guard is the safety net that makes reduced-precision
    // panel storage shippable (ADR-003). Three acts:
    //   1. a freshly calibrated estimate, demoted to bf16 panels, serves
    //      guard-silent and tracks the f32 reference backward;
    //   2. a deliberately degraded estimate injected into bf16 storage
    //      blows every cotangent past `ratio * ||dz||` — the guard reverts
    //      the answers and the RecalibPolicy flags the estimate stale;
    //   3. evict + re-calibrate restores guard-silent serving and
    //      reference-grade answers, exactly the Router's recovery loop.
    let d = 32;
    let tol = 1e-5;
    let b = 4;
    let mut config = cfg(b, tol);
    // Healthy amplification for SynthDeq is ||J_g^{-1}|| ≈ 2 (Jacobian norm
    // ≈ 0.5), so 4.0 stays silent on a good estimate and trips on a bad one.
    config.fallback_ratio = Some(4.0);
    let policy = RecalibPolicy {
        trip_rate: 0.5,
        min_cols: 4,
    };
    config.recalib = Some(policy);
    let model: SynthDeq<f32> = SynthDeq::new(d, 8, 77);
    let z0 = vec![0.0f32; d];

    // bf16 panel storage under test; homogeneous f32 panels as reference.
    let mut engine: ServeEngine<f32, Bf16, Bf16> = ServeEngine::new(d, config);
    let mut reference: ServeEngine<f32> = ServeEngine::new(d, cfg(b, tol));
    engine.calibrate(|z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out), &z0);
    reference.calibrate(|z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out), &z0);
    assert_eq!(engine.calibrations(), 1);

    let mut rng = Rng::new(9);
    let cots = rng.normal_vec_f32(b * d, 1.0);
    let rel = |a: &[f32], r: &[f32]| {
        let num: f64 = a
            .iter()
            .zip(r)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let den: f64 = r.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
        num / den.max(1e-30)
    };

    // Act 1: healthy bf16-stored estimate — guard silent, answers track f32.
    let (w_ref, rep_ref) = serve_once(&mut reference, &model, d, &cots);
    let (w16, rep) = serve_once(&mut engine, &model, d, &cots);
    assert!(rep_ref.all_converged && rep.all_converged);
    assert_eq!(rep.fallback_cols, 0, "healthy bf16 estimate must serve guard-silent");
    assert!(!rep.estimate_stale);
    let healthy_err = rel(&w16, &w_ref);
    assert!(
        healthy_err < 5e-2,
        "bf16 backward must track the f32 reference (rel err {healthy_err:.2e})"
    );

    // Act 2: inject a degraded estimate. H^T = I + Σ v_i u_i^T with
    // u_i = 100·e_i amplifies the first 8 components of every cotangent
    // ×101, so ||H^T dz|| >> ratio · ||dz|| for any generic dz. 100.0 and
    // 1.0 are exactly representable in bf16 — the blow-up survives demotion.
    let mut bad: LowRank<f32> = LowRank::identity(d, 16, MemoryPolicy::Freeze);
    for i in 0..8 {
        let mut u = vec![0.0f32; d];
        let mut v = vec![0.0f32; d];
        u[i] = 100.0;
        v[i] = 1.0;
        assert!(bad.push(&u, &v));
    }
    engine.install_estimate(EstimateHandle::new(bad));
    let (_w_bad, rep_bad) = serve_once(&mut engine, &model, d, &cots);
    assert!(rep_bad.all_converged, "the forward solve is estimate-independent");
    assert_eq!(
        rep_bad.fallback_cols, b,
        "every degraded cotangent must trip the guard"
    );
    assert!(
        rep_bad.estimate_stale,
        "{} guarded cols at 100% trips must cross RecalibPolicy {{ trip_rate: {}, min_cols: {} }}",
        b, policy.trip_rate, policy.min_cols
    );
    assert!(engine.estimate_stale());
    assert!(engine.trip_rate() > policy.trip_rate);

    // Act 3: the Router's recovery loop — evict, re-calibrate, serve again.
    engine.invalidate_estimate();
    assert!(engine.estimate().is_none());
    let (_, probe_res) = engine.calibrate(
        |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
        &z0,
    );
    assert!(probe_res <= tol, "re-calibration probe must converge ({probe_res:.2e})");
    assert_eq!(engine.calibrations(), 2, "install_estimate is not a calibration");
    let (w_rec, rep_rec) = serve_once(&mut engine, &model, d, &cots);
    assert!(rep_rec.all_converged);
    assert_eq!(rep_rec.fallback_cols, 0, "re-calibration must silence the guard");
    assert!(!rep_rec.estimate_stale && !engine.estimate_stale());
    assert_eq!(engine.trip_rate(), 0.0, "staleness counters restart clean");
    let rec_err = rel(&w_rec, &w_ref);
    assert!(
        rec_err < 5e-2,
        "recovered bf16 backward must match the reference again (rel err {rec_err:.2e})"
    );
}

#[test]
fn single_key_scheduler_matches_plain_scheduler_policy() {
    // With one key, the keyed scheduler's policy must agree with the plain
    // Scheduler on the same arrival trace (routing degenerates cleanly).
    let k = ModelKey::new(0, 0);
    let sc = SchedulerConfig {
        max_batch: 3,
        max_wait: 0.5,
        queue_cap: 16,
    };
    let mut plain: Scheduler<u32> = Scheduler::new(sc);
    let mut keyed: KeyedScheduler<u32> = KeyedScheduler::new(sc);
    let arrivals = [(0.0, 1u32), (0.1, 2), (0.2, 3), (0.25, 4)];
    for &(t, p) in &arrivals {
        plain.push(t, p).unwrap();
        keyed.push(t, k, p).unwrap();
    }
    for now in [0.2, 0.3, 0.6, 1.0] {
        let plain_n = plain.ready(now);
        let keyed_n = keyed.ready(now).map(|(_, n)| n).unwrap_or(0);
        assert_eq!(plain_n, keyed_n, "policy divergence at t={now}");
    }
    assert_eq!(plain.next_deadline(), keyed.next_deadline());
}
