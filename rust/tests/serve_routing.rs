//! Multi-model routing invariants (ISSUE 5 satellite):
//!
//! 1. interleaved requests for ≥2 [`ModelKey`]s through ONE
//!    [`KeyedScheduler`] are never cross-batched — every released batch is
//!    single-key and each key's answers match its own model's sequential
//!    reference;
//! 2. a parameter-version bump invalidates only that key's cached
//!    calibration estimate (the other model's estimate survives bit-for-bit);
//! 3. the trip-rate re-calibration policy evicts and re-captures a stale
//!    estimate through the [`Router`] while serving continues.

use shine::qn::InvOp;
use shine::serve::{
    run_routed_closed_loop, EngineConfig, KeyedScheduler, ModelKey, RecalibPolicy,
    RoutedLoadConfig, Router, Scheduler, SchedulerConfig, SynthDeq,
};
use shine::solvers::fixed_point::{picard_solve, ColStats};
use shine::solvers::session::SolverSpec;
use shine::util::rng::Rng;

fn cfg(max_batch: usize, tol: f64) -> EngineConfig {
    EngineConfig {
        max_batch,
        solver: SolverSpec::picard(1.0).with_tol(tol).with_max_iters(200),
        calib: SolverSpec::broyden(20).with_tol(tol).with_max_iters(40),
        fallback_ratio: None,
        recalib: None,
        col_budget: None,
    }
}

#[test]
fn interleaved_keys_never_cross_batch() {
    // Two models with different parameters behind one keyed scheduler.
    // Requests arrive interleaved A,B,A,B,…; every drained batch must be
    // single-key, and each served answer must equal the sequential solve
    // against THAT key's model (a cross-batched request would converge to
    // the wrong model's fixed point).
    let d = 40;
    let tol = 1e-5;
    let ka = ModelKey::new(0, 0);
    let kb = ModelKey::new(1, 0);
    let model_a: SynthDeq<f32> = SynthDeq::new(d, 8, 100);
    let model_b: SynthDeq<f32> = SynthDeq::new(d, 8, 200);
    let mut router: Router<f32> = Router::new(cfg(4, tol));
    router.register(ka, Box::new(SynthDeq::<f32>::new(d, 8, 100)));
    router.register(kb, Box::new(SynthDeq::<f32>::new(d, 8, 200)));

    let mut sched: KeyedScheduler<u32> = KeyedScheduler::new(SchedulerConfig {
        max_batch: 4,
        max_wait: 0.0, // release whatever the oldest key has queued
        queue_cap: 64,
    });
    let total = 14u32;
    for i in 0..total {
        let key = if i % 2 == 0 { ka } else { kb };
        sched.push(i as f64 * 0.01, key, i).unwrap();
    }
    // Per-model sequential references (all requests start from z0 = 0, so
    // each model has ONE reference fixed point).
    let reference = |m: &SynthDeq<f32>| {
        picard_solve(
            |z: &[f32], out: &mut [f32]| m.residual_batch(z, 1, out),
            &vec![0.0f32; d],
            1.0,
            tol,
            200,
        )
        .0
    };
    let ref_a = reference(&model_a);
    let ref_b = reference(&model_b);
    assert!(ref_a != ref_b, "distinct models must have distinct fixed points");

    let mut served = 0u32;
    let mut items: Vec<(f64, u32)> = Vec::new();
    while served < total {
        let (key, n) = sched.ready(1e9).expect("work outstanding");
        items.clear();
        sched.drain_key(key, n, 1e9, &mut items);
        assert!(!items.is_empty());
        // The batch is single-key by construction of drain_key; check the
        // payload parity (we enqueued evens on A, odds on B).
        for &(_, payload) in &items {
            assert_eq!(
                payload % 2 == 0,
                key == ka,
                "request {payload} routed into a {key} batch"
            );
        }
        let b = items.len();
        let mut zs = vec![0.0f32; b * d];
        let cots = vec![0.0f32; b * d];
        let mut w = vec![0.0f32; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = router.process(key, &mut zs, &cots, &mut w, &mut stats).unwrap();
        assert!(rep.all_converged);
        let want = if key == ka { &ref_a } else { &ref_b };
        for j in 0..b {
            assert!(
                zs[j * d..(j + 1) * d] == want[..],
                "batch for {key} solved against the wrong model"
            );
        }
        served += b as u32;
    }
    assert_eq!(served, total);
}

#[test]
fn version_bump_invalidates_only_that_key() {
    let d = 36;
    let mut router: Router<f64> = Router::new(cfg(4, 1e-7));
    let m0v0 = ModelKey::new(0, 0);
    let m1v0 = ModelKey::new(1, 0);
    router.register(m0v0, Box::new(SynthDeq::<f64>::new(d, 6, 11)));
    router.register(m1v0, Box::new(SynthDeq::<f64>::new(d, 6, 22)));
    let mut rng = Rng::new(4);
    let probe = rng.normal_vec(d);
    let m1_before = router
        .engine(m1v0)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);
    let m0_before = router
        .engine(m0v0)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);

    // Roll model 0 to version 1 (new parameters → new key).
    let m0v1 = ModelKey::new(0, 1);
    router.register(m0v1, Box::new(SynthDeq::<f64>::new(d, 6, 33)));

    // Exactly (0,0) was evicted; (0,1) has a FRESH estimate; (1,0) kept its
    // cached estimate bit-for-bit.
    assert!(router.engine(m0v0).is_none(), "old version must be evicted");
    let m0_after = router
        .engine(m0v1)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);
    assert!(m0_after != m0_before, "new version must re-calibrate");
    let m1_after = router
        .engine(m1v0)
        .unwrap()
        .estimate()
        .unwrap()
        .apply_t_vec(&probe);
    assert_eq!(m1_before, m1_after, "unrelated key's cache must survive");
    assert_eq!(router.keys(), vec![m1v0, m0v1]);
}

#[test]
fn routed_closed_loop_with_recalibration_policy() {
    // End-to-end routed serving with an aggressive staleness policy: a
    // pathological fallback ratio trips the guard on every cotangent, so
    // the router must evict + re-calibrate mid-run and still serve every
    // request to convergence.
    let d = 32;
    let mut config = cfg(3, 1e-4);
    config.fallback_ratio = Some(1e-6); // everything "blows up" → trips
    config.recalib = Some(RecalibPolicy {
        trip_rate: 0.5,
        min_cols: 4,
    });
    let mut router: Router<f32> = Router::new(config);
    let ka = ModelKey::new(0, 0);
    let kb = ModelKey::new(1, 0);
    router.register(ka, Box::new(SynthDeq::<f32>::new(d, 8, 7)));
    router.register(kb, Box::new(SynthDeq::<f32>::new(d, 8, 8)));
    let lc = RoutedLoadConfig {
        clients_per_model: 3,
        total: 24,
        max_batch: 3,
        max_wait: 1e-4,
    };
    let rep = run_routed_closed_loop(&mut router, &[ka, kb], &lc, 3);
    assert_eq!(rep.requests, 24);
    assert!(rep.all_converged);
    assert!(
        rep.recalibrations > 0,
        "the trip-rate policy must have re-calibrated at least once"
    );
    // Re-calibration restores a live estimate per key.
    assert!(router.engine(ka).unwrap().estimate().is_some());
    assert!(router.engine(kb).unwrap().estimate().is_some());
    assert!(router.engine(ka).unwrap().calibrations() >= 2 || router.engine(kb).unwrap().calibrations() >= 2);
}

#[test]
fn single_key_scheduler_matches_plain_scheduler_policy() {
    // With one key, the keyed scheduler's policy must agree with the plain
    // Scheduler on the same arrival trace (routing degenerates cleanly).
    let k = ModelKey::new(0, 0);
    let sc = SchedulerConfig {
        max_batch: 3,
        max_wait: 0.5,
        queue_cap: 16,
    };
    let mut plain: Scheduler<u32> = Scheduler::new(sc);
    let mut keyed: KeyedScheduler<u32> = KeyedScheduler::new(sc);
    let arrivals = [(0.0, 1u32), (0.1, 2), (0.2, 3), (0.25, 4)];
    for &(t, p) in &arrivals {
        plain.push(t, p).unwrap();
        keyed.push(t, k, p).unwrap();
    }
    for now in [0.2, 0.3, 0.6, 1.0] {
        let plain_n = plain.ready(now);
        let keyed_n = keyed.ready(now).map(|(_, n)| n).unwrap_or(0);
        assert_eq!(plain_n, keyed_n, "policy divergence at t={now}");
    }
    assert_eq!(plain.next_deadline(), keyed.next_deadline());
}
