//! Cross-module integration tests of the bi-level stack on realistic
//! (generated) workloads — no artifacts needed; pure-Rust path.

use shine::bilevel::hoag::{hoag_run, HoagOptions};
use shine::data::split::{logreg_to_nls, split_logreg, split_nls};
use shine::data::synth_text::{synth_text, TextConfig};
use shine::hypergrad::{hypergrad, ForwardArtifacts, Strategy};
use shine::problems::logreg::{LogRegInner, LogRegOuter};
use shine::problems::nls::{NlsInner, NlsOuter};
use shine::problems::InnerProblem;
use shine::solvers::minimize::{lbfgs_minimize, MinimizeOptions};
use shine::util::rng::Rng;

fn small_cfg() -> TextConfig {
    TextConfig {
        n_docs: 240,
        n_features: 400,
        n_informative: 40,
        len_lo: 15,
        len_hi: 50,
        zipf_a: 1.05,
        label_noise: 0.02,
        seed: 0,
    }
}

fn lr_problem(seed: u64) -> (LogRegInner, LogRegOuter) {
    let data = synth_text(&small_cfg(), seed);
    let mut rng = Rng::new(seed ^ 7);
    let (train, val, test) = split_logreg(&data, &mut rng);
    (LogRegInner { train }, LogRegOuter { val, test })
}

/// SHINE's hypergradient on the real LR problem must correlate strongly
/// with the full (exact iterative) hypergradient across theta values.
#[test]
fn shine_hypergrad_correlates_with_full_on_logreg() {
    let (prob, outer) = lr_problem(1);
    let d = prob.dim();
    let mut sign_matches = 0;
    let thetas = [-6.0, -4.0, -2.0, 0.0];
    for &t in &thetas {
        let theta = [t];
        let obj = (d, |z: &[f64]| {
            (prob.inner_value(&theta, z).unwrap(), prob.g(&theta, z))
        });
        let res = lbfgs_minimize(
            &obj,
            &vec![0.0; d],
            &MinimizeOptions {
                tol: 1e-9,
                max_iters: 3000,
                memory: 30,
                ..Default::default()
            },
            None,
            None,
        );
        assert!(res.grad_norm < 1e-6, "inner solve failed at theta={t}");
        let arts = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let full = hypergrad(
            &prob,
            &outer,
            &theta,
            &arts,
            Strategy::Full {
                tol: 1e-10,
                max_iters: usize::MAX,
            },
            None,
        );
        let sh = hypergrad(&prob, &outer, &theta, &arts, Strategy::Shine, None);
        if full.grad_theta[0] * sh.grad_theta[0] > 0.0 {
            sign_matches += 1;
        }
    }
    assert!(
        sign_matches >= 3,
        "SHINE disagreed in sign with full hypergrad too often ({sign_matches}/4)"
    );
}

/// The headline Fig. 1 claim at integration scale: SHINE's backward pass
/// costs zero matvecs while HOAG's full inversion costs many, and both
/// optimize the validation loss.
#[test]
fn hoag_vs_shine_backward_cost_and_descent() {
    let (prob, outer) = lr_problem(2);
    let mk = |strategy| HoagOptions {
        outer_iters: 12,
        strategy,
        ..Default::default()
    };
    let full = hoag_run(
        &prob,
        &outer,
        &[-3.0],
        &mk(Strategy::Full {
            tol: 1e-8,
            max_iters: usize::MAX,
        }),
    );
    let shine = hoag_run(&prob, &outer, &[-3.0], &mk(Strategy::Shine));
    let total_mv_full: usize = full.trace.iter().map(|p| p.backward_matvecs).sum();
    let total_mv_shine: usize = shine.trace.iter().map(|p| p.backward_matvecs).sum();
    assert!(total_mv_full > 0);
    assert_eq!(total_mv_shine, 0);
    // Both decrease validation loss from the first iterate.
    for res in [&full, &shine] {
        let first = res.trace.first().unwrap().val_loss;
        let last = res.trace.last().unwrap().val_loss;
        assert!(last <= first + 1e-9, "val loss increased: {first} -> {last}");
    }
}

/// The fallback guard rarely fires on a healthy LR run with the paper's
/// 1.3 ratio (it is a rare-event guard: 6.25e-5 firing rate in the paper).
#[test]
fn fallback_is_rare_on_healthy_runs() {
    let (prob, outer) = lr_problem(3);
    let opts = HoagOptions {
        outer_iters: 10,
        strategy: Strategy::ShineFallback { ratio: 1.3 },
        ..Default::default()
    };
    let res = hoag_run(&prob, &outer, &[-3.0], &opts);
    let fallbacks = res.trace.iter().filter(|p| p.fallback_used).count();
    assert!(
        fallbacks <= res.trace.len() / 2,
        "fallback fired on {fallbacks}/{} iterations",
        res.trace.len()
    );
}

/// NLS (non-convex inner problem): OPA still produces a descending outer
/// loop and its SHINE directions stay finite.
#[test]
fn nls_with_opa_descends() {
    let data = logreg_to_nls(&synth_text(&small_cfg(), 5));
    let mut rng = Rng::new(11);
    let (train, val, test) = split_nls(&data, &mut rng);
    let prob = NlsInner { train };
    let outer = NlsOuter { val, test };
    let opts = HoagOptions {
        outer_iters: 10,
        strategy: Strategy::Shine,
        inner_memory: 60,
        opa: Some(shine::qn::lbfgs::OpaConfig { freq: 5, t0: 1.0 }),
        ..Default::default()
    };
    let res = hoag_run(&prob, &outer, &[-3.0], &opts);
    assert!(res.trace.iter().all(|p| p.val_loss.is_finite()));
    let first = res.trace.first().unwrap().val_loss;
    let last = res.trace.last().unwrap().val_loss;
    assert!(last <= first + 1e-9);
}

/// Grid search ends up in the same ballpark theta as hypergradient descent —
/// a cross-validation of the whole bilevel stack.
#[test]
fn grid_and_hoag_agree_on_theta_region() {
    let (prob, outer) = lr_problem(6);
    let gs = shine::bilevel::search::grid_search(&prob, &outer, -8.0, 0.0, 9, 1e-7, 2000, 120.0);
    let opts = HoagOptions {
        outer_iters: 25,
        strategy: Strategy::Full {
            tol: 1e-8,
            max_iters: usize::MAX,
        },
        ..Default::default()
    };
    let res = hoag_run(&prob, &outer, &[-4.0], &opts);
    assert!(
        (res.theta[0] - gs.best_theta).abs() < 3.0,
        "hoag theta {} vs grid theta {}",
        res.theta[0],
        gs.best_theta
    );
}
