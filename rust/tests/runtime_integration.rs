//! Integration tests over the PJRT runtime: the AOT artifacts must agree
//! with the pure-Rust native mirror on random inputs (tiny variant), and the
//! DEQ trainer must run end-to-end for every backward strategy.
//!
//! Requires `make artifacts` (skips gracefully with a loud message if the
//! artifacts are missing, so plain `cargo test` works in a fresh checkout).

use shine::data::synth_images::synth_images;
use shine::deq::model::{DeqModel, Params};
use shine::deq::native;
use shine::deq::trainer::{BackwardKind, Trainer, TrainerConfig};
use shine::runtime::engine::{Engine, Tensor};
use shine::util::rng::Rng;

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    match Engine::load(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP: artifacts not available ({err}); run `make artifacts`");
            None
        }
    }
}

fn randv(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    rng.normal_vec_f32(n, std)
}

#[test]
fn inject_matches_native() {
    let Some(eng) = engine() else { return };
    let m = DeqModel::new(&eng, "tiny").unwrap();
    let mut rng = Rng::new(1);
    let p = Params::init(&m.v, &mut rng);
    let x = randv(&mut rng, m.v.batch * m.v.h * m.v.w * m.v.c_in, 1.0);
    let got = m.inject(&p, &x).unwrap();
    let want = native::inject(&m.v, &p.get(&m.v, "wemb").data, &p.get(&m.v, "bemb").data, &x);
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn f_fwd_matches_native() {
    let Some(eng) = engine() else { return };
    let m = DeqModel::new(&eng, "tiny").unwrap();
    let mut rng = Rng::new(2);
    let p = Params::init(&m.v, &mut rng);
    let d = m.v.fixed_point_dim;
    let z = randv(&mut rng, d, 1.0);
    let u = randv(&mut rng, d, 1.0);
    let got = m.f(&p, &z, &u).unwrap();
    let np = p.native(&m.v);
    let want = native::f_theta(&m.v, &np, &z, &u);
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!((a - b).abs() < 1e-3, "idx {i}: {a} vs {b}");
    }
}

#[test]
fn head_matches_native() {
    let Some(eng) = engine() else { return };
    let m = DeqModel::new(&eng, "tiny").unwrap();
    let mut rng = Rng::new(3);
    let p = Params::init(&m.v, &mut rng);
    let z = randv(&mut rng, m.v.fixed_point_dim, 1.0);
    let got = m.head_logits(&p, &z).unwrap();
    let want = native::head_logits(
        &m.v,
        &p.get(&m.v, "whead").data,
        &p.get(&m.v, "bhead").data,
        &z,
    );
    for (a, b) in got.iter().zip(&want) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    // loss consistency
    let labels: Vec<usize> = (0..m.v.batch).map(|i| i % m.v.n_classes).collect();
    let y = native::one_hot(&labels, m.v.n_classes);
    let (loss, dz, _, _) = m.head_loss_grad(&p, &z, &y).unwrap();
    let want_loss = native::ce_loss(&want, &y, m.v.batch, m.v.n_classes);
    assert!((loss - want_loss).abs() < 1e-4, "{loss} vs {want_loss}");
    assert_eq!(dz.len(), z.len());
}

#[test]
fn f_vjp_z_matches_finite_difference() {
    let Some(eng) = engine() else { return };
    let m = DeqModel::new(&eng, "tiny").unwrap();
    let mut rng = Rng::new(4);
    let p = Params::init(&m.v, &mut rng);
    let d = m.v.fixed_point_dim;
    let z = randv(&mut rng, d, 0.5);
    let u = randv(&mut rng, d, 0.5);
    let v = randv(&mut rng, d, 1.0);
    let w = randv(&mut rng, d, 1.0);
    // ⟨v, J w⟩ via finite differences vs ⟨Jᵀv, w⟩ via the artifact.
    let eps = 1e-3f32;
    let zp: Vec<f32> = z.iter().zip(&w).map(|(&a, &b)| a + eps * b).collect();
    let zm: Vec<f32> = z.iter().zip(&w).map(|(&a, &b)| a - eps * b).collect();
    let fp = m.f(&p, &zp, &u).unwrap();
    let fm = m.f(&p, &zm, &u).unwrap();
    let jw: Vec<f64> = fp
        .iter()
        .zip(&fm)
        .map(|(&a, &b)| (a as f64 - b as f64) / (2.0 * eps as f64))
        .collect();
    let lhs: f64 = v.iter().zip(&jw).map(|(&a, &b)| a as f64 * b).sum();
    let jtv = m.f_vjp_z(&p, &z, &u, &v).unwrap();
    let rhs: f64 = jtv.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum();
    let scale = lhs.abs().max(rhs.abs()).max(1.0);
    assert!(
        (lhs - rhs).abs() / scale < 2e-2,
        "adjoint mismatch: {lhs} vs {rhs}"
    );
}

#[test]
fn jvp_vjp_adjoint_identity() {
    let Some(eng) = engine() else { return };
    let m = DeqModel::new(&eng, "tiny").unwrap();
    let mut rng = Rng::new(5);
    let p = Params::init(&m.v, &mut rng);
    let d = m.v.fixed_point_dim;
    let z = randv(&mut rng, d, 0.5);
    let u = randv(&mut rng, d, 0.5);
    let v = randv(&mut rng, d, 1.0);
    let w = randv(&mut rng, d, 1.0);
    let jw = m.f_jvp(&p, &z, &u, &w).unwrap();
    let jtv = m.f_vjp_z(&p, &z, &u, &v).unwrap();
    let lhs: f64 = v.iter().zip(&jw).map(|(&a, &b)| a as f64 * b as f64).sum();
    let rhs: f64 = jtv.iter().zip(&w).map(|(&a, &b)| a as f64 * b as f64).sum();
    let scale = lhs.abs().max(rhs.abs()).max(1.0);
    assert!((lhs - rhs).abs() / scale < 1e-3, "{lhs} vs {rhs}");
}

#[test]
fn lowrank_artifact_matches_rust_lowrank() {
    let Some(eng) = engine() else { return };
    let m = DeqModel::new(&eng, "tiny").unwrap();
    let d = m.v.fixed_point_dim;
    let mut rng = Rng::new(6);
    let mm = 30usize;
    let v32 = randv(&mut rng, d, 1.0);
    let us = randv(&mut rng, mm * d, 0.3);
    let vs = randv(&mut rng, mm * d, 0.3);
    let got = m.lowrank_apply(&v32, &us, &vs).unwrap();
    // Rust-native: H = I + Σ uᵢ vᵢᵀ applied to v.
    use shine::qn::{low_rank::LowRank, InvOp, MemoryPolicy};
    let mut lr = LowRank::identity(d, mm, MemoryPolicy::Freeze);
    for i in 0..mm {
        let u64s: Vec<f64> = us[i * d..(i + 1) * d].iter().map(|&x| x as f64).collect();
        let v64s: Vec<f64> = vs[i * d..(i + 1) * d].iter().map(|&x| x as f64).collect();
        lr.push(&u64s, &v64s);
    }
    let v64: Vec<f64> = v32.iter().map(|&x| x as f64).collect();
    let want = lr.apply_vec(&v64);
    for (i, (a, b)) in got.iter().zip(&want).enumerate() {
        assert!(
            (*a as f64 - b).abs() < 1e-2 * (1.0 + b.abs()),
            "idx {i}: {a} vs {b}"
        );
    }
}

#[test]
fn pretrain_step_reduces_loss() {
    let Some(eng) = engine() else { return };
    let cfg = TrainerConfig {
        variant: "tiny".into(),
        lr: 5e-3,
        total_steps: 100_000, // effectively constant LR for this check
        seed: 7,
        ..Default::default()
    };
    let mut tr = Trainer::new(&eng, cfg).unwrap();
    let v = tr.model.v.clone();
    let ds = synth_images(v.batch * 4, v.h, v.w, v.c_in, v.n_classes, 0.3, 11);
    let mut rng = Rng::new(1);
    let batches = ds.epoch_batches(v.batch, &mut rng);
    let (x, labels) = ds.batch(&batches[0]);
    let first = tr.pretrain_step(&x, &labels).unwrap();
    let mut last = first;
    for _ in 0..30 {
        last = tr.pretrain_step(&x, &labels).unwrap();
    }
    assert!(
        last < first * 0.9,
        "pretraining did not reduce loss: {first} -> {last}"
    );
}

#[test]
fn train_step_runs_for_every_strategy() {
    let Some(eng) = engine() else { return };
    let strategies = [
        BackwardKind::Original {
            tol: 1e-6,
            max_iters: 30,
        },
        BackwardKind::JacobianFree,
        BackwardKind::Shine,
        BackwardKind::ShineFallback { ratio: 1.3 },
        BackwardKind::ShineRefine { iters: 3 },
        BackwardKind::JacobianFreeRefine { iters: 3 },
        BackwardKind::AdjointBroyden { opa_freq: None },
    ];
    for bk in strategies {
        let cfg = TrainerConfig {
            variant: "tiny".into(),
            backward: bk,
            fwd_max_iters: 12,
            seed: 3,
            ..Default::default()
        };
        let mut tr = Trainer::new(&eng, cfg).unwrap();
        let v = tr.model.v.clone();
        let ds = synth_images(v.batch * 2, v.h, v.w, v.c_in, v.n_classes, 0.3, 5);
        let mut rng = Rng::new(2);
        let batches = ds.epoch_batches(v.batch, &mut rng);
        let (x, labels) = ds.batch(&batches[0]);
        let s1 = tr.train_step(&x, &labels).unwrap();
        let s2 = tr.train_step(&x, &labels).unwrap();
        assert!(s1.loss.is_finite() && s2.loss.is_finite(), "{bk:?}");
        assert!(s1.fwd_iters > 0, "{bk:?}");
        // Training on the same batch twice must reduce (or at least not
        // explode) the loss.
        assert!(
            s2.loss < s1.loss * 1.5,
            "{bk:?}: loss {0} -> {1}",
            s1.loss,
            s2.loss
        );
    }
}

#[test]
fn shine_backward_is_cheaper_than_original() {
    let Some(eng) = engine() else { return };
    let mk = |bk| TrainerConfig {
        variant: "tiny".into(),
        backward: bk,
        fwd_max_iters: 15,
        seed: 9,
        ..Default::default()
    };
    let ds = synth_images(8, 8, 8, 3, 4, 0.3, 5);
    let run = |cfg: TrainerConfig| -> shine::deq::trainer::StepStats {
        let mut tr = Trainer::new(&eng, cfg).unwrap();
        let v = tr.model.v.clone();
        let mut rng = Rng::new(2);
        let batches = ds.epoch_batches(v.batch, &mut rng);
        let (x, labels) = ds.batch(&batches[0]);
        tr.train_step(&x, &labels).unwrap()
    };
    let orig = run(mk(BackwardKind::Original {
        tol: 1e-8,
        max_iters: 50,
    }));
    let shine = run(mk(BackwardKind::Shine));
    assert!(orig.bwd_matvecs > 0);
    assert_eq!(shine.bwd_matvecs, 0);
}

#[test]
fn engine_rejects_bad_shapes() {
    let Some(eng) = engine() else { return };
    let bad = vec![Tensor::new(vec![3], vec![0.0; 3])];
    assert!(eng.call("tiny_inject", &bad).is_err());
    assert!(eng.call("no_such_artifact", &[]).is_err());
}
