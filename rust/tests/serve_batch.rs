//! Batched-vs-sequential parity for the serving solvers (ISSUE 4 satellite):
//! `picard_solve_batch` / `anderson_solve_batch` on B random per-column
//! problems must agree **column-for-column** with B independent
//! `picard_solve` / `anderson_solve_ws` runs — bit-identical iterates,
//! residuals and iteration counts — in both storage precisions (the
//! Anderson pair shares its literal iteration body, so any drift between
//! the two paths is a real regression). Plus an end-to-end check that the
//! scheduler + engine pipeline serves the same answers a per-request
//! server would.

use shine::linalg::vecops::Elem;
use shine::qn::workspace::Workspace;
use shine::qn::InvOp;
use shine::serve::{EngineConfig, ServeEngine, SynthDeq};
use shine::solvers::fixed_point::{
    anderson_solve_batch, anderson_solve_ws, picard_solve, picard_solve_batch, ColStats,
};
use shine::solvers::session::SolverSpec;
use shine::util::rng::Rng;

/// Per-column linear contractive map with per-column factor and shift:
/// g(z)[i] = z[i] − c·z[(i+1) mod d] − b[i], in any storage precision.
fn col_g<E: Elem>(c: f64, b: &[E], z: &[E], out: &mut [E]) {
    let d = z.len();
    for i in 0..d {
        out[i] = E::from_f64(z[i].to_f64() - c * z[(i + 1) % d].to_f64() - b[i].to_f64());
    }
}

/// Random per-column problem set: factors spread over [0.15, 0.55] so
/// columns retire at genuinely different iterations (exercising the
/// swap-to-back compaction), plus random shifts and initial iterates.
struct Problems<E: Elem> {
    d: usize,
    cs: Vec<f64>,
    bs: Vec<Vec<E>>,
    z0s: Vec<Vec<E>>,
}

impl<E: Elem> Problems<E> {
    fn new(d: usize, nb: usize, seed: u64) -> Problems<E> {
        let mut rng = Rng::new(seed);
        let cs = (0..nb).map(|j| 0.15 + 0.4 * j as f64 / nb as f64).collect();
        let bs = (0..nb)
            .map(|_| (0..d).map(|_| E::from_f64(rng.normal())).collect())
            .collect();
        let z0s = (0..nb)
            .map(|_| (0..d).map(|_| E::from_f64(rng.normal() * 0.5)).collect())
            .collect();
        Problems { d, cs, bs, z0s }
    }

    fn pack_z0(&self) -> Vec<E> {
        let mut zs = Vec::with_capacity(self.bs.len() * self.d);
        for z0 in &self.z0s {
            zs.extend_from_slice(z0);
        }
        zs
    }

    fn batch_g(&self) -> impl FnMut(&[E], &[usize], &mut [E]) + '_ {
        let d = self.d;
        move |block: &[E], ids: &[usize], out: &mut [E]| {
            for (p, &id) in ids.iter().enumerate() {
                col_g(
                    self.cs[id],
                    &self.bs[id],
                    &block[p * d..(p + 1) * d],
                    &mut out[p * d..(p + 1) * d],
                );
            }
        }
    }
}

fn picard_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 20;
    let nb = 6;
    let (tau, max_iters) = (1.0, 400);
    let p: Problems<E> = Problems::new(d, nb, seed);
    let mut zs = p.pack_z0();
    let mut stats = vec![ColStats::default(); nb];
    let mut ws: Workspace<E> = Workspace::new();
    picard_solve_batch(p.batch_g(), &mut zs, d, tau, tol, max_iters, &mut ws, &mut stats);
    for j in 0..nb {
        let (z, rn, it) = picard_solve(
            |z: &[E], out: &mut [E]| col_g(p.cs[j], &p.bs[j], z, out),
            &p.z0s[j],
            tau,
            tol,
            max_iters,
        );
        assert!(zs[j * d..(j + 1) * d] == z[..], "col {j}: iterate mismatch");
        assert_eq!(stats[j].iters, it, "col {j}: iteration count");
        assert_eq!(stats[j].residual, rn, "col {j}: residual bits");
        assert!(stats[j].converged, "col {j} must converge");
    }
}

fn anderson_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 16;
    let nb = 5;
    let m = 4;
    let (beta, max_iters) = (1.0, 250);
    let p: Problems<E> = Problems::new(d, nb, seed);
    let mut zs = p.pack_z0();
    let mut stats = vec![ColStats::default(); nb];
    let mut ws: Workspace<E> = Workspace::new();
    anderson_solve_batch(
        p.batch_g(),
        &mut zs,
        d,
        m,
        beta,
        tol,
        max_iters,
        &mut ws,
        &mut stats,
    );
    let mut seq_ws: Workspace<E> = Workspace::new();
    for j in 0..nb {
        let (z, rn, it) = anderson_solve_ws(
            |z: &[E], out: &mut [E]| col_g(p.cs[j], &p.bs[j], z, out),
            &p.z0s[j],
            m,
            tol,
            max_iters,
            beta,
            &mut seq_ws,
        );
        assert!(zs[j * d..(j + 1) * d] == z[..], "col {j}: iterate mismatch");
        assert_eq!(stats[j].iters, it, "col {j}: iteration count");
        assert_eq!(stats[j].residual, rn, "col {j}: residual bits");
        assert!(stats[j].converged, "col {j} must converge");
    }
}

#[test]
fn picard_batch_parity_f64() {
    for seed in [1u64, 2, 3] {
        picard_parity::<f64>(seed, 1e-8);
    }
}

#[test]
fn picard_batch_parity_f32() {
    // f32 iterates floor out near machine-eps·‖z‖, so the tolerance stays
    // above that floor; the bit-parity asserts are precision-independent.
    for seed in [4u64, 5, 6] {
        picard_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn anderson_batch_parity_f64() {
    for seed in [7u64, 8, 9] {
        anderson_parity::<f64>(seed, 1e-7);
    }
}

#[test]
fn anderson_batch_parity_f32() {
    for seed in [10u64, 11, 12] {
        anderson_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn native_deq_residual_serves_through_engine() {
    // The advertised batched-DEQ-serving integration, end to end: the
    // native model's k-stacked residual (`f_theta_batch`) behind the
    // engine's batched closure, with PER-REQUEST input injections looked up
    // through the `ids` slice (each request has its own `u`, so the gather
    // must follow the compaction permutation). Parity against sequential
    // per-request Picard runs must hold column-for-column — convergence is
    // deliberately not assumed (the LN map need not contract under plain
    // Picard), only trajectory/iteration-count identity within a fixed
    // budget, which is exactly the bit-parity contract.
    use shine::deq::native::{self, NativeParams};
    use shine::runtime::manifest::VariantCfg;

    let v = VariantCfg {
        name: "tiny".into(),
        batch: 2,
        h: 4,
        w: 4,
        c_in: 3,
        patch: 2,
        c: 8,
        n_classes: 4,
        unroll: 4,
        pixels: 4,
        patch_channels: 12,
        fixed_point_dim: 2 * 4 * 8,
        param_shapes: vec![],
        f_param_names: vec![],
    };
    let c = v.c;
    let d = v.fixed_point_dim;
    let b = 4usize;
    let mut rng = Rng::new(99);
    let w1: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
    let w2: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
    let b1: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
    let b2: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
    let gamma = vec![1.0f32; c];
    let beta = vec![0.0f32; c];
    let np = NativeParams {
        wemb: &[],
        bemb: &[],
        w1: &w1,
        b1: &b1,
        w2: &w2,
        b2: &b2,
        gamma: &gamma,
        beta: &beta,
        whead: &[],
        bhead: &[],
    };
    // Per-request input injections — the per-request context the ids slice
    // exists for.
    let us_all: Vec<f32> = rng.normal_vec_f32(b * d, 1.0);
    let mut us_gather = vec![0.0f32; b * d];
    let g_batch = |block: &[f32], ids: &[usize], out: &mut [f32]| {
        let k = ids.len();
        for (p, &id) in ids.iter().enumerate() {
            us_gather[p * d..(p + 1) * d].copy_from_slice(&us_all[id * d..(id + 1) * d]);
        }
        let f = native::f_theta_batch(&v, &np, block, &us_gather[..k * d], k);
        for i in 0..k * d {
            out[i] = block[i] - f[i];
        }
    };
    let (tau, tol, max_iters) = (0.5, 1e-4, 8);
    let mut zs = vec![0.0f32; b * d];
    let mut stats = vec![ColStats::default(); b];
    let mut ws: Workspace<f32> = Workspace::new();
    picard_solve_batch(g_batch, &mut zs, d, tau, tol, max_iters, &mut ws, &mut stats);
    for j in 0..b {
        let uj = &us_all[j * d..(j + 1) * d];
        let (z_ref, rn, it) = picard_solve(
            |z: &[f32], out: &mut [f32]| {
                let f = native::f_theta(&v, &np, z, uj);
                for i in 0..d {
                    out[i] = z[i] - f[i];
                }
            },
            &vec![0.0f32; d],
            tau,
            tol,
            max_iters,
        );
        assert!(zs[j * d..(j + 1) * d] == z_ref[..], "request {j}: iterate mismatch");
        assert_eq!(stats[j].iters, it, "request {j}: iteration count");
        assert_eq!(stats[j].residual, rn, "request {j}: residual bits");
    }
}

#[test]
fn serving_pipeline_matches_per_request_reference() {
    // End-to-end: a calibrated engine serving a batch must hand back, per
    // request, exactly the fixed point a sequential Picard solve finds and
    // exactly Hᵀ·dz for the shared calibration estimate H.
    let d = 96;
    let b = 6;
    let model: SynthDeq<f32> = SynthDeq::new(d, 16, 42);
    let mut engine: ServeEngine<f32> = ServeEngine::new(
        d,
        EngineConfig {
            max_batch: b,
            solver: SolverSpec::picard(1.0).with_tol(1e-5).with_max_iters(200),
            calib: SolverSpec::broyden(20).with_tol(1e-5).with_max_iters(40),
            fallback_ratio: None,
            recalib: None,
        },
    );
    engine.calibrate(
        |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
        &vec![0.0f32; d],
    );
    let mut rng = Rng::new(13);
    let z0s: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec_f32(d, 0.5)).collect();
    let cots: Vec<f32> = rng.normal_vec_f32(b * d, 1.0);
    let mut zs: Vec<f32> = Vec::new();
    for z0 in &z0s {
        zs.extend_from_slice(z0);
    }
    let mut w = vec![0.0f32; b * d];
    let mut stats = vec![ColStats::default(); b];
    let rep = engine.process(
        |block: &[f32], _ids: &[usize], out: &mut [f32]| {
            model.residual_batch(block, block.len() / d, out)
        },
        &mut zs,
        &cots,
        &mut w,
        &mut stats,
    );
    assert!(rep.all_converged);
    assert_eq!(rep.batch, b);
    let h = engine.estimate().expect("calibrated");
    for j in 0..b {
        let (z_ref, _, it) = picard_solve(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &z0s[j],
            1.0,
            1e-5,
            200,
        );
        assert!(zs[j * d..(j + 1) * d] == z_ref[..], "request {j}: fixed point");
        assert_eq!(stats[j].iters, it, "request {j}: iterations");
        let w_ref = h.apply_t_vec(&cots[j * d..(j + 1) * d]);
        assert!(w[j * d..(j + 1) * d] == w_ref[..], "request {j}: backward");
    }
}
