//! Batched-vs-sequential parity for the serving solvers (ISSUE 4 satellite):
//! `picard_solve_batch` / `anderson_solve_batch` on B random per-column
//! problems must agree **column-for-column** with B independent
//! `picard_solve` / `anderson_solve_ws` runs — bit-identical iterates,
//! residuals and iteration counts — in both storage precisions (the
//! Anderson pair shares its literal iteration body, so any drift between
//! the two paths is a real regression). Plus an end-to-end check that the
//! scheduler + engine pipeline serves the same answers a per-request
//! server would.

use shine::linalg::vecops::Elem;
use shine::qn::workspace::Workspace;
use shine::qn::InvOp;
use shine::serve::{Admission, EngineConfig, ServeEngine, SynthDeq};
use shine::solvers::fixed_point::{
    anderson_solve_batch, anderson_solve_ws, picard_solve, picard_solve_batch, ColStats,
};
use shine::solvers::session::SolverSpec;
use shine::util::rng::Rng;

/// Per-column linear contractive map with per-column factor and shift:
/// g(z)[i] = z[i] − c·z[(i+1) mod d] − b[i], in any storage precision.
fn col_g<E: Elem>(c: f64, b: &[E], z: &[E], out: &mut [E]) {
    let d = z.len();
    for i in 0..d {
        out[i] = E::from_f64(z[i].to_f64() - c * z[(i + 1) % d].to_f64() - b[i].to_f64());
    }
}

/// Random per-column problem set: factors spread over [0.15, 0.55] so
/// columns retire at genuinely different iterations (exercising the
/// swap-to-back compaction), plus random shifts and initial iterates.
struct Problems<E: Elem> {
    d: usize,
    cs: Vec<f64>,
    bs: Vec<Vec<E>>,
    z0s: Vec<Vec<E>>,
}

impl<E: Elem> Problems<E> {
    fn new(d: usize, nb: usize, seed: u64) -> Problems<E> {
        let mut rng = Rng::new(seed);
        let cs = (0..nb).map(|j| 0.15 + 0.4 * j as f64 / nb as f64).collect();
        let bs = (0..nb)
            .map(|_| (0..d).map(|_| E::from_f64(rng.normal())).collect())
            .collect();
        let z0s = (0..nb)
            .map(|_| (0..d).map(|_| E::from_f64(rng.normal() * 0.5)).collect())
            .collect();
        Problems { d, cs, bs, z0s }
    }

    fn pack_z0(&self) -> Vec<E> {
        let mut zs = Vec::with_capacity(self.bs.len() * self.d);
        for z0 in &self.z0s {
            zs.extend_from_slice(z0);
        }
        zs
    }

    fn batch_g(&self) -> impl FnMut(&[E], &[usize], &mut [E]) + '_ {
        let d = self.d;
        move |block: &[E], ids: &[usize], out: &mut [E]| {
            for (p, &id) in ids.iter().enumerate() {
                col_g(
                    self.cs[id],
                    &self.bs[id],
                    &block[p * d..(p + 1) * d],
                    &mut out[p * d..(p + 1) * d],
                );
            }
        }
    }
}

fn picard_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 20;
    let nb = 6;
    let (tau, max_iters) = (1.0, 400);
    let p: Problems<E> = Problems::new(d, nb, seed);
    let mut zs = p.pack_z0();
    let mut stats = vec![ColStats::default(); nb];
    let mut ws: Workspace<E> = Workspace::new();
    picard_solve_batch(p.batch_g(), &mut zs, d, tau, tol, max_iters, &mut ws, &mut stats);
    for j in 0..nb {
        let (z, rn, it) = picard_solve(
            |z: &[E], out: &mut [E]| col_g(p.cs[j], &p.bs[j], z, out),
            &p.z0s[j],
            tau,
            tol,
            max_iters,
        );
        assert!(zs[j * d..(j + 1) * d] == z[..], "col {j}: iterate mismatch");
        assert_eq!(stats[j].iters, it, "col {j}: iteration count");
        assert_eq!(stats[j].residual, rn, "col {j}: residual bits");
        assert!(stats[j].converged, "col {j} must converge");
    }
}

fn anderson_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 16;
    let nb = 5;
    let m = 4;
    let (beta, max_iters) = (1.0, 250);
    let p: Problems<E> = Problems::new(d, nb, seed);
    let mut zs = p.pack_z0();
    let mut stats = vec![ColStats::default(); nb];
    let mut ws: Workspace<E> = Workspace::new();
    anderson_solve_batch(
        p.batch_g(),
        &mut zs,
        d,
        m,
        beta,
        tol,
        max_iters,
        &mut ws,
        &mut stats,
    );
    let mut seq_ws: Workspace<E> = Workspace::new();
    for j in 0..nb {
        let (z, rn, it) = anderson_solve_ws(
            |z: &[E], out: &mut [E]| col_g(p.cs[j], &p.bs[j], z, out),
            &p.z0s[j],
            m,
            tol,
            max_iters,
            beta,
            &mut seq_ws,
        );
        assert!(zs[j * d..(j + 1) * d] == z[..], "col {j}: iterate mismatch");
        assert_eq!(stats[j].iters, it, "col {j}: iteration count");
        assert_eq!(stats[j].residual, rn, "col {j}: residual bits");
        assert!(stats[j].converged, "col {j} must converge");
    }
}

#[test]
fn picard_batch_parity_f64() {
    for seed in [1u64, 2, 3] {
        picard_parity::<f64>(seed, 1e-8);
    }
}

#[test]
fn picard_batch_parity_f32() {
    // f32 iterates floor out near machine-eps·‖z‖, so the tolerance stays
    // above that floor; the bit-parity asserts are precision-independent.
    for seed in [4u64, 5, 6] {
        picard_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn anderson_batch_parity_f64() {
    for seed in [7u64, 8, 9] {
        anderson_parity::<f64>(seed, 1e-7);
    }
}

#[test]
fn anderson_batch_parity_f32() {
    for seed in [10u64, 11, 12] {
        anderson_parity::<f32>(seed, 1e-4);
    }
}

/// Serve every problem in `p` through [`ServeEngine::process_streaming`]
/// with a block narrower than the problem count, so later requests are
/// admitted **mid-solve** into columns freed by earlier retirements.
/// Returns the per-request retirements (by request id) and how many were
/// admitted while another column was already mid-flight.
fn run_streaming<E: Elem>(
    p: &Problems<E>,
    spec: SolverSpec,
    cap: usize,
) -> (Vec<(Vec<E>, ColStats)>, usize) {
    let nb = p.cs.len();
    let d = p.d;
    // Uncalibrated on purpose: this pins the forward trajectory (w = dz
    // identity backward); the backward contract is pinned elsewhere.
    let mut engine: ServeEngine<E> = ServeEngine::new(
        d,
        EngineConfig {
            max_batch: cap,
            solver: spec,
            calib: SolverSpec::broyden(10).with_tol(spec.tol).with_max_iters(40),
            fallback_ratio: None,
            recalib: None,
            col_budget: None,
            breaker: None,
        },
    );
    let mut next = 0usize;
    let mut midflight_admissions = 0usize;
    // Columns in flight, tracked caller-side; a Cell because both the
    // admit and the retire closure touch it.
    let live = std::cell::Cell::new(0usize);
    let mut done: Vec<Option<(Vec<E>, ColStats)>> = vec![None; nb];
    let rep = engine.process_streaming(
        p.batch_g(),
        || cap,
        |z: &mut [E], c: &mut [E]| {
            if next >= nb {
                return None;
            }
            let id = next;
            z.copy_from_slice(&p.z0s[id]);
            c.iter_mut().for_each(|x| *x = E::ZERO);
            if live.get() > 0 {
                midflight_admissions += 1;
            }
            live.set(live.get() + 1);
            next += 1;
            Some(Admission {
                id,
                budget: spec.max_iters,
            })
        },
        |id, z, _w, st, evicted| {
            assert!(!evicted, "no col_budget configured");
            live.set(live.get() - 1);
            done[id] = Some((z.to_vec(), st));
        },
    );
    assert_eq!(rep.served, nb);
    assert!(rep.all_converged);
    (
        done.into_iter().map(|s| s.expect("retired")).collect(),
        midflight_admissions,
    )
}

fn picard_streaming_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 20;
    let nb = 6;
    let p: Problems<E> = Problems::new(d, nb, seed);
    let spec = SolverSpec::picard(1.0).with_tol(tol).with_max_iters(400);
    let (done, midflight) = run_streaming(&p, spec, 2);
    // With a width-2 block and factors spread over [0.15, 0.55), columns
    // retire at different sweeps, so at least nb − 2 admissions land next
    // to a mid-flight neighbour — the case the parity below is about.
    assert!(midflight >= nb - 2, "only {midflight} mid-solve admissions");
    for (j, (z, st)) in done.iter().enumerate() {
        let (z_ref, rn, it) = picard_solve(
            |z: &[E], out: &mut [E]| col_g(p.cs[j], &p.bs[j], z, out),
            &p.z0s[j],
            1.0,
            tol,
            400,
        );
        assert!(z[..] == z_ref[..], "req {j}: iterate mismatch");
        assert_eq!(st.iters, it, "req {j}: iteration count");
        assert_eq!(st.residual, rn, "req {j}: residual bits");
        assert!(st.converged, "req {j} must converge");
    }
}

fn anderson_streaming_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 16;
    let nb = 5;
    let m = 4;
    let p: Problems<E> = Problems::new(d, nb, seed);
    let spec = SolverSpec::anderson(m, 1.0).with_tol(tol).with_max_iters(250);
    let (done, midflight) = run_streaming(&p, spec, 2);
    assert!(midflight >= nb - 2, "only {midflight} mid-solve admissions");
    let mut ws: Workspace<E> = Workspace::new();
    for (j, (z, st)) in done.iter().enumerate() {
        let (z_ref, rn, it) = anderson_solve_ws(
            |z: &[E], out: &mut [E]| col_g(p.cs[j], &p.bs[j], z, out),
            &p.z0s[j],
            m,
            tol,
            250,
            1.0,
            &mut ws,
        );
        assert!(z[..] == z_ref[..], "req {j}: iterate mismatch");
        assert_eq!(st.iters, it, "req {j}: iteration count");
        assert_eq!(st.residual, rn, "req {j}: residual bits");
        assert!(st.converged, "req {j} must converge");
    }
}

#[test]
fn picard_streaming_admission_parity_f64() {
    for seed in [31u64, 32, 33] {
        picard_streaming_parity::<f64>(seed, 1e-8);
    }
}

#[test]
fn picard_streaming_admission_parity_f32() {
    for seed in [34u64, 35, 36] {
        picard_streaming_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn anderson_streaming_admission_parity_f64() {
    for seed in [37u64, 38, 39] {
        anderson_streaming_parity::<f64>(seed, 1e-7);
    }
}

#[test]
fn anderson_streaming_admission_parity_f32() {
    for seed in [40u64, 41, 42] {
        anderson_streaming_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn streaming_admission_preserves_fifo_within_key() {
    // Streaming admission pulls from the keyed queue one request at a time
    // (KeyedScheduler::pop_front_key); admission order for the served key
    // must be exactly its FIFO push order, and the other key's queue must
    // come through untouched afterwards.
    use shine::serve::{KeyedScheduler, ModelKey, SchedulerConfig};

    let d = 20;
    let nb = 6;
    let p: Problems<f64> = Problems::new(d, nb, 55);
    let ka = ModelKey::new(0, 0);
    let kb = ModelKey::new(1, 0);
    let mut sched: KeyedScheduler<usize> = KeyedScheduler::new(SchedulerConfig {
        max_batch: 2,
        max_wait: 1e-3,
        queue_cap: 64,
    });
    // Interleave pushes: A gets ids 0..nb, B gets sentinel payloads.
    for id in 0..nb {
        sched.push(id as f64, ka, id).unwrap();
        sched.push(id as f64 + 0.5, kb, 100 + id).unwrap();
    }
    let mut engine: ServeEngine<f64> = ServeEngine::new(
        d,
        EngineConfig {
            max_batch: 2,
            solver: SolverSpec::picard(1.0).with_tol(1e-8).with_max_iters(400),
            ..Default::default()
        },
    );
    let mut admitted: Vec<usize> = Vec::new();
    let mut served: Vec<usize> = Vec::new();
    let rep = engine.process_streaming(
        p.batch_g(),
        || 2,
        |z: &mut [f64], c: &mut [f64]| {
            let (_wait, id) = sched.pop_front_key(ka, 10.0)?;
            z.copy_from_slice(&p.z0s[id]);
            c.iter_mut().for_each(|x| *x = 0.0);
            admitted.push(id);
            Some(Admission { id, budget: 400 })
        },
        |id, _z, _w, st, _evicted| {
            assert!(st.converged);
            served.push(id);
        },
    );
    assert_eq!(rep.served, nb);
    // Admission is FIFO-within-key even though retirement frees columns in
    // convergence order, not arrival order.
    assert_eq!(admitted, (0..nb).collect::<Vec<_>>());
    assert_eq!(served.len(), nb);
    // Key B's queue is untouched and still FIFO.
    assert_eq!(sched.count_key(ka), 0);
    assert_eq!(sched.count_key(kb), nb);
    let mut out = Vec::new();
    sched.drain_key(kb, nb, 10.0, &mut out);
    assert_eq!(
        out.iter().map(|(_, p)| *p).collect::<Vec<_>>(),
        (100..100 + nb).collect::<Vec<_>>()
    );
}

#[test]
fn native_deq_residual_serves_through_engine() {
    // The advertised batched-DEQ-serving integration, end to end: the
    // native model's k-stacked residual (`f_theta_batch`) behind the
    // engine's batched closure, with PER-REQUEST input injections looked up
    // through the `ids` slice (each request has its own `u`, so the gather
    // must follow the compaction permutation). Parity against sequential
    // per-request Picard runs must hold column-for-column — convergence is
    // deliberately not assumed (the LN map need not contract under plain
    // Picard), only trajectory/iteration-count identity within a fixed
    // budget, which is exactly the bit-parity contract.
    use shine::deq::native::{self, NativeParams};
    use shine::runtime::manifest::VariantCfg;

    let v = VariantCfg {
        name: "tiny".into(),
        batch: 2,
        h: 4,
        w: 4,
        c_in: 3,
        patch: 2,
        c: 8,
        n_classes: 4,
        unroll: 4,
        pixels: 4,
        patch_channels: 12,
        fixed_point_dim: 2 * 4 * 8,
        param_shapes: vec![],
        f_param_names: vec![],
    };
    let c = v.c;
    let d = v.fixed_point_dim;
    let b = 4usize;
    let mut rng = Rng::new(99);
    let w1: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
    let w2: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
    let b1: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
    let b2: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
    let gamma = vec![1.0f32; c];
    let beta = vec![0.0f32; c];
    let np = NativeParams {
        wemb: &[],
        bemb: &[],
        w1: &w1,
        b1: &b1,
        w2: &w2,
        b2: &b2,
        gamma: &gamma,
        beta: &beta,
        whead: &[],
        bhead: &[],
    };
    // Per-request input injections — the per-request context the ids slice
    // exists for.
    let us_all: Vec<f32> = rng.normal_vec_f32(b * d, 1.0);
    let mut us_gather = vec![0.0f32; b * d];
    let g_batch = |block: &[f32], ids: &[usize], out: &mut [f32]| {
        let k = ids.len();
        for (p, &id) in ids.iter().enumerate() {
            us_gather[p * d..(p + 1) * d].copy_from_slice(&us_all[id * d..(id + 1) * d]);
        }
        let f = native::f_theta_batch(&v, &np, block, &us_gather[..k * d], k);
        for i in 0..k * d {
            out[i] = block[i] - f[i];
        }
    };
    let (tau, tol, max_iters) = (0.5, 1e-4, 8);
    let mut zs = vec![0.0f32; b * d];
    let mut stats = vec![ColStats::default(); b];
    let mut ws: Workspace<f32> = Workspace::new();
    picard_solve_batch(g_batch, &mut zs, d, tau, tol, max_iters, &mut ws, &mut stats);
    for j in 0..b {
        let uj = &us_all[j * d..(j + 1) * d];
        let (z_ref, rn, it) = picard_solve(
            |z: &[f32], out: &mut [f32]| {
                let f = native::f_theta(&v, &np, z, uj);
                for i in 0..d {
                    out[i] = z[i] - f[i];
                }
            },
            &vec![0.0f32; d],
            tau,
            tol,
            max_iters,
        );
        assert!(zs[j * d..(j + 1) * d] == z_ref[..], "request {j}: iterate mismatch");
        assert_eq!(stats[j].iters, it, "request {j}: iteration count");
        assert_eq!(stats[j].residual, rn, "request {j}: residual bits");
    }
}

#[test]
fn serving_pipeline_matches_per_request_reference() {
    // End-to-end: a calibrated engine serving a batch must hand back, per
    // request, exactly the fixed point a sequential Picard solve finds and
    // exactly Hᵀ·dz for the shared calibration estimate H.
    let d = 96;
    let b = 6;
    let model: SynthDeq<f32> = SynthDeq::new(d, 16, 42);
    let mut engine: ServeEngine<f32> = ServeEngine::new(
        d,
        EngineConfig {
            max_batch: b,
            solver: SolverSpec::picard(1.0).with_tol(1e-5).with_max_iters(200),
            calib: SolverSpec::broyden(20).with_tol(1e-5).with_max_iters(40),
            fallback_ratio: None,
            recalib: None,
            col_budget: None,
            breaker: None,
        },
    );
    engine.calibrate(
        |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
        &vec![0.0f32; d],
    );
    let mut rng = Rng::new(13);
    let z0s: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec_f32(d, 0.5)).collect();
    let cots: Vec<f32> = rng.normal_vec_f32(b * d, 1.0);
    let mut zs: Vec<f32> = Vec::new();
    for z0 in &z0s {
        zs.extend_from_slice(z0);
    }
    let mut w = vec![0.0f32; b * d];
    let mut stats = vec![ColStats::default(); b];
    let rep = engine.process(
        |block: &[f32], _ids: &[usize], out: &mut [f32]| {
            model.residual_batch(block, block.len() / d, out)
        },
        &mut zs,
        &cots,
        &mut w,
        &mut stats,
    );
    assert!(rep.all_converged);
    assert_eq!(rep.batch, b);
    let h = engine.estimate().expect("calibrated");
    for j in 0..b {
        let (z_ref, _, it) = picard_solve(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &z0s[j],
            1.0,
            1e-5,
            200,
        );
        assert!(zs[j * d..(j + 1) * d] == z_ref[..], "request {j}: fixed point");
        assert_eq!(stats[j].iters, it, "request {j}: iterations");
        let w_ref = h.apply_t_vec(&cots[j * d..(j + 1) * d]);
        assert!(w[j * d..(j + 1) * d] == w_ref[..], "request {j}: backward");
    }
}
