//! Seeded fuzz harness for the HTTP edge's two parsers (ISSUE 10
//! satellite): ~20,000 deterministic cases through
//! [`shine::http::read_request`] and [`shine::http::LazyDoc`].
//!
//! The contract under test is narrow and absolute: **no input panics**,
//! and every rejection is a *typed* outcome — a 4xx [`HttpError`] from
//! the framing layer (only 400/411/413/431 exist there), a clean
//! `Closed`, or a positioned [`ScanError`] from the JSON scanner. Byte
//! soup, truncations at every prefix of valid requests, random
//! mutations, oversized bodies and header lines, 200-deep JSON nesting,
//! duplicate keys and header-injection payloads all go through the same
//! assertion. A differential cross-check pins the lazy scanner against
//! the crate's tree parser (`util::json::parse`) on generated valid
//! documents, where both must extract bit-identical numbers.
//!
//! Everything is driven by the crate's own [`Rng`], so a failure
//! reproduces from the seed printed in the assert message.

use shine::http::{read_request, HttpError, LazyDoc, RecvError, Response, DEFAULT_MAX_BODY};
use shine::util::json::{parse as tree_parse, Json};
use shine::util::rng::Rng;
use std::io::Cursor;

/// Framing-layer statuses that exist (anything else is a bug).
fn assert_typed(res: Result<shine::http::Request, RecvError>, ctx: &str) {
    match res {
        Ok(_) | Err(RecvError::Closed) | Err(RecvError::Io(_)) => {}
        Err(RecvError::Proto(HttpError { status, .. })) => {
            assert!(
                matches!(status, 400 | 411 | 413 | 431),
                "{ctx}: untyped framing status {status}"
            );
        }
    }
}

fn parse_bytes(bytes: &[u8], ctx: &str) {
    assert_typed(read_request(&mut Cursor::new(bytes), DEFAULT_MAX_BODY), ctx);
}

/// A canonical valid solve request with `n` body bytes of JSON payload.
fn valid_request(body: &str) -> Vec<u8> {
    format!(
        "POST /v1/solve HTTP/1.1\r\nhost: shine\r\ncontent-type: application/json\r\n\
         content-length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

#[test]
fn fuzz_random_bytes_through_the_framing_layer() {
    // 4,000 cases of raw byte soup, half biased into printable ASCII so
    // the parser gets past the request line more often.
    let mut rng = Rng::new(0x10_F422);
    for case in 0..4_000u32 {
        let len = rng.below(700);
        let ascii = case % 2 == 0;
        let bytes: Vec<u8> = (0..len)
            .map(|_| {
                let b = (rng.next_u64() & 0xFF) as u8;
                if ascii {
                    0x20 + (b % 0x5F)
                } else {
                    b
                }
            })
            .collect();
        parse_bytes(&bytes, &format!("random case {case}"));
    }
}

#[test]
fn fuzz_every_truncation_of_valid_requests() {
    // 10 distinct valid requests x every prefix length: ~3,400 cases.
    // A truncated request must resolve as Closed (EOF on the request
    // boundary) or a typed 400 (EOF mid-frame) — never a panic or hang.
    let mut rng = Rng::new(0x10_721C);
    for doc in 0..10u32 {
        let n = 1 + rng.below(40);
        let nums: Vec<String> = (0..n)
            .map(|_| format!("{:.6}", rng.uniform_in(-10.0, 10.0)))
            .collect();
        let body = format!("{{\"model\":{doc},\"cotangent\":[{}]}}", nums.join(","));
        let req = valid_request(&body);
        // The untruncated request must parse.
        let full = read_request(&mut Cursor::new(&req), DEFAULT_MAX_BODY)
            .unwrap_or_else(|_| panic!("untruncated request {doc} must parse"));
        assert_eq!(full.method, "POST");
        assert_eq!(full.body.len(), body.len());
        for cut in 0..req.len() {
            parse_bytes(&req[..cut], &format!("doc {doc} cut {cut}"));
        }
    }
}

#[test]
fn fuzz_mutated_requests() {
    // 4,000 cases: a valid request with 1-8 random bytes overwritten.
    // Mutations can corrupt the method, the version, a header name, the
    // content-length digits or the body — all must stay typed.
    let mut rng = Rng::new(0x10_3A7);
    let base = valid_request("{\"model\":1,\"cotangent\":[1.0,2.0,3.0]}");
    for case in 0..4_000u32 {
        let mut req = base.clone();
        for _ in 0..(1 + rng.below(8)) {
            let i = rng.below(req.len());
            req[i] = (rng.next_u64() & 0xFF) as u8;
        }
        parse_bytes(&req, &format!("mutation case {case}"));
    }
}

#[test]
fn fuzz_oversized_requests_are_bounded_rejections() {
    // ~600 cases around the body and line caps: content-length past the
    // configured max_body -> 413 before any body byte is read; header /
    // request lines past the 8 KiB line bound -> 431.
    let mut rng = Rng::new(0x10_B16);
    for case in 0..300u32 {
        let cap = 64 + rng.below(512);
        let claimed = cap + 1 + rng.below(1 << 20);
        let head = format!(
            "POST /v1/solve HTTP/1.1\r\nhost: s\r\ncontent-length: {claimed}\r\n\r\n"
        );
        match read_request(&mut Cursor::new(head.as_bytes()), cap) {
            Err(RecvError::Proto(e)) => assert_eq!(e.status, 413, "case {case}"),
            other => panic!("case {case}: oversize body not rejected: {other:?}"),
        }
    }
    for case in 0..300u32 {
        let pad = 8 * 1024 + 1 + rng.below(4096);
        let line = match case % 3 {
            0 => format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(pad)),
            1 => format!("GET / HTTP/1.1\r\nx-big: {}\r\n\r\n", "y".repeat(pad)),
            _ => "z".repeat(pad),
        };
        match read_request(&mut Cursor::new(line.as_bytes()), DEFAULT_MAX_BODY) {
            Err(RecvError::Proto(e)) => {
                assert!(matches!(e.status, 431 | 400), "case {case}: {}", e.status)
            }
            other => panic!("case {case}: oversize line not rejected: {other:?}"),
        }
    }
}

#[test]
fn fuzz_header_injection_is_neutralized_both_ways() {
    let mut rng = Rng::new(0x10_145);
    // Ingress: 500 requests whose header values embed control bytes that
    // survived line splitting (lone CR, NUL, ESC...) must be typed 400s.
    for case in 0..500u32 {
        let ctl = [b'\0', b'\r', 0x01, 0x0B, 0x1B][rng.below(5)];
        let mut req = Vec::new();
        req.extend_from_slice(b"GET /healthz HTTP/1.1\r\nx-evil: a");
        req.push(ctl);
        req.extend_from_slice(b"b\r\n\r\n");
        match read_request(&mut Cursor::new(&req), DEFAULT_MAX_BODY) {
            Err(RecvError::Proto(e)) => assert_eq!(e.status, 400, "case {case}"),
            other => panic!("case {case}: ctrl byte {ctl:#x} accepted: {other:?}"),
        }
    }
    // Egress: 500 hostile header values through Response::with_header —
    // the serialized response must contain exactly one blank line and no
    // smuggled header, whatever CR/LF/NUL the value carried.
    for case in 0..500u32 {
        let mut value = String::from("ok");
        for _ in 0..(1 + rng.below(4)) {
            value.push(['\r', '\n', '\0', ';'][rng.below(4)]);
            value.push_str("evil: injected");
        }
        let mut wire = Vec::new();
        Response::json(200, "{}".to_string())
            .with_header("x-fuzz", &value)
            .write_to(&mut wire, true)
            .unwrap();
        let text = String::from_utf8_lossy(&wire);
        let head = text.split("\r\n\r\n").next().unwrap();
        assert!(
            !head.lines().any(|l| l.starts_with("evil:")),
            "case {case}: smuggled header in {head:?}"
        );
        assert!(!text.contains('\0'), "case {case}: NUL on the wire");
    }
}

#[test]
fn fuzz_json_scanner_soup_nesting_and_duplicates() {
    // 6,000 cases through every LazyDoc entry point: random soup,
    // structured mutations, deep nesting past MAX_DEPTH (a typed
    // ScanError, not a stack overflow), duplicate keys (first match
    // wins), and oversized arrays against f64_vec_at's bound.
    let mut rng = Rng::new(0x10_D0C);
    for case in 0..4_000u32 {
        let bytes: Vec<u8> = if case % 2 == 0 {
            (0..rng.below(300)).map(|_| (rng.next_u64() & 0xFF) as u8).collect()
        } else {
            let mut b = format!(
                "{{\"model\":{},\"cotangent\":[{:.4},{:.4}],\"z0\":null}}",
                rng.below(9),
                rng.uniform(),
                rng.uniform()
            )
            .into_bytes();
            for _ in 0..(1 + rng.below(6)) {
                let i = rng.below(b.len());
                b[i] = (rng.next_u64() & 0xFF) as u8;
            }
            b
        };
        let doc = LazyDoc::new(&bytes);
        let _ = doc.validate();
        let _ = doc.path(&["model"]);
        let _ = doc.f64_at(&["cotangent"]);
        let _ = doc.u32_at(&["model"]);
        let _ = doc.str_at(&["z0"]);
        let _ = doc.f64_vec_at(&["cotangent"], 16);
    }
    // Nesting: every depth from shallow to far past MAX_DEPTH, both pure
    // arrays and alternating object/array chains. 1,000 cases.
    for depth in 1..=500usize {
        let arr = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        let d = LazyDoc::new(arr.as_bytes());
        if depth <= shine::http::MAX_DEPTH {
            d.validate().unwrap_or_else(|e| panic!("depth {depth}: {e}"));
        } else {
            assert!(d.validate().is_err(), "depth {depth} accepted");
        }
        let obj = format!("{}1{}", "{\"k\":".repeat(depth), "}".repeat(depth));
        let d = LazyDoc::new(obj.as_bytes());
        if depth <= shine::http::MAX_DEPTH {
            d.validate().unwrap_or_else(|e| panic!("obj depth {depth}: {e}"));
            assert_eq!(
                d.f64_at(&(0..depth).map(|_| "k").collect::<Vec<_>>()).unwrap(),
                Some(1.0),
                "obj depth {depth} path walk"
            );
        } else {
            assert!(d.validate().is_err(), "obj depth {depth} accepted");
        }
    }
    // Duplicate keys: the scanner documents first-match-wins; 1,000
    // seeded duplicate layouts must return the first binding.
    for case in 0..1_000u32 {
        let first = rng.below(1000) as f64;
        let second = first + 1.0;
        let pad = "\"x\":0,".repeat(rng.below(4));
        let doc = format!("{{{pad}\"k\":{first},\"k\":{second}}}");
        let d = LazyDoc::new(doc.as_bytes());
        assert_eq!(
            d.f64_at(&["k"]).unwrap(),
            Some(first),
            "case {case}: duplicate key not first-match"
        );
    }
}

#[test]
fn differential_scanner_vs_tree_parser() {
    // 2,000 generated valid documents (unique keys, depth <= 3): the lazy
    // scanner and the crate's tree parser must agree bit-for-bit on every
    // extracted number and string. Numbers are emitted through write_num
    // (shortest round-trip), so "agree" means exact equality.
    let mut rng = Rng::new(0x10_D1FF);
    for case in 0..2_000u32 {
        let x = match case % 4 {
            0 => rng.normal_ms(0.0, 1e6),
            1 => rng.uniform_in(-1.0, 1.0),
            2 => (rng.next_u64() % 1_000_000) as f64,
            _ => rng.normal() * 1e-12,
        };
        let n = 1 + rng.below(8);
        let arr: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let body = shine::http::JsonBuilder::obj()
            .num("x", x)
            .nums("arr", arr.iter().copied())
            .raw("inner", &shine::http::JsonBuilder::obj().num("y", x * 0.5).finish())
            .text("s", &format!("case-{case}"))
            .finish();

        let d = LazyDoc::new(body.as_bytes());
        d.validate().unwrap_or_else(|e| panic!("case {case}: generated doc invalid: {e}"));
        let tree = tree_parse(&body).unwrap_or_else(|e| panic!("case {case}: {e:?}"));
        let Json::Obj(map) = &tree else { panic!("case {case}: not an object") };

        let tree_x = match map.get("x") {
            Some(Json::Num(v)) => *v,
            other => panic!("case {case}: x = {other:?}"),
        };
        assert_eq!(
            d.f64_at(&["x"]).unwrap().unwrap().to_bits(),
            tree_x.to_bits(),
            "case {case}: x disagrees"
        );
        let tree_y = match map.get("inner") {
            Some(Json::Obj(inner)) => match inner.get("y") {
                Some(Json::Num(v)) => *v,
                other => panic!("case {case}: y = {other:?}"),
            },
            other => panic!("case {case}: inner = {other:?}"),
        };
        assert_eq!(
            d.f64_at(&["inner", "y"]).unwrap().unwrap().to_bits(),
            tree_y.to_bits(),
            "case {case}: nested y disagrees"
        );
        let scan_arr = d.f64_vec_at(&["arr"], n).unwrap().unwrap();
        let tree_arr: Vec<f64> = match map.get("arr") {
            Some(Json::Arr(xs)) => xs
                .iter()
                .map(|v| match v {
                    Json::Num(x) => *x,
                    other => panic!("case {case}: arr elem {other:?}"),
                })
                .collect(),
            other => panic!("case {case}: arr = {other:?}"),
        };
        assert_eq!(scan_arr.len(), tree_arr.len(), "case {case}");
        for (a, b) in scan_arr.iter().zip(&tree_arr) {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case}: arr elem disagrees");
        }
        assert_eq!(
            d.str_at(&["s"]).unwrap().as_deref(),
            Some(format!("case-{case}").as_str()),
            "case {case}: string disagrees"
        );
    }
}
