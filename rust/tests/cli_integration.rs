//! CLI integration: exercise the `shine` binary end-to-end through
//! std::process (list, version, quick experiments, error paths).

use std::process::Command;

fn shine() -> Command {
    Command::new(env!("CARGO_BIN_EXE_shine"))
}

#[test]
fn version_and_help() {
    let out = shine().arg("version").output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("shine"));
    let out = shine().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    for cmd in ["list", "run", "train", "hpo", "artifacts-check"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn list_contains_every_paper_artifact() {
    let out = shine().arg("list").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    for id in [
        "fig1",
        "fig2-left",
        "fig2-right",
        "fig-e1",
        "fig-e2",
        "fig3-cifar",
        "fig3-imagenet",
        "table-e1",
        "table-e2",
        "table-e3",
        "fig-e3",
        "e2e",
    ] {
        assert!(text.contains(id), "list missing {id}");
    }
}

#[test]
fn unknown_command_fails() {
    let out = shine().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn unknown_experiment_fails() {
    let out = shine().args(["run", "not-an-exp", "--quick"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn quick_fig2_right_runs_and_writes_json() {
    let tmp = std::env::temp_dir().join("shine_cli_test_results");
    let _ = std::fs::remove_dir_all(&tmp);
    let out = shine()
        .args([
            "run",
            "fig2-right",
            "--quick",
            "--out",
            tmp.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let json = std::fs::read_to_string(tmp.join("fig2-right.json")).unwrap();
    let parsed = shine::util::json::parse(&json).unwrap();
    assert!(parsed.at(&["prescribed", "median_cos"]).is_some());
    // The paper's qualitative claim: prescribed-direction inversion is
    // better than random-direction inversion.
    let presc = parsed
        .at(&["prescribed", "median_cos"])
        .unwrap()
        .as_f64()
        .unwrap();
    let rand = parsed
        .at(&["random", "median_cos"])
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(presc > rand, "prescribed {presc} vs random {rand}");
}

#[test]
fn hpo_subcommand_runs() {
    let out = shine()
        .args([
            "hpo",
            "--dataset",
            "news20",
            "--strategy",
            "shine",
            "--outer-iters",
            "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("final theta"));
}
