//! Sharded-router invariants (ISSUE 7 tentpole):
//!
//! 1. **Bit parity** — every request served through a [`ShardedRouter`]
//!    at 1 or 4 shards returns the bit-identical fixed point, backward
//!    answer and iteration count as the single-threaded [`Router`]
//!    serving it per-request. Shard count, batch formation and steal
//!    timing are invisible in the results.
//! 2. **FIFO-within-key under stealing** — with one hot key hammering a
//!    single shard and the other shards idle, whole-queue steals fire,
//!    and within every key the admission stamps (`seq`) still recover
//!    exact submission order.
//! 3. **Zero-downtime swap** — a mid-run version roll serves every
//!    pre-cutover request on the old snapshot's engine and every
//!    post-cutover request on the new one, then invalidates exactly the
//!    rolled key's estimate (the other model's engine survives).

use shine::serve::{
    EngineConfig, ModelKey, Router, SchedulerConfig, ShardConfig, ShardRequest, ShardedRouter,
    SharedModel, SynthDeq,
};
use shine::solvers::fixed_point::ColStats;
use shine::util::rng::Rng;
use std::sync::Arc;

const D: usize = 24;
const BLOCK: usize = 8;

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        ..Default::default()
    }
    .with_tol(1e-8)
}

fn shard_cfg(shards: usize, queue_cap: usize) -> ShardConfig {
    ShardConfig::new(
        shards,
        engine_cfg(),
        SchedulerConfig {
            max_batch: 4,
            max_wait: 1e-4,
            queue_cap,
        },
    )
}

fn model_seed(m: u32, v: u32) -> u64 {
    100 * (m as u64 + 1) + v as u64
}

fn mk_model(m: u32, v: u32) -> SharedModel<f32> {
    Arc::new(SynthDeq::<f32>::new(D, BLOCK, model_seed(m, v)))
}

/// Deterministic per-request cotangents, independent of shard count.
fn cotangents(n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(42);
    (0..n)
        .map(|_| (0..D).map(|_| rng.normal() as f32).collect())
        .collect()
}

/// Serve `reqs` (request id → model id) through a fresh sharded router and
/// return per-id `(z, w, stats)` in id order.
fn run_sharded(
    shards: usize,
    reqs: &[u32],
    cots: &[Vec<f32>],
) -> Vec<(Vec<f32>, Vec<f32>, ColStats)> {
    let router: ShardedRouter<f32> = ShardedRouter::new(shard_cfg(shards, reqs.len().max(4)));
    let mut models: Vec<u32> = reqs.to_vec();
    models.sort_unstable();
    models.dedup();
    for &m in &models {
        router.register(ModelKey::new(m, 0), mk_model(m, 0));
    }
    for (id, &m) in reqs.iter().enumerate() {
        router
            .submit(m, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("queue sized for the whole run");
    }
    let mut out = router.collect(reqs.len());
    assert_eq!(out.len(), reqs.len());
    assert!(out.iter().all(|r| r.ok()), "fault-free run has no typed failures");
    out.sort_by_key(|r| r.id);
    let res = out.into_iter().map(|r| (r.z, r.w, r.stats)).collect();
    router.shutdown();
    res
}

/// Reference: the single-threaded Router serving each request alone
/// (batch = 1) — the baseline every sharded configuration must match bit
/// for bit.
fn run_reference(reqs: &[u32], cots: &[Vec<f32>]) -> Vec<(Vec<f32>, Vec<f32>, ColStats)> {
    let mut router: Router<f32> = Router::new(engine_cfg());
    let mut models: Vec<u32> = reqs.to_vec();
    models.sort_unstable();
    models.dedup();
    for &m in &models {
        router.register(
            ModelKey::new(m, 0),
            Box::new(SynthDeq::<f32>::new(D, BLOCK, model_seed(m, 0))),
        );
    }
    reqs.iter()
        .enumerate()
        .map(|(id, &m)| {
            let mut z = vec![0.0f32; D];
            let mut w = vec![0.0f32; D];
            let mut stats = [ColStats::default()];
            router
                .process(ModelKey::new(m, 0), &mut z, &cots[id], &mut w, &mut stats)
                .expect("registered");
            (z, w, stats[0])
        })
        .collect()
}

#[test]
fn sharded_results_are_bit_identical_to_single_threaded_router() {
    // 24 requests over 3 models, interleaved so sharded batches mix
    // cohorts of different sizes.
    let reqs: Vec<u32> = (0..24u32).map(|i| i % 3).collect();
    let cots = cotangents(reqs.len());
    let reference = run_reference(&reqs, &cots);
    for shards in [1usize, 4] {
        let got = run_sharded(shards, &reqs, &cots);
        for (id, ((gz, gw, gs), (rz, rw, rs))) in got.iter().zip(reference.iter()).enumerate() {
            assert!(gs.converged, "request {id} converged ({shards} shards)");
            assert_eq!(
                gz.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                rz.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "forward bits, request {id}, {shards} shards"
            );
            assert_eq!(
                gw.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                rw.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "backward bits, request {id}, {shards} shards"
            );
            assert_eq!(gs.iters, rs.iters, "iteration count, request {id}");
            assert_eq!(gs.converged, rs.converged);
        }
    }
}

#[test]
fn fifo_within_key_survives_work_stealing() {
    // One hot model floods its affinity shard while three cold models
    // trickle: the idle shards must steal the hot key's queue (whole-queue
    // moves), and per-key submission order must still be recoverable from
    // the admission stamps.
    let mut reqs: Vec<u32> = Vec::new();
    for i in 0..128u32 {
        // 3 of 4 requests hit model 0; the rest rotate the cold models.
        reqs.push(if i % 4 == 3 { 1 + (i / 4) % 3 } else { 0 });
    }
    let cots = cotangents(reqs.len());
    let router: ShardedRouter<f32> = ShardedRouter::new(shard_cfg(4, reqs.len()));
    let mut models: Vec<u32> = reqs.clone();
    models.sort_unstable();
    models.dedup();
    for &m in &models {
        router.register(ModelKey::new(m, 0), mk_model(m, 0));
    }
    // Per-key submission order = increasing request id.
    for (id, &m) in reqs.iter().enumerate() {
        router
            .submit(m, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("queue sized for the whole run");
    }
    let responses = router.collect(reqs.len());
    assert_eq!(responses.len(), reqs.len());
    for &m in &models {
        let key = ModelKey::new(m, 0);
        let mut of_key: Vec<_> = responses.iter().filter(|r| r.key == key).collect();
        let expected: Vec<usize> = reqs
            .iter()
            .enumerate()
            .filter(|&(_, &rm)| rm == m)
            .map(|(id, _)| id)
            .collect();
        assert_eq!(of_key.len(), expected.len(), "key {key} served everything");
        of_key.sort_by_key(|r| r.seq);
        let admitted: Vec<usize> = of_key.iter().map(|r| r.id).collect();
        assert_eq!(
            admitted, expected,
            "admission stamps of {key} recover submission order"
        );
    }
    // The hot key's backlog must actually have moved between shards at
    // least once: 96 requests against a 4-wide batch on one shard, with
    // three mostly-idle shards polling every 200 µs, cannot drain before
    // an idle worker probes it.
    assert!(
        router.total_steals() >= 1,
        "expected at least one whole-queue steal (got {})",
        router.total_steals()
    );
    // Stolen or not, the hot traffic stayed hot: served counts add up.
    let served: usize = router.shard_stats().iter().map(|s| s.served).sum();
    assert_eq!(served, reqs.len());
    router.shutdown();
}

#[test]
fn live_swap_serves_old_then_new_and_invalidates_exactly_one_key() {
    let old_key = ModelKey::new(0, 0);
    let new_key = ModelKey::new(0, 1);
    let other_key = ModelKey::new(1, 0);
    // Stealing off: placement stays pinned, so the calibration count below
    // is exact (the swap protocol itself is steal-agnostic).
    let mut cfg = shard_cfg(2, 64);
    cfg.steal = false;
    let router: ShardedRouter<f32> = ShardedRouter::new(cfg);
    router.register(old_key, mk_model(0, 0));
    router.register(other_key, mk_model(1, 0));
    let cots = cotangents(24);
    let submit = |id: usize, m: u32| -> ModelKey {
        router
            .submit(m, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("routed")
    };
    // Phase 1: pre-swap traffic on both models.
    for id in 0..8 {
        let k = submit(id, (id % 2) as u32);
        if id % 2 == 0 {
            assert_eq!(k, old_key, "pre-swap model-0 traffic routes to v0");
        }
    }
    // Roll model 0. The old version keeps serving anything queued; once
    // the background calibration finishes the route cuts over atomically.
    router.swap(new_key, mk_model(0, 1));
    router.wait_live(new_key);
    assert_eq!(router.live_version(0), Some(1));
    // Phase 2: post-cutover traffic must route to the new version.
    for id in 8..16 {
        let k = submit(id, (id % 2) as u32);
        if id % 2 == 0 {
            assert_eq!(k, new_key, "post-cutover model-0 traffic routes to v1");
        }
    }
    let responses = router.collect(16);
    assert_eq!(responses.len(), 16);
    // Every request converged and served on the engine its submission was
    // routed to; with z0 = 0 each version has ONE fixed point, so the two
    // sides of the cutover are distinguishable by their bits.
    let z_of = |key: ModelKey| -> Vec<u32> {
        responses
            .iter()
            .find(|r| r.key == key)
            .unwrap_or_else(|| panic!("{key} served requests"))
            .z
            .iter()
            .map(|x| x.to_bits())
            .collect()
    };
    assert!(responses.iter().all(|r| r.stats.converged));
    let (z_old, z_new) = (z_of(old_key), z_of(new_key));
    assert_ne!(z_old, z_new, "the roll changed the parameters");
    for r in &responses {
        if r.key == old_key {
            assert_eq!(z_old, r.z.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        }
        if r.key == new_key {
            assert_eq!(z_new, r.z.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        }
    }
    let old_served = responses.iter().filter(|r| r.key == old_key).count();
    let new_served = responses.iter().filter(|r| r.key == new_key).count();
    assert_eq!(old_served, 4, "all pre-swap model-0 requests on the old engine");
    assert_eq!(new_served, 4, "all post-cutover model-0 requests on the new engine");
    assert_eq!(
        responses.iter().filter(|r| r.key == other_key).count(),
        8,
        "the other model is untouched by the roll"
    );
    // The retired key's engine (and its calibration estimate) is collected
    // once its queue drains — and ONLY that key's. GC runs on the owning
    // shard's idle path, so poll briefly.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let stats = router.shard_stats();
        let old_alive = stats.iter().any(|s| s.engine_keys.contains(&old_key));
        let new_alive = stats.iter().any(|s| s.engine_keys.contains(&new_key));
        let other_alive = stats.iter().any(|s| s.engine_keys.contains(&other_key));
        if !old_alive {
            assert!(new_alive, "the new version's estimate survives");
            assert!(other_alive, "the other model's estimate survives");
            // Exactly three calibrations ever ran: two registrations plus
            // the background calibration of the roll. The cutover itself
            // re-used the rolled-in estimate — nothing was recomputed.
            let calibrations: usize = stats.iter().map(|s| s.calibrations).sum();
            assert_eq!(calibrations, 3);
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "retired engine was never garbage-collected"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    router.shutdown();
}
