//! HTTP front-end invariants (ISSUE 10 tentpole):
//!
//! 1. **Wire-format bit parity** — a `POST /v1/solve` over loopback TCP
//!    returns the bit-identical fixed point, backward answer, iteration
//!    count and residual as the in-process single-threaded [`Router`]
//!    serving the same request. JSON (de)serialization, the gateway's
//!    f64 wire boundary and the network layer are invisible in the
//!    results — pinned for both the `f64` and `f32` state precisions
//!    (shortest-round-trip number formatting makes this exact, see
//!    ADR-005).
//! 2. **Typed status mapping end-to-end** — malformed bodies, unknown
//!    models, wrong methods/paths, oversized bodies/headers, expired
//!    deadlines and shed connections each surface as their one canonical
//!    status over a real socket, with machine-readable error tokens.
//! 3. **Telemetry surfaces** — `/healthz` and `/metrics` expose the
//!    supervision, breaker, staleness and admission counters the
//!    acceptance criteria name, and keep-alive connections are actually
//!    reused (one accepted connection serves many requests).

use shine::http::{
    Gateway, HttpClient, HttpConfig, HttpServer, JsonBuilder, LazyDoc, SolveBackend,
};
use shine::linalg::vecops::Elem;
use shine::serve::{
    EngineConfig, ModelKey, RetryPolicy, Router, SchedulerConfig, ShardConfig, ShardedRouter,
    SynthDeq,
};
use shine::solvers::fixed_point::ColStats;
use shine::util::rng::Rng;
use std::sync::Arc;

const D: usize = 24;
const BLOCK: usize = 8;
const MODEL_SEED: u64 = 4242;

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        ..Default::default()
    }
    .with_tol(1e-8)
}

fn shard_cfg(queue_cap: usize) -> ShardConfig {
    ShardConfig::new(
        1,
        engine_cfg(),
        SchedulerConfig {
            max_batch: 4,
            max_wait: 1e-4,
            queue_cap,
        },
    )
}

/// Boot router + gateway + server on an ephemeral loopback port and hand
/// back the pieces. The returned server must outlive the last request;
/// the gateway Arc keeps the router alive underneath it.
fn boot<E: Elem, EU: Elem, EV: Elem>(
    queue_cap: usize,
    http: HttpConfig,
) -> (Arc<Gateway<E, EU, EV>>, HttpServer, HttpClient) {
    let router: ShardedRouter<E, EU, EV> = ShardedRouter::new(shard_cfg(queue_cap));
    assert!(router.register(
        ModelKey::new(0, 0),
        Arc::new(SynthDeq::<E>::new(D, BLOCK, MODEL_SEED)),
    ));
    let gateway = Arc::new(Gateway::new(router, D, RetryPolicy::none()));
    let backend: Arc<dyn SolveBackend> = gateway.clone();
    let server = HttpServer::bind(backend, "127.0.0.1:0", http).expect("bind loopback");
    let client = HttpClient::connect(server.local_addr()).expect("connect loopback");
    (gateway, server, client)
}

/// Deterministic per-request cotangents (same idiom as serve_shard.rs).
fn cotangents(n: usize) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(42);
    (0..n).map(|_| rng.normal_vec(D)).collect()
}

fn solve_body(cot: &[f64]) -> String {
    JsonBuilder::obj()
        .uint("model", 0)
        .nums("cotangent", cot.iter().copied())
        .finish()
}

/// Reference: the single-threaded [`Router`] serving each request alone.
fn run_reference<E: Elem>(cots: &[Vec<f64>]) -> Vec<(Vec<E>, Vec<E>, ColStats)> {
    let mut router: Router<E> = Router::new(engine_cfg());
    router.register(
        ModelKey::new(0, 0),
        Box::new(SynthDeq::<E>::new(D, BLOCK, MODEL_SEED)),
    );
    cots.iter()
        .map(|cot| {
            let mut z = vec![E::ZERO; D];
            let mut w = vec![E::ZERO; D];
            let cot_e: Vec<E> = cot.iter().map(|&x| E::from_f64(x)).collect();
            let mut stats = [ColStats::default()];
            router
                .process(ModelKey::new(0, 0), &mut z, &cot_e, &mut w, &mut stats)
                .expect("registered");
            (z, w, stats[0])
        })
        .collect()
}

/// The parity harness at one state precision: every value in the HTTP
/// response must parse back to the exact bits the in-process reference
/// produced. `E::from_f64(wire_f64)` is exact because the wire carries
/// shortest-round-trip decimals of values that originated in `E`.
fn assert_http_parity<E: Elem, EU: Elem, EV: Elem>() {
    let n = 6;
    let cots = cotangents(n);
    let reference = run_reference::<E>(&cots);
    let (_gw, _server, mut client) = boot::<E, EU, EV>(n.max(4), HttpConfig::default());

    for (i, cot) in cots.iter().enumerate() {
        let resp = client
            .post_json("/v1/solve", &solve_body(cot), &[])
            .expect("solve round-trip");
        assert_eq!(resp.status, 200, "request {i}: {}", resp.text());
        assert!(
            resp.header("x-shine-attempts").is_some(),
            "attempt echo header missing"
        );
        let doc = LazyDoc::new(&resp.body);
        let z = doc.f64_vec_at(&["z"], D).unwrap().expect("z present");
        let w = doc.f64_vec_at(&["w"], D).unwrap().expect("w present");
        let iters = doc.u32_at(&["iters"]).unwrap().expect("iters present");
        let residual = doc.f64_at(&["residual"]).unwrap().expect("residual present");
        assert_eq!(
            doc.path(&["converged"]).unwrap().expect("converged present"),
            b"true",
            "request {i} did not converge"
        );

        let (ref_z, ref_w, ref_stats) = &reference[i];
        assert_eq!(iters as usize, ref_stats.iters, "request {i} iters");
        assert_eq!(
            residual.to_bits(),
            ref_stats.residual.to_bits(),
            "request {i} residual bits"
        );
        for (j, (&wire, refv)) in z.iter().zip(ref_z).enumerate() {
            assert_eq!(
                E::from_f64(wire).to_f64().to_bits(),
                refv.to_f64().to_bits(),
                "request {i} z[{j}]"
            );
        }
        for (j, (&wire, refv)) in w.iter().zip(ref_w).enumerate() {
            assert_eq!(
                E::from_f64(wire).to_f64().to_bits(),
                refv.to_f64().to_bits(),
                "request {i} w[{j}]"
            );
        }
    }
}

#[test]
fn http_solve_is_bit_identical_to_in_process_f64() {
    assert_http_parity::<f64, f64, f64>();
}

#[test]
fn http_solve_is_bit_identical_to_in_process_f32() {
    assert_http_parity::<f32, f32, f32>();
}

#[test]
fn typed_status_mapping_over_the_wire() {
    let (_gw, _server, mut client) = boot::<f64, f64, f64>(8, HttpConfig::default());
    let cot = cotangents(1).remove(0);

    // Unknown model -> the submit path's 404, with the machine token.
    let resp = client
        .post_json(
            "/v1/solve",
            &JsonBuilder::obj()
                .uint("model", 7)
                .nums("cotangent", cot.iter().copied())
                .finish(),
            &[],
        )
        .unwrap();
    assert_eq!(resp.status, 404);
    assert!(resp.text().contains("unknown_model"), "{}", resp.text());

    // Malformed JSON -> 400 with the scanner's diagnosis.
    let resp = client.post_json("/v1/solve", "{\"cotangent\":[1,", &[]).unwrap();
    assert_eq!(resp.status, 400);
    assert!(resp.text().contains("error"), "{}", resp.text());

    // Wrong cotangent length -> 400 naming the model dimension.
    let resp = client
        .post_json("/v1/solve", "{\"cotangent\":[1.0,2.0]}", &[])
        .unwrap();
    assert_eq!(resp.status, 400);
    assert!(resp.text().contains("dimension"), "{}", resp.text());

    // Method / path mapping.
    let resp = client.get("/v1/solve").unwrap();
    assert_eq!(resp.status, 405);
    let resp = client.get("/nope").unwrap();
    assert_eq!(resp.status, 404);
    let resp = client
        .request("POST", "/healthz", &[], Some(b"{}"))
        .unwrap();
    assert_eq!(resp.status, 405);

    // An already-expired deadline -> the canonical 504.
    let resp = client
        .post_json(
            "/v1/solve",
            &JsonBuilder::obj()
                .uint("model", 0)
                .nums("cotangent", cot.iter().copied())
                .num("deadline_ms", 1e-6)
                .finish(),
            &[],
        )
        .unwrap();
    assert_eq!(resp.status, 504, "{}", resp.text());
    assert!(resp.text().contains("deadline_exceeded"), "{}", resp.text());
}

#[test]
fn request_bounds_are_typed_rejections_not_panics() {
    let cfg = HttpConfig {
        max_body: 256,
        ..HttpConfig::default()
    };
    let (_gw, _server, mut client) = boot::<f64, f64, f64>(8, cfg);

    // Body over the configured cap -> 413 before the body is read.
    let big = format!("{{\"cotangent\":[{}]}}", vec!["1.0"; 200].join(","));
    assert!(big.len() > 256);
    let resp = client.post_json("/v1/solve", &big, &[]).unwrap();
    assert_eq!(resp.status, 413);

    // A header line past the 8 KiB bound -> 431 (request line included).
    let huge = "x".repeat(9 * 1024);
    let resp = client
        .post_json("/v1/solve", "{}", &[("x-padding", &huge)])
        .unwrap();
    assert_eq!(resp.status, 431);

    // The connection was closed after the framing error; the client's
    // single reconnect must make the next request succeed.
    let cot = cotangents(1).remove(0);
    let resp = client.post_json("/v1/solve", &solve_body(&cot), &[]).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.text());
}

#[test]
fn admission_control_sheds_with_fast_429() {
    // A zero connection budget sheds every connection before any parse.
    let cfg = HttpConfig {
        max_connections: 0,
        ..HttpConfig::default()
    };
    let (_gw, server, mut client) = boot::<f64, f64, f64>(8, cfg);
    let cot = cotangents(1).remove(0);
    let resp = client.post_json("/v1/solve", &solve_body(&cot), &[]).unwrap();
    assert_eq!(resp.status, 429);
    assert!(resp.header("retry-after").is_some(), "shed without a hint");
    assert!(server.counters().shed() >= 1);
    // Shed before any worker or parse touched the connection.
    assert_eq!(server.counters().requests(), 0);
}

#[test]
fn healthz_and_metrics_expose_the_ledger() {
    let (gw, server, mut client) = boot::<f64, f64, f64>(8, HttpConfig::default());
    let cots = cotangents(3);
    for cot in &cots {
        let resp = client.post_json("/v1/solve", &solve_body(cot), &[]).unwrap();
        assert_eq!(resp.status, 200);
    }

    let health = client.get("/healthz").unwrap();
    assert_eq!(health.status, 200);
    let text = health.text();
    for needle in ["\"status\":\"ok\"", "\"respawns\"", "\"queue_depth\"", "\"quarantined\""] {
        assert!(text.contains(needle), "healthz missing {needle}: {text}");
    }

    let metrics = client.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    let text = metrics.text();
    for needle in [
        "shine_shard_served_total{shard=\"0\"} 3",
        "shine_shard_respawns_total",
        "shine_shard_queue_depth",
        "shine_shard_retry_after_seconds",
        "shine_key_served_total{key=\"m0v0\"}",
        "shine_key_fallback_rate{key=\"m0v0\"}",
        "shine_key_estimate_stale{key=\"m0v0\"}",
        "shine_key_breaker_state{key=\"m0v0\"} 0",
        "shine_key_quarantined{key=\"m0v0\"} 0",
        "shine_gateway_orphaned_responses_total 0",
        "shine_http_requests_total",
        "shine_http_responses_total{code=\"200\"}",
        "shine_http_admission_shed_total 0",
    ] {
        assert!(text.contains(needle), "metrics missing {needle}:\n{text}");
    }

    // Keep-alive actually reused one connection for every request above.
    assert_eq!(server.counters().accepted(), 1);
    assert!(server.counters().requests() >= 5);
    assert_eq!(gw.orphans(), 0);
}
