//! Fault-tolerance invariants (ISSUE 9 tentpole):
//!
//! 1. **Supervision / exactly-once** — an injected model panic kills the
//!    worker mid-batch; supervision respawns it, reports the in-flight
//!    batch as typed [`ServeError::WorkerLost`] casualties, and every
//!    submitted request still resolves to exactly one outcome (`collect`
//!    never hangs, nothing is duplicated). The respawned worker keeps
//!    serving subsequent rounds.
//! 2. **FIFO-within-key across a crash** — the admission stamps still
//!    recover per-key submission order on both sides of a worker death
//!    (casualties included), even when the dead shard's queues re-home.
//! 3. **Chaos parity** — under an active [`FaultPlan`] (panic + NaNs +
//!    straggler), every fault-free request that didn't share the panicked
//!    batch returns the bit-identical fixed point, backward answer and
//!    iteration count as the single-threaded [`Router`] reference; faults
//!    are confined to their victims' typed outcomes.
//! 4. **Deadlines** — an already-expired deadline bounces at admission;
//!    requests whose deadline lapses while a straggler batch occupies the
//!    worker resolve as typed [`ServeError::DeadlineExceeded`] at drain
//!    instead of being served late.
//! 5. **Per-key respawn cap / quarantine** (ISSUE 10 satellite) — a key
//!    whose model panics on every batch stops respawn-looping the shard
//!    after [`ShardConfig::quarantine_after`] attributable strikes: its
//!    queued requests resolve as typed [`ServeError::ModelFault`], new
//!    submits bounce as [`SubmitError::Quarantined`], the record is
//!    published through `quarantined_keys` / `key_metrics`, and innocent
//!    keys on the same shard keep serving.

use shine::serve::{
    EngineConfig, Fault, FaultPlan, FaultyModel, ModelKey, Router, SchedulerConfig, ServeError,
    ShardConfig, ShardRequest, ShardedRouter, SharedModel, SubmitError, SynthDeq,
};
use shine::solvers::fixed_point::ColStats;
use shine::util::rng::Rng;
use std::sync::Arc;

const D: usize = 24;
const BLOCK: usize = 8;

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        max_batch: 4,
        ..Default::default()
    }
    .with_tol(1e-8)
}

fn shard_cfg(shards: usize, queue_cap: usize) -> ShardConfig {
    ShardConfig::new(
        shards,
        engine_cfg(),
        SchedulerConfig {
            max_batch: 4,
            max_wait: 1e-4,
            queue_cap,
        },
    )
}

fn model_seed(m: u32) -> u64 {
    100 * (m as u64 + 1)
}

fn mk_model(m: u32) -> SharedModel<f32> {
    Arc::new(SynthDeq::<f32>::new(D, BLOCK, model_seed(m)))
}

/// A model executing the shared fault plan (victims keyed by request id).
fn faulty(m: u32, plan: &FaultPlan) -> SharedModel<f32> {
    Arc::new(FaultyModel::new(mk_model(m), plan.clone()))
}

/// Deterministic per-request cotangents, independent of shard count.
fn cotangents(n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(42);
    (0..n)
        .map(|_| (0..D).map(|_| rng.normal() as f32).collect())
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Reference: the single-threaded Router serving each request alone
/// (batch = 1), fault-free — the baseline the sharded chaos run's clean
/// requests must match bit for bit.
fn run_reference(reqs: &[u32], cots: &[Vec<f32>]) -> Vec<(Vec<f32>, Vec<f32>, ColStats)> {
    let mut router: Router<f32> = Router::new(engine_cfg());
    let mut models: Vec<u32> = reqs.to_vec();
    models.sort_unstable();
    models.dedup();
    for &m in &models {
        router.register(
            ModelKey::new(m, 0),
            Box::new(SynthDeq::<f32>::new(D, BLOCK, model_seed(m))),
        );
    }
    reqs.iter()
        .enumerate()
        .map(|(id, &m)| {
            let mut z = vec![0.0f32; D];
            let mut w = vec![0.0f32; D];
            let mut stats = [ColStats::default()];
            router
                .process(ModelKey::new(m, 0), &mut z, &cots[id], &mut w, &mut stats)
                .expect("registered");
            (z, w, stats[0])
        })
        .collect()
}

#[test]
fn worker_panic_respawns_and_every_request_resolves_exactly_once() {
    let total = 16;
    let plan = FaultPlan::from_faults(vec![(3, Fault::Panic)]);
    let router: ShardedRouter<f32> = ShardedRouter::new(shard_cfg(1, total));
    router.register(ModelKey::new(0, 0), faulty(0, &plan));
    let cots = cotangents(total + 4);
    for id in 0..total {
        router
            .submit(0, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("queue sized for the whole run");
    }
    // Exactly once: `collect` returns despite the crash, and the id
    // multiset is exactly the submitted set.
    let responses = router.collect(total);
    assert_eq!(responses.len(), total);
    let mut ids: Vec<usize> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..total).collect::<Vec<_>>());
    // The panic victim died with its batch; anything else either served
    // fine or was an in-flight casualty of the same batch.
    let victim = responses.iter().find(|r| r.id == 3).expect("resolved");
    assert_eq!(victim.error, Some(ServeError::WorkerLost));
    assert!(victim.z.is_empty() && victim.w.is_empty());
    for r in &responses {
        assert!(
            r.ok() || r.error == Some(ServeError::WorkerLost),
            "request {}: unexpected outcome {:?}",
            r.id,
            r.error
        );
        if r.ok() {
            assert!(r.stats.converged, "served request {} converged", r.id);
        }
    }
    let stats = &router.shard_stats()[0];
    assert!(stats.respawns >= 1, "supervision respawned the worker");
    assert_eq!(
        stats.worker_lost,
        responses.iter().filter(|r| !r.ok()).count(),
        "casualty counter matches the typed outcomes"
    );
    // The respawned worker keeps serving: a post-crash round is clean.
    for id in total..total + 4 {
        router
            .submit(0, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("respawned worker still admits");
    }
    let next = router.collect(4);
    assert_eq!(next.len(), 4);
    assert!(next.iter().all(|r| r.ok() && r.stats.converged));
    router.shutdown();
}

#[test]
fn fifo_within_key_survives_a_worker_crash() {
    // A panic mid-stream (and the queue re-homing it triggers at 2 shards):
    // per-key admission stamps must still recover submission order,
    // casualties included.
    let total = 32;
    let plan = FaultPlan::from_faults(vec![(10, Fault::Panic)]);
    let router: ShardedRouter<f32> = ShardedRouter::new(shard_cfg(2, total));
    let reqs: Vec<u32> = (0..total as u32).map(|i| i % 2).collect();
    for m in 0..2u32 {
        router.register(ModelKey::new(m, 0), faulty(m, &plan));
    }
    let cots = cotangents(total);
    for (id, &m) in reqs.iter().enumerate() {
        router
            .submit(m, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("queue sized for the whole run");
    }
    let responses = router.collect(total);
    assert_eq!(responses.len(), total);
    for m in 0..2u32 {
        let key = ModelKey::new(m, 0);
        let mut of_key: Vec<_> = responses.iter().filter(|r| r.key == key).collect();
        of_key.sort_by_key(|r| r.seq);
        let got: Vec<usize> = of_key.iter().map(|r| r.id).collect();
        let mut expected = got.clone();
        expected.sort_unstable();
        assert_eq!(
            got, expected,
            "admission stamps of {key} recover submission order across the crash"
        );
    }
    let respawns: usize = router.shard_stats().iter().map(|s| s.respawns).sum();
    assert!(respawns >= 1, "the injected panic killed a worker");
    router.shutdown();
}

#[test]
fn chaos_fault_free_requests_match_the_single_threaded_reference_bit_for_bit() {
    // Request id → model id: evens on model 0, odds on model 1. The panic
    // and one NaN land on model 1, one NaN on model 0, the straggler on
    // model 1 — so both keys see faults and both keys carry clean traffic.
    let total = 32;
    let reqs: Vec<u32> = (0..total as u32).map(|i| i % 2).collect();
    let cots = cotangents(total);
    let plan = FaultPlan::from_faults(vec![
        (3, Fault::Panic),
        (7, Fault::Nan),
        (12, Fault::Nan),
        (19, Fault::Straggle { delay_s: 2e-3 }),
    ]);
    let reference = run_reference(&reqs, &cots);
    let router: ShardedRouter<f32> = ShardedRouter::new(shard_cfg(2, total));
    for m in 0..2u32 {
        router.register(ModelKey::new(m, 0), faulty(m, &plan));
    }
    for (id, &m) in reqs.iter().enumerate() {
        router
            .submit(m, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("queue sized for the whole run");
    }
    let mut responses = router.collect(total);
    assert_eq!(responses.len(), total);
    responses.sort_by_key(|r| r.id);
    // Typed outcomes of the victims: the panic victim is always a
    // WorkerLost casualty; a NaN victim is a ModelFault unless it shared
    // the panicked batch (batch composition is timing-dependent); the
    // straggler is value-neutral and, when served, must match the
    // reference (checked below with the clean set).
    assert_eq!(responses[3].error, Some(ServeError::WorkerLost));
    assert!(
        matches!(
            responses[7].error,
            Some(ServeError::ModelFault | ServeError::WorkerLost)
        ),
        "NaN victim 7: {:?}",
        responses[7].error
    );
    // Request 12 is on model 0 — a different key than the panic — so its
    // NaN can never be masked by the crash.
    assert_eq!(responses[12].error, Some(ServeError::ModelFault));
    // Clean requests: bit parity with the fault-free single-threaded
    // reference, except in-flight casualties of the panicked batch (which
    // are typed, not silently wrong).
    let mut compared = 0usize;
    for id in plan.clean_ids(total) {
        let r = &responses[id];
        if r.error == Some(ServeError::WorkerLost) {
            assert_eq!(reqs[id], 1, "casualties share the panicked batch's key");
            continue;
        }
        assert!(r.ok(), "clean request {id}: {:?}", r.error);
        let (rz, rw, rs) = &reference[id];
        assert_eq!(bits(&r.z), bits(rz), "forward bits, request {id}");
        assert_eq!(bits(&r.w), bits(rw), "backward bits, request {id}");
        assert_eq!(r.stats.iters, rs.iters, "iteration count, request {id}");
        assert!(r.stats.converged);
        compared += 1;
    }
    // The panicked batch holds at most max_batch requests, one of which is
    // the victim itself — the parity set cannot silently collapse.
    assert!(
        compared >= total - plan.len() - 3,
        "parity compared only {compared} requests"
    );
    router.shutdown();
}

#[test]
fn deadlines_bounce_at_admission_and_expire_at_drain() {
    let router: ShardedRouter<f32> = ShardedRouter::new(shard_cfg(1, 64));
    // Model 0's first request straggles hard (10 ms per residual sweep);
    // model 1 is clean. Both keys live on the single shard, and key 0's
    // full batch is strictly older, so the worker must finish the
    // straggler batch before it can drain key 1 — by which time key 1's
    // deadlines have long lapsed.
    let plan = FaultPlan::from_faults(vec![(0, Fault::Straggle { delay_s: 10e-3 })]);
    router.register(ModelKey::new(0, 0), faulty(0, &plan));
    router.register(ModelKey::new(1, 0), mk_model(1));
    let cots = cotangents(9);
    // Admission: an already-expired deadline bounces with the payload
    // handed back, before it ever reaches a queue.
    let mut dead = ShardRequest::new(8, vec![0.0f32; D], cots[8].clone());
    dead.deadline = Some(0.0);
    match router.submit(0, dead) {
        Err(e @ SubmitError::DeadlineExceeded(_)) => {
            assert_eq!(e.as_serve_error(), ServeError::DeadlineExceeded);
            assert_eq!(e.into_request().id, 8);
        }
        other => panic!("expected an admission bounce, got {other:?}"),
    }
    // A full straggler-fronted batch on key 0 ...
    for id in 0..4 {
        router
            .submit(0, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("admitted");
    }
    // ... then a full batch of short-deadline requests on key 1. The
    // deadline is in the future at admission (so they queue) but expires
    // during key 0's straggler service.
    for id in 4..8 {
        let mut req = ShardRequest::new(id, vec![0.0f32; D], cots[id].clone());
        req.deadline = Some(router.now() + 2e-3);
        router.submit(1, req).expect("admitted");
    }
    let mut responses = router.collect(8);
    assert_eq!(responses.len(), 8);
    responses.sort_by_key(|r| r.id);
    for id in 0..4 {
        assert!(
            responses[id].ok() && responses[id].stats.converged,
            "straggled batch served fine: request {id} {:?}",
            responses[id].error
        );
    }
    for id in 4..8 {
        assert_eq!(
            responses[id].error,
            Some(ServeError::DeadlineExceeded),
            "request {id} expired at drain"
        );
        assert!(responses[id].z.is_empty() && responses[id].w.is_empty());
    }
    let stats = &router.shard_stats()[0];
    assert_eq!(stats.deadline_expired, 4);
    assert_eq!(stats.respawns, 0, "no supervision events in this scenario");
    router.shutdown();
}

#[test]
fn repeat_offender_key_is_quarantined_after_the_respawn_cap() {
    // Model 1 panics on every request it ever serves (the calibration
    // probe is id-less, so registration itself succeeds); model 0 is
    // clean. With a cap of one strike, the first panicked batch must be
    // the shard's LAST supervision event for that key.
    let total = 6;
    let mut cfg = shard_cfg(1, 64);
    cfg.quarantine_after = 1;
    let plan = FaultPlan::from_faults((0..64).map(|id| (id, Fault::Panic)).collect());
    let router: ShardedRouter<f32> = ShardedRouter::new(cfg);
    router.register(ModelKey::new(0, 0), mk_model(0));
    router.register(ModelKey::new(1, 0), faulty(1, &plan));
    let cots = cotangents(16);

    for id in 0..total {
        router
            .submit(1, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("admitted before the quarantine");
    }
    // Exactly once across the crash AND the quarantine: whatever was
    // in-flight with the panic is a WorkerLost casualty (at most one
    // batch), everything still queued resolves as the quarantined key's
    // typed ModelFault — never a hang, never a respawn loop.
    let mut responses = router.collect(total);
    assert_eq!(responses.len(), total);
    responses.sort_by_key(|r| r.id);
    let mut ids: Vec<usize> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..total).collect::<Vec<_>>());
    let lost = responses
        .iter()
        .filter(|r| r.error == Some(ServeError::WorkerLost))
        .count();
    let faulted = responses
        .iter()
        .filter(|r| r.error == Some(ServeError::ModelFault))
        .count();
    assert_eq!(lost + faulted, total, "only the two typed outcomes exist");
    assert!((1..=4).contains(&lost), "one panicked batch: {lost} casualties");
    assert!(faulted >= total - 4, "queued requests resolved as ModelFault");

    // One respawn, then the cap: the record is public on every surface,
    // and the quarantine-drain counter reconciles with the typed ledger.
    let stats = &router.shard_stats()[0];
    assert_eq!(stats.respawns, 1, "quarantine stopped the respawn loop");
    assert_eq!(stats.quarantined, faulted);
    assert_eq!(stats.worker_lost, lost);
    assert_eq!(router.quarantined_keys(), vec![(ModelKey::new(1, 0), 1)]);
    let metrics = router.key_metrics();
    let m1 = metrics
        .iter()
        .find(|m| m.key == ModelKey::new(1, 0))
        .expect("quarantined key stays in the metrics");
    assert!(m1.quarantined);
    assert_eq!(m1.strikes, 1);
    let m0 = metrics
        .iter()
        .find(|m| m.key == ModelKey::new(0, 0))
        .expect("clean key");
    assert!(!m0.quarantined);
    assert_eq!(m0.strikes, 0);

    // New submits bounce at admission as the typed quarantine error.
    let late = ShardRequest::new(9, vec![0.0f32; D], cots[9].clone());
    match router.submit(1, late) {
        Err(e @ SubmitError::Quarantined(_)) => {
            assert_eq!(e.as_serve_error(), ServeError::ModelFault);
            assert_eq!(e.into_request().id, 9);
        }
        other => panic!("expected a quarantine bounce, got {other:?}"),
    }

    // The innocent key on the same shard is untouched by its neighbour's
    // quarantine: still serving, still converged.
    for id in 10..14 {
        router
            .submit(0, ShardRequest::new(id, vec![0.0f32; D], cots[id].clone()))
            .expect("clean key still admits");
    }
    let clean = router.collect(4);
    assert_eq!(clean.len(), 4);
    assert!(clean.iter().all(|r| r.ok() && r.stats.converged));
    router.shutdown();
}
