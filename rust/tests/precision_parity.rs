//! Precision-parity property tests: the f32 instantiation of the qN stack
//! must agree with the f64 reference to f32 tolerance.
//!
//! Problems are random SPD-perturbed linear maps `A = I + P` (P symmetric
//! positive definite with eigenvalues well inside (0, 1]), so every update
//! is well-conditioned in both precisions: curvature `sᵀy = sᵀAs > 0` for
//! L-BFGS, healthy Sherman–Morrison denominators for the Broyden families.
//! Each test drives the *same* update stream through `E = f64` and
//! `E = f32` and compares the resulting operators (`InvOp::apply` /
//! `apply_t`) on random probes; the solver test additionally checks the
//! f32 `broyden_solve` lands on the f64 root to f32 tolerance.

use shine::linalg::dmat::DMat;
use shine::linalg::lu::Lu;
use shine::linalg::vecops::{Bf16, Elem, F16};
use shine::qn::adjoint_broyden::AdjointBroyden;
use shine::qn::broyden::BroydenInverse;
use shine::qn::lbfgs::LbfgsInverse;
use shine::qn::{InvOp, LowRank, MemoryPolicy};
use shine::solvers::fixed_point::{broyden_solve, FpOptions};
use shine::util::prop;
use shine::util::rng::Rng;

/// f32 storage keeps ~7 significant digits; a handful of composed updates
/// amplifies that. 5e-3 relative is comfortably inside "f32 tolerance" while
/// far outside anything an algorithmic divergence would produce.
const TOL: f64 = 5e-3;

/// bf16 keeps an 8-bit significand (relative steps of 2⁻⁸ ≈ 0.4%); with
/// both panel factors demoted and a handful of rank-one terms composed,
/// ~1% relative drift is typical. 4e-2 is the documented bf16-panel
/// tolerance (ADR-003) — loose enough to never flake, far below any
/// algorithmic divergence.
const BF16_TOL: f64 = 4e-2;

/// f16 keeps an 11-bit significand (steps of 2⁻¹¹ ≈ 5e-4) — an order finer
/// than bf16 — but its 5-bit exponent caps the range at ±65504.
/// The documented f16-panel tolerance is 1e-2.
const F16_TOL: f64 = 1e-2;

/// Mixed layout (`LowRank<Bf16, f32>`): only the U factor of each term is
/// demoted, so the error budget is bf16-class but roughly halved. Documented
/// at the bf16 tolerance.
const MIXED_TOL: f64 = 4e-2;

fn to32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn widen(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

/// Random SPD-perturbed map A = I + P, ‖P‖ < 1 → A is PD with spectrum in
/// (1, 2): contractive residual g(z) = z − (2I − A)z − b style problems and
/// positive curvature everywhere.
fn spd_perturbed(n: usize, rng: &mut Rng) -> DMat {
    let p = DMat::random_spd(n, 0.05, 0.85, rng);
    let mut a = DMat::eye(n);
    for i in 0..n {
        for j in 0..n {
            a[(i, j)] += p[(i, j)];
        }
    }
    a
}

fn ensure_close_f32(got32: &[f32], want64: &[f64], what: &str) -> Result<(), String> {
    prop::ensure_close_vec(&widen(got32), want64, TOL, what)
}

#[test]
fn broyden_family_f32_matches_f64() {
    prop::check("parity-broyden", 12, |rng| {
        let n = 4 + rng.below(16);
        let a = spd_perturbed(n, rng);
        let mut q64 = BroydenInverse::new(n, 16, MemoryPolicy::Evict);
        let mut q32: BroydenInverse<f32> = BroydenInverse::new(n, 16, MemoryPolicy::Evict);
        for _ in 0..6 {
            let s = rng.normal_vec(n);
            let mut y = vec![0.0; n];
            a.matvec(&s, &mut y); // y = A s: SPD-perturbed secant pairs
            let ok64 = q64.update(&s, &y);
            let ok32 = q32.update(&to32(&s), &to32(&y));
            prop::ensure(ok64 == ok32, "same accept/skip decision")?;
        }
        let x = rng.normal_vec(n);
        ensure_close_f32(&q32.apply_vec(&to32(&x)), &q64.apply_vec(&x), "broyden apply")?;
        ensure_close_f32(
            &q32.apply_t_vec(&to32(&x)),
            &q64.apply_t_vec(&x),
            "broyden apply_t",
        )
    });
}

#[test]
fn lbfgs_family_f32_matches_f64() {
    prop::check("parity-lbfgs", 12, |rng| {
        let n = 4 + rng.below(16);
        let a = spd_perturbed(n, rng);
        let mut q64 = LbfgsInverse::new(n, 8);
        let mut q32: LbfgsInverse<f32> = LbfgsInverse::new(n, 8);
        for _ in 0..6 {
            let s = rng.normal_vec(n);
            let mut y = vec![0.0; n];
            a.matvec(&s, &mut y); // sᵀy = sᵀAs > 0: always accepted
            let ok64 = q64.update(&s, &y);
            let ok32 = q32.update(&to32(&s), &to32(&y));
            prop::ensure(ok64 && ok32, "SPD curvature accepted in both precisions")?;
        }
        let x = rng.normal_vec(n);
        ensure_close_f32(&q32.apply_vec(&to32(&x)), &q64.apply_vec(&x), "lbfgs apply")?;
        ensure_close_f32(
            &q32.apply_t_vec(&to32(&x)),
            &q64.apply_t_vec(&x),
            "lbfgs apply_t",
        )
    });
}

#[test]
fn adjoint_broyden_family_f32_matches_f64() {
    prop::check("parity-adjbroyden", 12, |rng| {
        let n = 4 + rng.below(12);
        let a = spd_perturbed(n, rng);
        let mut q64 = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
        let mut q32: AdjointBroyden<f32> = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
        for _ in 0..5 {
            let sigma = rng.normal_vec(n);
            let mut sigma_j = vec![0.0; n];
            a.matvec_t(&sigma, &mut sigma_j); // σᵀA = (Aᵀσ)ᵀ
            let ok64 = q64.update(&sigma, &sigma_j);
            let ok32 = q32.update(&to32(&sigma), &to32(&sigma_j));
            prop::ensure(ok64 == ok32, "same accept/skip decision")?;
        }
        let x = rng.normal_vec(n);
        ensure_close_f32(&q32.apply_vec(&to32(&x)), &q64.apply_vec(&x), "adj apply")?;
        ensure_close_f32(
            &q32.apply_t_vec(&to32(&x)),
            &q64.apply_t_vec(&x),
            "adj apply_t",
        )?;
        // Left application of the direct matrix (the OPA surface).
        let mut sb64 = vec![0.0; n];
        q64.left_apply_direct(&x, &mut sb64);
        let mut sb32 = vec![0.0f32; n];
        q32.left_apply_direct(&to32(&x), &mut sb32);
        ensure_close_f32(&sb32, &sb64, "adj left apply")
    });
}

#[test]
fn half_precision_panels_match_f64_reference() {
    // The ISSUE 8 serving contract: demoting a calibrated estimate's factor
    // panels to bf16 / f16 / mixed storage (`LowRank::convert`) perturbs
    // `apply` / `apply_t` by at most the documented per-format tolerance.
    // The state side stays wide (f64 probes through the blanket `InvOp`),
    // exactly like a reduced-precision serving engine applying its panels
    // to full-precision cotangents with f64 accumulation.
    prop::check("parity-halfpanels", 12, |rng| {
        let n = 8 + rng.below(24);
        let m = 3 + rng.below(6);
        let mut lr: LowRank<f64> = LowRank::identity(n, m, MemoryPolicy::Freeze);
        for _ in 0..m {
            prop::ensure(lr.push(&rng.normal_vec(n), &rng.normal_vec(n)), "panel has room")?;
        }
        let lr_bf: LowRank<Bf16> = lr.convert();
        let lr_f16: LowRank<F16> = lr.convert();
        let lr_mix: LowRank<Bf16, f32> = lr.convert();
        prop::ensure(
            lr_bf.rank() == lr.rank() && lr_f16.rank() == lr.rank() && lr_mix.rank() == lr.rank(),
            "conversion preserves every factor",
        )?;

        let x = rng.normal_vec(n);
        let want = lr.apply_vec(&x);
        let want_t = lr.apply_t_vec(&x);
        prop::ensure_close_vec(&lr_bf.apply_vec(&x), &want, BF16_TOL, "bf16 apply")?;
        prop::ensure_close_vec(&lr_bf.apply_t_vec(&x), &want_t, BF16_TOL, "bf16 apply_t")?;
        prop::ensure_close_vec(&lr_f16.apply_vec(&x), &want, F16_TOL, "f16 apply")?;
        prop::ensure_close_vec(&lr_f16.apply_t_vec(&x), &want_t, F16_TOL, "f16 apply_t")?;
        prop::ensure_close_vec(&lr_mix.apply_vec(&x), &want, MIXED_TOL, "mixed apply")?;
        prop::ensure_close_vec(&lr_mix.apply_t_vec(&x), &want_t, MIXED_TOL, "mixed apply_t")?;

        // Widening back is exact (bf16 ⊂ f32 ⊂ f64), so a demote → widen
        // round trip applies identically to the demoted operator.
        let back: LowRank<f64> = lr_bf.convert();
        prop::ensure_close_vec(
            &back.apply_t_vec(&x),
            &lr_bf.apply_t_vec(&x),
            1e-14,
            "widening a bf16 panel is exact",
        )
    });
}

#[test]
fn bf16_every_bit_pattern_round_trips() {
    // bf16 ⊂ f32 ⊂ f64: widening any bf16 value to f64 and narrowing back
    // must reproduce the exact bit pattern (RNE is the identity on
    // representable values). NaNs keep their class rather than their payload.
    for bits in 0..=u16::MAX {
        let v = Bf16::from_bits(bits);
        let f = v.to_f64();
        let back = Bf16::from_f64(f);
        if f.is_nan() {
            assert!(back.to_f64().is_nan(), "bf16 {bits:#06x} NaN class lost");
        } else {
            assert_eq!(back.to_bits(), bits, "bf16 {bits:#06x} failed to round-trip");
        }
    }
}

#[test]
fn f16_every_bit_pattern_round_trips() {
    for bits in 0..=u16::MAX {
        let v = F16::from_bits(bits);
        let f = v.to_f64();
        let back = F16::from_f64(f);
        if f.is_nan() {
            assert!(back.to_f64().is_nan(), "f16 {bits:#06x} NaN class lost");
        } else {
            assert_eq!(back.to_bits(), bits, "f16 {bits:#06x} failed to round-trip");
        }
    }
}

#[test]
fn bf16_narrowing_rounds_to_nearest_even() {
    // Ties round to the even mantissa; off-tie values to the nearest.
    // 1 + 2⁻⁸ sits exactly between 1.0 (0x3F80, even) and 1 + 2⁻⁷ (0x3F81).
    assert_eq!(Bf16::from_f64(1.0 + 0.00390625).to_bits(), 0x3F80, "tie to even (down)");
    // 1 + 3·2⁻⁸ sits between 0x3F81 (odd) and 1 + 2⁻⁶ (0x3F82, even).
    assert_eq!(Bf16::from_f64(1.0 + 3.0 * 0.00390625).to_bits(), 0x3F82, "tie to even (up)");
    // Nudged past the tie, round to the nearest neighbour.
    assert_eq!(Bf16::from_f64(1.0 + 0.00390625 + 1e-6).to_bits(), 0x3F81, "above tie");
    assert_eq!(Bf16::from_f64(1.0 + 0.00390625 - 1e-6).to_bits(), 0x3F80, "below tie");

    // Range behaviour: bf16 shares f32's exponent, so f32::MAX rounds up to
    // Inf (it sits above the largest bf16, 0x7F7F) and ±Inf pass through.
    assert_eq!(Bf16::from_f64(f32::MAX as f64).to_bits(), 0x7F80, "overflow to +Inf");
    assert_eq!(Bf16::from_f64(f64::INFINITY).to_bits(), 0x7F80);
    assert_eq!(Bf16::from_f64(f64::NEG_INFINITY).to_bits(), 0xFF80);
    assert!(Bf16::from_f64(f64::NAN).to_f64().is_nan());

    // Subnormals: the smallest positive bf16 is 2⁻¹³³ (bits 0x0001); half of
    // it ties back to the even zero.
    let tiny = 2.0f64.powi(-133);
    assert_eq!(Bf16::from_f64(tiny).to_bits(), 0x0001, "smallest subnormal is exact");
    assert_eq!(Bf16::from_f64(tiny / 2.0).to_bits(), 0x0000, "half-ulp ties to zero");
    assert_eq!(Bf16::from_f64(-0.0).to_bits(), 0x8000, "signed zero survives");
}

#[test]
fn f16_narrowing_rounds_to_nearest_even() {
    // 1 + 2⁻¹¹ ties between 1.0 (0x3C00, even) and 1 + 2⁻¹⁰ (0x3C01).
    let ulp = 2.0f64.powi(-11);
    assert_eq!(F16::from_f64(1.0 + ulp).to_bits(), 0x3C00, "tie to even (down)");
    assert_eq!(F16::from_f64(1.0 + 3.0 * ulp).to_bits(), 0x3C02, "tie to even (up)");
    assert_eq!(F16::from_f64(1.0 + ulp + 1e-7).to_bits(), 0x3C01, "above tie");

    // Range: 65504 is the largest finite f16 (0x7BFF); the tie at 65520
    // rounds to the even candidate 65536, which overflows to Inf.
    assert_eq!(F16::from_f64(65504.0).to_bits(), 0x7BFF, "max finite is exact");
    assert_eq!(F16::from_f64(65520.0).to_bits(), 0x7C00, "overflow tie to Inf");
    assert_eq!(F16::from_f64(65519.0).to_bits(), 0x7BFF, "below the overflow tie");
    assert_eq!(F16::from_f64(f64::NEG_INFINITY).to_bits(), 0xFC00);
    assert!(F16::from_f64(f64::NAN).to_f64().is_nan());

    // Subnormals: smallest positive f16 is 2⁻²⁴ (0x0001); exactly half of it
    // ties to zero, and 1.5·2⁻²⁴ ties up to the even 0x0002.
    let tiny = 2.0f64.powi(-24);
    assert_eq!(F16::from_f64(tiny).to_bits(), 0x0001, "smallest subnormal is exact");
    assert_eq!(F16::from_f64(tiny / 2.0).to_bits(), 0x0000, "half-ulp ties to zero");
    assert_eq!(F16::from_f64(1.5 * tiny).to_bits(), 0x0002, "mid-subnormal tie to even");
    assert_eq!(F16::from_f64(-tiny).to_bits(), 0x8001, "sign survives subnormals");
}

/// Value-ordered successor of a 16-bit IEEE-layout pattern (works for both
/// bf16 and f16: for a fixed sign, the bit patterns are value-ordered).
fn next_up16(bits: u16) -> u16 {
    if bits & 0x8000 == 0 {
        bits + 1 // positive: grow the magnitude
    } else if bits == 0x8000 {
        0x0001 // −0 → smallest positive
    } else {
        bits - 1 // negative: shrink the magnitude
    }
}

/// Value-ordered predecessor (mirror of [`next_up16`]).
fn next_down16(bits: u16) -> u16 {
    if bits & 0x8000 != 0 {
        bits + 1
    } else if bits == 0x0000 {
        0x8001
    } else {
        bits - 1
    }
}

/// The RNE contract, checked against the format itself: the narrowed value
/// must be at least as close to `x` as BOTH its representable neighbours,
/// and an exact tie must have landed on the even mantissa.
fn ensure_rne(x: f64, r_bits: u16, widen: impl Fn(u16) -> f64, fmt: &str) -> Result<(), String> {
    let r = widen(r_bits);
    if !r.is_finite() {
        return Ok(()); // overflow / NaN classes are pinned by the targeted tests
    }
    let err = (r - x).abs();
    for nb in [next_up16(r_bits), next_down16(r_bits)] {
        let nv = widen(nb);
        if !nv.is_finite() {
            continue;
        }
        let nerr = (nv - x).abs();
        prop::ensure(
            err < nerr || (err == nerr && r_bits & 1 == 0),
            &format!("{fmt}: {x:e} → {r:e} but neighbour {nv:e} is as close or closer"),
        )?;
    }
    Ok(())
}

#[test]
fn half_precision_narrowing_is_round_to_nearest_even() {
    // Property form of the RNE contract over magnitudes spanning both
    // formats' normal AND subnormal ranges (f16 subnormals live below
    // 2⁻¹⁴; draws above 65504 exercise its overflow path and are skipped
    // by the finiteness guard inside `ensure_rne`). The contract is
    // "narrow to f32, then RNE to 16 bits", so nearest-ness is measured
    // from the f32 value — measuring from the raw f64 would trip over
    // legitimate double rounding near tie midpoints.
    prop::check("half-rne", 16, |rng| {
        for _ in 0..256 {
            let x = rng.normal() * 2f64.powi(rng.below(80) as i32 - 40);
            let xf = (x as f32) as f64;
            ensure_rne(xf, Bf16::from_f64(x).to_bits(), |b| Bf16::from_bits(b).to_f64(), "bf16")?;
            ensure_rne(xf, F16::from_f64(x).to_bits(), |b| F16::from_bits(b).to_f64(), "f16")?;
        }
        Ok(())
    });
}

#[test]
fn broyden_solve_f32_lands_on_f64_root() {
    prop::check("parity-solve", 10, |rng| {
        let n = 6 + rng.below(14);
        let a = spd_perturbed(n, rng);
        let x_star = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        a.matvec(&x_star, &mut b);
        // g(z) = A z − b, root z* = x_star. Dense f64 oracle for reference.
        let want = match Lu::factor(&a) {
            Ok(lu) => lu.solve(&b),
            Err(_) => return Ok(()), // singular draw (measure zero): skip case
        };
        let g64 = |z: &[f64], out: &mut [f64]| {
            a.matvec(z, out);
            for i in 0..z.len() {
                out[i] -= b[i];
            }
        };
        let b32 = to32(&b);
        let a32_rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..n).map(|j| a[(i, j)] as f32).collect())
            .collect();
        let g32 = |z: &[f32], out: &mut [f32]| {
            // f32 matvec with f64 row accumulation — the same contract the
            // DEQ artifact boundary follows.
            for i in 0..z.len() {
                let mut acc = -(b32[i] as f64);
                for j in 0..z.len() {
                    acc += a32_rows[i][j] as f64 * z[j] as f64;
                }
                out[i] = acc as f32;
            }
        };
        // (a) Trajectory parity over a fixed iteration budget: precision
        // trajectories drift apart geometrically, so compare after exactly 5
        // iterations (tol unreachable forces the full budget in both runs)
        // where the accumulated f32 drift stays orders below TOL.
        let fixed = FpOptions {
            tol: -1.0,
            max_iters: 5,
            memory: 16,
            ..Default::default()
        };
        let t64 = broyden_solve(g64, &vec![0.0; n], &fixed);
        let t32 = broyden_solve(g32, &vec![0.0f32; n], &fixed);
        prop::ensure(t64.iters == 5 && t32.iters == 5, "both ran the fixed budget")?;
        ensure_close_f32(&t32.z, &t64.z, "iterate after 5 steps")?;
        // The shared inverse estimates act alike on a head-gradient probe.
        let probe = rng.normal_vec(n);
        ensure_close_f32(
            &t32.qn.apply_t_vec(&to32(&probe)),
            &t64.qn.apply_t_vec(&probe),
            "solver-built InvOp::apply_t",
        )?;
        // (b) The f32 instantiation converges to the true root on its own,
        // to an f32-appropriate tolerance.
        let opts32 = FpOptions {
            tol: 1e-3,
            max_iters: 40 * n,
            memory: 40 * n,
            ..Default::default()
        };
        let r32 = broyden_solve(g32, &vec![0.0f32; n], &opts32);
        prop::ensure(r32.converged, &format!("f32 converged, |g|={}", r32.g_norm))?;
        ensure_close_f32(&r32.z, &want, "f32 root vs dense oracle")
    });
}
