//! Precision-parity property tests: the f32 instantiation of the qN stack
//! must agree with the f64 reference to f32 tolerance.
//!
//! Problems are random SPD-perturbed linear maps `A = I + P` (P symmetric
//! positive definite with eigenvalues well inside (0, 1]), so every update
//! is well-conditioned in both precisions: curvature `sᵀy = sᵀAs > 0` for
//! L-BFGS, healthy Sherman–Morrison denominators for the Broyden families.
//! Each test drives the *same* update stream through `E = f64` and
//! `E = f32` and compares the resulting operators (`InvOp::apply` /
//! `apply_t`) on random probes; the solver test additionally checks the
//! f32 `broyden_solve` lands on the f64 root to f32 tolerance.

use shine::linalg::dmat::DMat;
use shine::linalg::lu::Lu;
use shine::qn::adjoint_broyden::AdjointBroyden;
use shine::qn::broyden::BroydenInverse;
use shine::qn::lbfgs::LbfgsInverse;
use shine::qn::{InvOp, MemoryPolicy};
use shine::solvers::fixed_point::{broyden_solve, FpOptions};
use shine::util::prop;
use shine::util::rng::Rng;

/// f32 storage keeps ~7 significant digits; a handful of composed updates
/// amplifies that. 5e-3 relative is comfortably inside "f32 tolerance" while
/// far outside anything an algorithmic divergence would produce.
const TOL: f64 = 5e-3;

fn to32(v: &[f64]) -> Vec<f32> {
    v.iter().map(|&x| x as f32).collect()
}

fn widen(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

/// Random SPD-perturbed map A = I + P, ‖P‖ < 1 → A is PD with spectrum in
/// (1, 2): contractive residual g(z) = z − (2I − A)z − b style problems and
/// positive curvature everywhere.
fn spd_perturbed(n: usize, rng: &mut Rng) -> DMat {
    let p = DMat::random_spd(n, 0.05, 0.85, rng);
    let mut a = DMat::eye(n);
    for i in 0..n {
        for j in 0..n {
            a[(i, j)] += p[(i, j)];
        }
    }
    a
}

fn ensure_close_f32(got32: &[f32], want64: &[f64], what: &str) -> Result<(), String> {
    prop::ensure_close_vec(&widen(got32), want64, TOL, what)
}

#[test]
fn broyden_family_f32_matches_f64() {
    prop::check("parity-broyden", 12, |rng| {
        let n = 4 + rng.below(16);
        let a = spd_perturbed(n, rng);
        let mut q64 = BroydenInverse::new(n, 16, MemoryPolicy::Evict);
        let mut q32: BroydenInverse<f32> = BroydenInverse::new(n, 16, MemoryPolicy::Evict);
        for _ in 0..6 {
            let s = rng.normal_vec(n);
            let mut y = vec![0.0; n];
            a.matvec(&s, &mut y); // y = A s: SPD-perturbed secant pairs
            let ok64 = q64.update(&s, &y);
            let ok32 = q32.update(&to32(&s), &to32(&y));
            prop::ensure(ok64 == ok32, "same accept/skip decision")?;
        }
        let x = rng.normal_vec(n);
        ensure_close_f32(&q32.apply_vec(&to32(&x)), &q64.apply_vec(&x), "broyden apply")?;
        ensure_close_f32(
            &q32.apply_t_vec(&to32(&x)),
            &q64.apply_t_vec(&x),
            "broyden apply_t",
        )
    });
}

#[test]
fn lbfgs_family_f32_matches_f64() {
    prop::check("parity-lbfgs", 12, |rng| {
        let n = 4 + rng.below(16);
        let a = spd_perturbed(n, rng);
        let mut q64 = LbfgsInverse::new(n, 8);
        let mut q32: LbfgsInverse<f32> = LbfgsInverse::new(n, 8);
        for _ in 0..6 {
            let s = rng.normal_vec(n);
            let mut y = vec![0.0; n];
            a.matvec(&s, &mut y); // sᵀy = sᵀAs > 0: always accepted
            let ok64 = q64.update(&s, &y);
            let ok32 = q32.update(&to32(&s), &to32(&y));
            prop::ensure(ok64 && ok32, "SPD curvature accepted in both precisions")?;
        }
        let x = rng.normal_vec(n);
        ensure_close_f32(&q32.apply_vec(&to32(&x)), &q64.apply_vec(&x), "lbfgs apply")?;
        ensure_close_f32(
            &q32.apply_t_vec(&to32(&x)),
            &q64.apply_t_vec(&x),
            "lbfgs apply_t",
        )
    });
}

#[test]
fn adjoint_broyden_family_f32_matches_f64() {
    prop::check("parity-adjbroyden", 12, |rng| {
        let n = 4 + rng.below(12);
        let a = spd_perturbed(n, rng);
        let mut q64 = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
        let mut q32: AdjointBroyden<f32> = AdjointBroyden::new(n, 16, MemoryPolicy::Freeze);
        for _ in 0..5 {
            let sigma = rng.normal_vec(n);
            let mut sigma_j = vec![0.0; n];
            a.matvec_t(&sigma, &mut sigma_j); // σᵀA = (Aᵀσ)ᵀ
            let ok64 = q64.update(&sigma, &sigma_j);
            let ok32 = q32.update(&to32(&sigma), &to32(&sigma_j));
            prop::ensure(ok64 == ok32, "same accept/skip decision")?;
        }
        let x = rng.normal_vec(n);
        ensure_close_f32(&q32.apply_vec(&to32(&x)), &q64.apply_vec(&x), "adj apply")?;
        ensure_close_f32(
            &q32.apply_t_vec(&to32(&x)),
            &q64.apply_t_vec(&x),
            "adj apply_t",
        )?;
        // Left application of the direct matrix (the OPA surface).
        let mut sb64 = vec![0.0; n];
        q64.left_apply_direct(&x, &mut sb64);
        let mut sb32 = vec![0.0f32; n];
        q32.left_apply_direct(&to32(&x), &mut sb32);
        ensure_close_f32(&sb32, &sb64, "adj left apply")
    });
}

#[test]
fn broyden_solve_f32_lands_on_f64_root() {
    prop::check("parity-solve", 10, |rng| {
        let n = 6 + rng.below(14);
        let a = spd_perturbed(n, rng);
        let x_star = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        a.matvec(&x_star, &mut b);
        // g(z) = A z − b, root z* = x_star. Dense f64 oracle for reference.
        let want = match Lu::factor(&a) {
            Ok(lu) => lu.solve(&b),
            Err(_) => return Ok(()), // singular draw (measure zero): skip case
        };
        let g64 = |z: &[f64], out: &mut [f64]| {
            a.matvec(z, out);
            for i in 0..z.len() {
                out[i] -= b[i];
            }
        };
        let b32 = to32(&b);
        let a32_rows: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..n).map(|j| a[(i, j)] as f32).collect())
            .collect();
        let g32 = |z: &[f32], out: &mut [f32]| {
            // f32 matvec with f64 row accumulation — the same contract the
            // DEQ artifact boundary follows.
            for i in 0..z.len() {
                let mut acc = -(b32[i] as f64);
                for j in 0..z.len() {
                    acc += a32_rows[i][j] as f64 * z[j] as f64;
                }
                out[i] = acc as f32;
            }
        };
        // (a) Trajectory parity over a fixed iteration budget: precision
        // trajectories drift apart geometrically, so compare after exactly 5
        // iterations (tol unreachable forces the full budget in both runs)
        // where the accumulated f32 drift stays orders below TOL.
        let fixed = FpOptions {
            tol: -1.0,
            max_iters: 5,
            memory: 16,
            ..Default::default()
        };
        let t64 = broyden_solve(g64, &vec![0.0; n], &fixed);
        let t32 = broyden_solve(g32, &vec![0.0f32; n], &fixed);
        prop::ensure(t64.iters == 5 && t32.iters == 5, "both ran the fixed budget")?;
        ensure_close_f32(&t32.z, &t64.z, "iterate after 5 steps")?;
        // The shared inverse estimates act alike on a head-gradient probe.
        let probe = rng.normal_vec(n);
        ensure_close_f32(
            &t32.qn.apply_t_vec(&to32(&probe)),
            &t64.qn.apply_t_vec(&probe),
            "solver-built InvOp::apply_t",
        )?;
        // (b) The f32 instantiation converges to the true root on its own,
        // to an f32-appropriate tolerance.
        let opts32 = FpOptions {
            tol: 1e-3,
            max_iters: 40 * n,
            memory: 40 * n,
            ..Default::default()
        };
        let r32 = broyden_solve(g32, &vec![0.0f32; n], &opts32);
        prop::ensure(r32.converged, &format!("f32 converged, |g|={}", r32.g_norm))?;
        ensure_close_f32(&r32.z, &want, "f32 root vs dense oracle")
    });
}
