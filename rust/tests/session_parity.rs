//! Shim-layer parity (ISSUE 5 satellite): the legacy free-function entry
//! points (`broyden_solve_ws`, `anderson_solve_ws`, `picard_solve_batch`,
//! `anderson_solve_batch`) must produce **bit-identical** iterates,
//! residuals and iteration counts to the session API they now delegate to
//! (`SolverSpec::build()` → `FixedPointSolver::solve`/`solve_batch`), in
//! both storage precisions. The shims share the iteration cores with the
//! trait implementations, so any drift between the two surfaces is a real
//! regression in the delegation plumbing — exactly what this pins.

use shine::linalg::vecops::Elem;
use shine::qn::workspace::Workspace;
use shine::qn::InvOp;
use shine::solvers::fixed_point::{
    anderson_solve_batch, anderson_solve_ws, broyden_solve_ws, picard_solve_batch, ColStats,
    FpOptions,
};
use shine::solvers::session::{Session, SolverSpec};
use shine::util::rng::Rng;

/// Per-column linear contractive map g(z)[i] = z[i] − c·z[(i+1) mod d] − b[i].
fn col_g<E: Elem>(c: f64, b: &[E], z: &[E], out: &mut [E]) {
    let d = z.len();
    for i in 0..d {
        out[i] = E::from_f64(z[i].to_f64() - c * z[(i + 1) % d].to_f64() - b[i].to_f64());
    }
}

fn problem<E: Elem>(d: usize, seed: u64) -> (Vec<E>, Vec<E>) {
    let mut rng = Rng::new(seed);
    let b = (0..d).map(|_| E::from_f64(rng.normal())).collect();
    let z0 = (0..d).map(|_| E::from_f64(rng.normal() * 0.5)).collect();
    (b, z0)
}

fn broyden_shim_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 18;
    let (b, z0) = problem::<E>(d, seed);
    let opts = FpOptions {
        tol,
        max_iters: 80,
        memory: 10,
        ..Default::default()
    };
    let mut ws: Workspace<E> = Workspace::new();
    let shim = broyden_solve_ws(
        |z: &[E], out: &mut [E]| col_g(0.3, &b, z, out),
        &z0,
        &opts,
        &mut ws,
    );
    let spec = SolverSpec::from_fp_options(&opts);
    let mut solver = spec.build::<E>();
    let mut sess: Session<E> = Session::new();
    let mut g = |z: &[E], out: &mut [E]| col_g(0.3, &b, z, out);
    let api = solver.solve(&mut sess, &mut g, &z0);
    assert!(shim.z == api.z, "iterate bits");
    assert_eq!(shim.iters, api.iters, "iteration count");
    assert_eq!(shim.g_norm, api.residual, "residual bits");
    assert_eq!(shim.converged, api.converged);
    assert_eq!(shim.n_g_evals, api.n_g_evals);
    // The shim's reconstructed qN operator and the API's estimate handle
    // are the same operator, bit for bit.
    let mut rng = Rng::new(seed ^ 0xE5);
    let x: Vec<E> = (0..d).map(|_| E::from_f64(rng.normal())).collect();
    let est = api.estimate.expect("broyden captures an estimate");
    assert!(shim.qn.apply_t_vec(&x) == est.low_rank().apply_t_vec(&x), "estimate bits");
}

fn anderson_shim_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 14;
    let m = 4;
    let (b, z0) = problem::<E>(d, seed);
    let mut ws: Workspace<E> = Workspace::new();
    let (z_shim, rn_shim, it_shim) = anderson_solve_ws(
        |z: &[E], out: &mut [E]| col_g(0.25, &b, z, out),
        &z0,
        m,
        tol,
        150,
        1.0,
        &mut ws,
    );
    let spec = SolverSpec::anderson(m, 1.0).with_tol(tol).with_max_iters(150);
    let mut solver = spec.build::<E>();
    let mut sess: Session<E> = Session::new();
    let mut g = |z: &[E], out: &mut [E]| col_g(0.25, &b, z, out);
    let api = solver.solve(&mut sess, &mut g, &z0);
    assert!(z_shim == api.z, "iterate bits");
    assert_eq!(it_shim, api.iters, "iteration count");
    assert_eq!(rn_shim, api.residual, "residual bits");
}

fn batch_problem<E: Elem>(d: usize, nb: usize, seed: u64) -> (Vec<f64>, Vec<Vec<E>>, Vec<E>) {
    let mut rng = Rng::new(seed);
    let cs = (0..nb).map(|j| 0.15 + 0.35 * j as f64 / nb as f64).collect();
    let bs: Vec<Vec<E>> = (0..nb)
        .map(|_| (0..d).map(|_| E::from_f64(rng.normal())).collect())
        .collect();
    let zs = (0..nb * d).map(|_| E::from_f64(rng.normal() * 0.5)).collect();
    (cs, bs, zs)
}

fn picard_batch_shim_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 16;
    let nb = 5;
    let (cs, bs, zs0) = batch_problem::<E>(d, nb, seed);
    let g = |block: &[E], ids: &[usize], out: &mut [E]| {
        for (p, &id) in ids.iter().enumerate() {
            col_g(
                cs[id],
                &bs[id],
                &block[p * d..(p + 1) * d],
                &mut out[p * d..(p + 1) * d],
            );
        }
    };
    let mut zs_shim = zs0.clone();
    let mut stats_shim = vec![ColStats::default(); nb];
    let mut ws: Workspace<E> = Workspace::new();
    picard_solve_batch(g, &mut zs_shim, d, 1.0, tol, 300, &mut ws, &mut stats_shim);
    let spec = SolverSpec::picard(1.0).with_tol(tol).with_max_iters(300);
    let mut solver = spec.build::<E>();
    let mut sess: Session<E> = Session::new();
    let mut zs_api = zs0;
    let mut stats_api = vec![ColStats::default(); nb];
    let mut g2 = |block: &[E], ids: &[usize], out: &mut [E]| g(block, ids, out);
    solver.solve_batch(&mut sess, &mut g2, &mut zs_api, d, &mut stats_api);
    assert!(zs_shim == zs_api, "block bits");
    for j in 0..nb {
        assert_eq!(stats_shim[j].iters, stats_api[j].iters, "col {j} iters");
        assert_eq!(stats_shim[j].residual, stats_api[j].residual, "col {j} residual");
        assert_eq!(stats_shim[j].converged, stats_api[j].converged, "col {j}");
    }
}

fn anderson_batch_shim_parity<E: Elem>(seed: u64, tol: f64) {
    let d = 12;
    let nb = 4;
    let m = 3;
    let (cs, bs, zs0) = batch_problem::<E>(d, nb, seed);
    let g = |block: &[E], ids: &[usize], out: &mut [E]| {
        for (p, &id) in ids.iter().enumerate() {
            col_g(
                cs[id],
                &bs[id],
                &block[p * d..(p + 1) * d],
                &mut out[p * d..(p + 1) * d],
            );
        }
    };
    let mut zs_shim = zs0.clone();
    let mut stats_shim = vec![ColStats::default(); nb];
    let mut ws: Workspace<E> = Workspace::new();
    anderson_solve_batch(g, &mut zs_shim, d, m, 1.0, tol, 200, &mut ws, &mut stats_shim);
    let spec = SolverSpec::anderson(m, 1.0).with_tol(tol).with_max_iters(200);
    let mut solver = spec.build::<E>();
    let mut sess: Session<E> = Session::new();
    let mut zs_api = zs0;
    let mut stats_api = vec![ColStats::default(); nb];
    let mut g2 = |block: &[E], ids: &[usize], out: &mut [E]| g(block, ids, out);
    solver.solve_batch(&mut sess, &mut g2, &mut zs_api, d, &mut stats_api);
    assert!(zs_shim == zs_api, "block bits");
    for j in 0..nb {
        assert_eq!(stats_shim[j].iters, stats_api[j].iters, "col {j} iters");
        assert_eq!(stats_shim[j].residual, stats_api[j].residual, "col {j} residual");
    }
}

#[test]
fn broyden_shim_parity_f64() {
    for seed in [1u64, 2, 3] {
        broyden_shim_parity::<f64>(seed, 1e-9);
    }
}

#[test]
fn broyden_shim_parity_f32() {
    for seed in [4u64, 5, 6] {
        broyden_shim_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn anderson_shim_parity_f64() {
    for seed in [7u64, 8, 9] {
        anderson_shim_parity::<f64>(seed, 1e-8);
    }
}

#[test]
fn anderson_shim_parity_f32() {
    for seed in [10u64, 11, 12] {
        anderson_shim_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn picard_batch_shim_parity_f64() {
    for seed in [13u64, 14] {
        picard_batch_shim_parity::<f64>(seed, 1e-9);
    }
}

#[test]
fn picard_batch_shim_parity_f32() {
    for seed in [15u64, 16] {
        picard_batch_shim_parity::<f32>(seed, 1e-4);
    }
}

#[test]
fn anderson_batch_shim_parity_f64() {
    for seed in [17u64, 18] {
        anderson_batch_shim_parity::<f64>(seed, 1e-8);
    }
}

#[test]
fn anderson_batch_shim_parity_f32() {
    for seed in [19u64, 20] {
        anderson_batch_shim_parity::<f32>(seed, 1e-4);
    }
}
