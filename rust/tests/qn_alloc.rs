//! Counting-allocator proof that the qN hot loops are allocation-free.
//!
//! A wrapping global allocator counts alloc/realloc events. The key
//! assertion: running `broyden_solve_ws` for 30 iterations costs exactly as
//! many allocation events as running it for 6 — i.e. the iteration loop
//! itself performs **zero heap allocations** once the workspace and panels
//! are warm (everything else — panels, iterate buffers, trace — is set up
//! front-loaded and identical for both runs).
//!
//! Everything lives in a single #[test] because the counter is global: a
//! second test running on a sibling thread would pollute the counts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use shine::qn::broyden::BroydenInverse;
use shine::qn::workspace::Workspace;
use shine::qn::{InvOp, LowRank, MemoryPolicy};
use shine::solvers::fixed_point::{broyden_solve_ws, FpOptions};

struct CountingAlloc;

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocation events (allocs + reallocs; deallocs don't count) during `f`.
fn alloc_events<T>(f: impl FnOnce() -> T) -> (usize, T) {
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    let r = f();
    (ALLOC_EVENTS.load(Ordering::SeqCst) - before, r)
}

/// Run the Broyden solver on an allocation-free contractive map for exactly
/// `iters` iterations; returns the allocation events of the whole call.
fn solver_events(iters: usize, b: &[f64], ws: &mut Workspace) -> usize {
    let d = b.len();
    let g = |z: &[f64], out: &mut [f64]| {
        for i in 0..d {
            let zn = z[(i + 1) % d];
            out[i] = z[i] - 0.3 * zn - b[i];
        }
    };
    let opts = FpOptions {
        tol: -1.0, // unreachable even at an exact root: run the full budget
        max_iters: iters,
        memory: 4,
        ..Default::default()
    };
    let (events, res) = alloc_events(|| broyden_solve_ws(g, &vec![0.0; d], &opts, ws));
    assert_eq!(res.iters, iters, "solver must not converge early");
    events
}

#[test]
fn qn_hot_loops_do_not_allocate() {
    let d = 32;
    let b: Vec<f64> = (0..d).map(|i| ((i as f64) * 0.37).sin()).collect();

    // --- (1) broyden_solve: iterations past warm-up add zero allocations.
    let mut ws = Workspace::new();
    let _warm = solver_events(6, &b, &mut ws); // warms the shared workspace
    let short = solver_events(6, &b, &mut ws);
    let long = solver_events(30, &b, &mut ws);
    assert_eq!(
        short, long,
        "broyden_solve iteration loop allocated: {short} events for 6 iters vs {long} for 30"
    );

    // --- (2) LowRank::apply_into / apply_t_into are allocation-free with a
    // warm workspace (serial path below the parallel threshold).
    let mut rng = shine::util::rng::Rng::new(9);
    let n = 64;
    let mut lr = LowRank::identity(n, 8, MemoryPolicy::Evict);
    for _ in 0..8 {
        lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
    }
    let x = rng.normal_vec(n);
    let mut out = vec![0.0; n];
    lr.apply_into(&x, &mut out, &mut ws); // warm for this size
    lr.apply_t_into(&x, &mut out, &mut ws);
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            lr.apply_into(&x, &mut out, &mut ws);
            lr.apply_t_into(&x, &mut out, &mut ws);
        }
    });
    assert_eq!(events, 0, "LowRank apply_into allocated {events} times");

    // --- (3) BroydenInverse::update_ws at steady state (Evict ring full)
    // writes factors in place: zero allocations.
    let mut bro = BroydenInverse::new(n, 6, MemoryPolicy::Evict);
    let s = rng.normal_vec(n);
    let y = rng.normal_vec(n);
    for _ in 0..8 {
        bro.update_ws(&s, &y, &mut ws);
    }
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            bro.update_ws(&s, &y, &mut ws);
        }
    });
    assert_eq!(events, 0, "update_ws allocated {events} times at steady state");
    assert_eq!(bro.rank(), 6);
}
