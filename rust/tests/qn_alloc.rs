//! Counting-allocator proof that the qN hot loops are allocation-free.
//!
//! A wrapping global allocator counts alloc/realloc events. The key
//! assertion: running `broyden_solve_ws` for 30 iterations costs exactly as
//! many allocation events as running it for 6 — i.e. the iteration loop
//! itself performs **zero heap allocations** once the workspace and panels
//! are warm (everything else — panels, iterate buffers, trace — is set up
//! front-loaded and identical for both runs). The proof runs for **both
//! precision instantiations** (f64 and f32 storage) and, since the
//! incremental-Gram rework, for `anderson_solve_ws` too.
//!
//! Everything lives in a single #[test] because the counter is global: a
//! second test running on a sibling thread would pollute the counts.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use shine::linalg::vecops::{Bf16, Elem};
use shine::qn::broyden::BroydenInverse;
use shine::qn::workspace::Workspace;
use shine::qn::{InvOp, LowRank, MemoryPolicy};
use shine::serve::{EngineConfig, ServeEngine};
use shine::solvers::fixed_point::{anderson_solve_ws, broyden_solve_ws, ColStats, FpOptions};
use shine::solvers::session::SolverSpec;

struct CountingAlloc;

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocation events (allocs + reallocs; deallocs don't count) during `f`.
fn alloc_events<T>(f: impl FnOnce() -> T) -> (usize, T) {
    let before = ALLOC_EVENTS.load(Ordering::SeqCst);
    let r = f();
    (ALLOC_EVENTS.load(Ordering::SeqCst) - before, r)
}

/// Run the Broyden solver on an allocation-free contractive map for exactly
/// `iters` iterations (both precisions — the map widens/narrows per element,
/// which costs no allocation); returns the allocation events of the call.
fn solver_events<E: Elem>(iters: usize, b: &[E], ws: &mut Workspace<E>) -> usize {
    let d = b.len();
    let g = |z: &[E], out: &mut [E]| {
        for i in 0..d {
            let zn = z[(i + 1) % d];
            out[i] = E::from_f64(z[i].to_f64() - 0.3 * zn.to_f64() - b[i].to_f64());
        }
    };
    let opts = FpOptions {
        tol: -1.0, // unreachable even at an exact root: run the full budget
        max_iters: iters,
        memory: 4,
        ..Default::default()
    };
    let (events, res) = alloc_events(|| broyden_solve_ws(g, &vec![E::ZERO; d], &opts, ws));
    assert_eq!(res.iters, iters, "solver must not converge early");
    events
}

/// Same proof for Anderson acceleration: with the persistent incremental
/// Gram, iterations past warm-up must add zero allocation events.
fn anderson_events(iters: usize, b: &[f64], ws: &mut Workspace) -> usize {
    let d = b.len();
    let g = |z: &[f64], out: &mut [f64]| {
        for i in 0..d {
            let zn = z[(i + 1) % d];
            out[i] = z[i] - 0.3 * zn - b[i];
        }
    };
    let (events, (_z, _rn, it)) =
        alloc_events(|| anderson_solve_ws(g, &vec![0.0; d], 4, -1.0, iters, 1.0, ws));
    assert_eq!(it, iters, "anderson must not converge early");
    events
}

#[test]
fn qn_hot_loops_do_not_allocate() {
    let d = 32;
    let b: Vec<f64> = (0..d).map(|i| ((i as f64) * 0.37).sin()).collect();
    let b32: Vec<f32> = b.iter().map(|&x| x as f32).collect();

    // --- (1) broyden_solve (f64): iterations past warm-up add zero allocs.
    let mut ws = Workspace::new();
    let _warm = solver_events(6, &b, &mut ws); // warms the shared workspace
    let short = solver_events(6, &b, &mut ws);
    let long = solver_events(30, &b, &mut ws);
    assert_eq!(
        short, long,
        "broyden_solve<f64> iteration loop allocated: {short} events for 6 iters vs {long} for 30"
    );

    // --- (1b) broyden_solve (f32): the f32 instantiation gives the same
    // zero-allocation guarantee through its own Workspace<f32>.
    let mut ws32: Workspace<f32> = Workspace::new();
    let _warm = solver_events(6, &b32, &mut ws32);
    let short32 = solver_events(6, &b32, &mut ws32);
    let long32 = solver_events(30, &b32, &mut ws32);
    assert_eq!(
        short32, long32,
        "broyden_solve<f32> iteration loop allocated: {short32} events for 6 iters vs {long32} for 30"
    );

    // --- (1c) anderson_solve_ws: persistent incremental Gram + in-place
    // solve — iterations past warm-up add zero allocation events.
    let mut ws_and = Workspace::new();
    let _warm = anderson_events(6, &b, &mut ws_and);
    let short_and = anderson_events(6, &b, &mut ws_and);
    let long_and = anderson_events(30, &b, &mut ws_and);
    assert_eq!(
        short_and, long_and,
        "anderson_solve_ws iteration loop allocated: {short_and} events for 6 iters vs {long_and} for 30"
    );

    // --- (2) LowRank::apply_into / apply_t_into are allocation-free with a
    // warm workspace (serial path below the parallel threshold), in both
    // precisions.
    let mut rng = shine::util::rng::Rng::new(9);
    let n = 64;
    let mut lr = LowRank::identity(n, 8, MemoryPolicy::Evict);
    for _ in 0..8 {
        lr.push(&rng.normal_vec(n), &rng.normal_vec(n));
    }
    let x = rng.normal_vec(n);
    let mut out = vec![0.0; n];
    lr.apply_into(&x, &mut out, &mut ws); // warm for this size
    lr.apply_t_into(&x, &mut out, &mut ws);
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            lr.apply_into(&x, &mut out, &mut ws);
            lr.apply_t_into(&x, &mut out, &mut ws);
        }
    });
    assert_eq!(events, 0, "LowRank<f64> apply_into allocated {events} times");

    let mut lr32: LowRank<f32> = LowRank::identity(n, 8, MemoryPolicy::Evict);
    for _ in 0..8 {
        lr32.push(&rng.normal_vec_f32(n, 1.0), &rng.normal_vec_f32(n, 1.0));
    }
    let x32 = rng.normal_vec_f32(n, 1.0);
    let mut out32 = vec![0.0f32; n];
    lr32.apply_into(&x32, &mut out32, &mut ws32);
    lr32.apply_t_into(&x32, &mut out32, &mut ws32);
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            lr32.apply_into(&x32, &mut out32, &mut ws32);
            lr32.apply_t_into(&x32, &mut out32, &mut ws32);
        }
    });
    assert_eq!(events, 0, "LowRank<f32> apply_into allocated {events} times");

    // --- (2b) half-precision and mixed panel storage (ISSUE 8): applying a
    // bf16-stored or mixed-layout estimate to f32 state widens per element
    // inside the sweeps — no conversion buffers, and the coefficient scratch
    // comes from the same Workspace<f32> pools. Zero allocations once warm.
    let mut lr16: LowRank<Bf16> = LowRank::identity(n, 8, MemoryPolicy::Evict);
    let mut lrmix: LowRank<Bf16, f32> = LowRank::identity(n, 8, MemoryPolicy::Evict);
    for _ in 0..8 {
        let u: Vec<Bf16> = rng.normal_vec(n).iter().map(|&x| Bf16::from_f64(x)).collect();
        let v32 = rng.normal_vec_f32(n, 1.0);
        let v: Vec<Bf16> = v32.iter().map(|&x| Bf16::from_f64(x as f64)).collect();
        lr16.push(&u, &v);
        lrmix.push(&u, &v32);
    }
    lr16.apply_into(&x32, &mut out32, &mut ws32); // warm for this size
    lr16.apply_t_into(&x32, &mut out32, &mut ws32);
    lrmix.apply_into(&x32, &mut out32, &mut ws32);
    lrmix.apply_t_into(&x32, &mut out32, &mut ws32);
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            lr16.apply_into(&x32, &mut out32, &mut ws32);
            lr16.apply_t_into(&x32, &mut out32, &mut ws32);
            lrmix.apply_into(&x32, &mut out32, &mut ws32);
            lrmix.apply_t_into(&x32, &mut out32, &mut ws32);
        }
    });
    assert_eq!(
        events, 0,
        "half-precision panel apply allocated {events} times after warm-up"
    );

    // --- (3) BroydenInverse::update_ws at steady state (Evict ring full)
    // writes factors in place: zero allocations, in both precisions.
    let mut bro = BroydenInverse::new(n, 6, MemoryPolicy::Evict);
    let s = rng.normal_vec(n);
    let y = rng.normal_vec(n);
    for _ in 0..8 {
        bro.update_ws(&s, &y, &mut ws);
    }
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            bro.update_ws(&s, &y, &mut ws);
        }
    });
    assert_eq!(events, 0, "update_ws<f64> allocated {events} times at steady state");
    assert_eq!(bro.rank(), 6);

    let mut bro32: BroydenInverse<f32> = BroydenInverse::new(n, 6, MemoryPolicy::Evict);
    let s32 = rng.normal_vec_f32(n, 1.0);
    let y32 = rng.normal_vec_f32(n, 1.0);
    for _ in 0..8 {
        bro32.update_ws(&s32, &y32, &mut ws32);
    }
    let (events, _) = alloc_events(|| {
        for _ in 0..16 {
            bro32.update_ws(&s32, &y32, &mut ws32);
        }
    });
    assert_eq!(events, 0, "update_ws<f32> allocated {events} times at steady state");
    assert_eq!(bro32.rank(), 6);

    // --- (4) serving path: a whole batch — batched fixed-point forward
    // (Picard and Anderson) + ONE apply_t_multi panel sweep answering every
    // cotangent — performs zero heap allocations per batch once the engine
    // is warm. Sizes stay below every thread threshold (scoped spawns
    // allocate) and tol = -1.0 pins the iteration count. The guarantee
    // holds for every panel storage layout: homogeneous f32, demoted bf16
    // and the mixed (bf16 U, f32 V) layout.
    serving_batch_is_allocation_free::<f32, f32>(SolverSpec::picard(1.0), "picard");
    serving_batch_is_allocation_free::<f32, f32>(SolverSpec::anderson(4, 1.0), "anderson");
    serving_batch_is_allocation_free::<Bf16, Bf16>(SolverSpec::picard(1.0), "picard-bf16");
    serving_batch_is_allocation_free::<Bf16, f32>(SolverSpec::picard(1.0), "picard-mixed");
}

/// Build a small f32-state serving engine with `EU`/`EV` panel storage,
/// warm it with two batches, then assert the third batch allocates nothing:
/// forward block solve, retirement bookkeeping (idx pool), the
/// shared-estimate multi-RHS backward and the fallback-guard scan all run
/// out of the engine's pools — including the widen-per-element sweeps of
/// the reduced-precision layouts.
fn serving_batch_is_allocation_free<EU: Elem, EV: Elem>(solver: SolverSpec, name: &str) {
    let d = 48usize;
    let bsz = 4usize;
    let bias: Vec<f32> = (0..d).map(|i| ((i as f32) * 0.13).cos() * 0.1).collect();
    let g_batch = |block: &[f32], _ids: &[usize], out: &mut [f32]| {
        let k = block.len() / d;
        for p in 0..k {
            for i in 0..d {
                let zn = block[p * d + (i + 1) % d];
                out[p * d + i] = block[p * d + i] - 0.3 * zn - bias[i];
            }
        }
    };
    let mut eng: ServeEngine<f32, EU, EV> = ServeEngine::new(
        d,
        EngineConfig {
            max_batch: bsz,
            // tol -1.0 is unreachable: every column runs the full budget.
            solver: solver.with_tol(-1.0).with_max_iters(12),
            calib: SolverSpec::broyden(4).with_tol(-1.0).with_max_iters(6),
            fallback_ratio: Some(1e30), // guard scan runs, never triggers
            recalib: None,
            col_budget: None,
            breaker: None,
        },
    );
    eng.calibrate(
        |z: &[f32], out: &mut [f32]| {
            for i in 0..d {
                out[i] = z[i] - 0.3 * z[(i + 1) % d] - bias[i];
            }
        },
        &vec![0.0f32; d],
    );
    let mut rng = shine::util::rng::Rng::new(17);
    let cots = rng.normal_vec_f32(bsz * d, 1.0);
    let mut zs = vec![0.0f32; bsz * d];
    let mut w = vec![0.0f32; bsz * d];
    let mut stats = vec![ColStats::default(); bsz];
    // Two warm batches populate every pool at its steady-state capacity.
    for _ in 0..2 {
        zs.iter_mut().for_each(|z| *z = 0.0);
        let rep = eng.process(&g_batch, &mut zs, &cots, &mut w, &mut stats);
        assert_eq!(rep.fwd_iters_max, 12, "{name}: full budget must run");
    }
    zs.iter_mut().for_each(|z| *z = 0.0);
    let (events, rep) =
        alloc_events(|| eng.process(&g_batch, &mut zs, &cots, &mut w, &mut stats));
    assert_eq!(
        events, 0,
        "{name} serving batch allocated {events} times after warm-up"
    );
    assert_eq!(rep.batch, bsz);
    assert_eq!(rep.fallback_cols, 0);
}
