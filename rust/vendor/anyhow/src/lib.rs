//! Minimal offline stand-in for the `anyhow` crate, API-compatible with the
//! subset this repository uses:
//!
//! * [`Error`] — message + optional source, `Display`/`Debug`, `From<E>` for
//!   any `std::error::Error` (so `?` converts),
//! * [`Result`] — `Result<T, Error>` alias with the same default-parameter
//!   shape as anyhow's,
//! * [`anyhow!`] / [`bail!`] — format-style constructors,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result<T, Error>`.
//!
//! Like the real anyhow, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl coherent.

use std::fmt;

type Source = Box<dyn std::error::Error + Send + Sync + 'static>;

/// A message-carrying error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Source>,
}

impl Error {
    /// Build from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap a concrete error value.
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }

    /// Prepend context to the message, keeping the source.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
            source: self.source,
        }
    }

    /// The wrapped source error, if any.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match &self.source {
            Some(b) => {
                let e: &(dyn std::error::Error + 'static) = b.as_ref();
                Some(e)
            }
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source();
        while let Some(e) = src {
            write!(f, "\n\ncaused by: {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>` — the crate-wide fallible return type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy or eager context to an error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.source().is_some());
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(anyhow!("inner {}", 42));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 42");
        let e2: Result<()> = Err(anyhow!("x"));
        let e2 = e2.with_context(|| format!("ctx {}", 1)).unwrap_err();
        assert_eq!(e2.to_string(), "ctx 1: x");
    }

    #[test]
    fn bail_early_returns() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("boom {flag}");
            }
            Ok(7)
        }
        assert_eq!(f(false).unwrap(), 7);
        assert_eq!(f(true).unwrap_err().to_string(), "boom true");
    }

    #[test]
    fn debug_prints_chain() {
        let e = io_fail().unwrap_err().context("loading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("loading config"));
        assert!(dbg.contains("caused by"));
    }
}
