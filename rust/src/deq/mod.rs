//! Deep Equilibrium model training system (the Fig. 3 / Tables E.1–E.3
//! experiments), built on the PJRT runtime.
//!
//! * [`native`] — pure-Rust mirror of the JAX model (f32 storage, f64 row
//!   accumulation): the numerical oracle for the integration tests and a
//!   runtime-free path for small benches. Its batched form
//!   (`native::f_theta_batch_into`) evaluates a whole k-wide serving block
//!   in one parallel region — the shape the batched solvers of
//!   [`crate::serve`] consume (per-request input injections gathered
//!   through the ids slice; wired end-to-end in
//!   `rust/tests/serve_batch.rs`).
//! * [`model`] — artifact-backed model: every entry point of
//!   `python/compile/model.py` as a typed method.
//! * [`optim`] — Adam / SGD(momentum) with cosine schedule (App. D).
//! * [`trainer`] — unrolled pre-training + equilibrium training with the
//!   backward strategy as a plug-in; per-phase timing telemetry.

pub mod model;
pub mod native;
pub mod optim;
pub mod trainer;

pub use model::{DeqModel, Params};
pub use trainer::{BackwardKind, StepStats, Trainer, TrainerConfig};
