//! Optimizers for DEQ training (App. D: Adam + cosine schedule on CIFAR,
//! SGD + momentum + cosine on ImageNet).

use crate::runtime::engine::Tensor;

/// Cosine-annealed learning rate: lr(t) = lr₀ · ½(1 + cos(π t/T)).
pub fn cosine_lr(lr0: f64, step: usize, total: usize) -> f64 {
    let t = (step as f64 / total.max(1) as f64).min(1.0);
    lr0 * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
}

pub trait Optimizer {
    /// In-place parameter update given gradients (same tensor layout).
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f64);
}

/// Adam (Kingma & Ba 2015) with bias correction.
pub struct Adam {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<Vec<f64>>,
    v: Vec<Vec<f64>>,
    t: usize,
}

impl Adam {
    pub fn new() -> Adam {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }
}

impl Default for Adam {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f64) {
        if self.m.is_empty() {
            self.m = params.iter().map(|t| vec![0.0; t.len()]).collect();
            self.v = params.iter().map(|t| vec![0.0; t.len()]).collect();
        }
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            debug_assert_eq!(p.len(), g.len());
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            for j in 0..p.data.len() {
                let gj = g.data[j] as f64;
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * gj;
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * gj * gj;
                let mhat = m[j] / b1t;
                let vhat = v[j] / b2t;
                p.data[j] -= (lr * mhat / (vhat.sqrt() + self.eps)) as f32;
            }
        }
    }
}

/// SGD with classical momentum.
pub struct Sgd {
    pub momentum: f64,
    vel: Vec<Vec<f64>>,
}

impl Sgd {
    pub fn new(momentum: f64) -> Sgd {
        Sgd {
            momentum,
            vel: Vec::new(),
        }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f64) {
        if self.vel.is_empty() {
            self.vel = params.iter().map(|t| vec![0.0; t.len()]).collect();
        }
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let vel = &mut self.vel[i];
            for j in 0..p.data.len() {
                vel[j] = self.momentum * vel[j] + g.data[j] as f64;
                p.data[j] -= (lr * vel[j]) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_grad(p: &Tensor) -> Tensor {
        // f = ½‖p − 3‖² → ∇ = p − 3
        Tensor::new(
            p.shape.clone(),
            p.data.iter().map(|&x| x - 3.0).collect(),
        )
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let mut params = vec![Tensor::new(vec![4], vec![0.0; 4])];
        let mut opt = Adam::new();
        for _ in 0..2000 {
            let g = quad_grad(&params[0]);
            opt.step(&mut params, &[g], 1e-2);
        }
        for &x in &params[0].data {
            assert!((x - 3.0).abs() < 1e-2, "x={x}");
        }
    }

    #[test]
    fn sgd_momentum_converges() {
        let mut params = vec![Tensor::new(vec![3], vec![10.0; 3])];
        let mut opt = Sgd::new(0.9);
        for _ in 0..500 {
            let g = quad_grad(&params[0]);
            opt.step(&mut params, &[g], 1e-2);
        }
        for &x in &params[0].data {
            assert!((x - 3.0).abs() < 1e-2, "x={x}");
        }
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction ⇒ first step magnitude ≈ lr regardless of grad scale.
        let mut params = vec![Tensor::new(vec![1], vec![0.0])];
        let g = Tensor::new(vec![1], vec![1e-6]);
        let mut opt = Adam::new();
        opt.step(&mut params, &[g], 0.1);
        assert!((params[0].data[0].abs() - 0.1).abs() < 1e-3);
    }

    #[test]
    fn cosine_schedule_endpoints() {
        assert!((cosine_lr(1.0, 0, 100) - 1.0).abs() < 1e-12);
        assert!(cosine_lr(1.0, 100, 100) < 1e-12);
        assert!((cosine_lr(1.0, 50, 100) - 0.5).abs() < 1e-12);
    }
}
