//! DEQ trainer: unrolled pre-training followed by equilibrium training with
//! a pluggable backward strategy — the engine behind Fig. 3 and
//! Tables E.1–E.3.
//!
//! Per step (equilibrium phase):
//! 1. `u = inject(x)` — input injection (once per batch, not per iteration);
//! 2. forward pass — Broyden root solve of `g(z) = z − f_θ(z; u) = 0` over
//!    the flattened batch fixed point (d = B·P·C), exactly the batched
//!    solving of the DEQ implementation;
//! 3. head loss + `∇_z L`;
//! 4. backward pass — the configured strategy produces
//!    `w ≈ J_g(z*)⁻ᵀ ∇_z L` (SHINE reuses the forward Broyden estimate;
//!    Original runs the iterative inversion on VJPs; etc.);
//! 5. parameter gradients by pullback: `dθ_f = wᵀ ∂f/∂θ`,
//!    `demb = (wᵀ ∂f/∂u) ∂u/∂emb`, head grads from step 3;
//! 6. Adam/SGD step with cosine LR.
//!
//! # Precision
//!
//! The whole solver path runs at **f32 storage** (`LowRank<f32>`,
//! `Workspace<f32>`, f32 panels) with f64 accumulation inside every dot —
//! the fixed point is f32 at the artifact boundary anyway, so the old
//! f64↔f32 conversion buffers around every `f`/VJP call are gone and the
//! panel sweeps of the SHINE backward move half the bytes. Residual norms,
//! tolerances and Sherman–Morrison denominators stay f64 per the
//! [`crate::linalg::vecops::Elem`] contract.

use crate::deq::model::{DeqModel, Params};
use crate::deq::native;
use crate::deq::optim::{cosine_lr, Adam, Optimizer, Sgd};
use crate::qn::low_rank::LowRank;
use crate::runtime::engine::{Engine, Tensor};
use crate::solvers::adjoint::{adjoint_broyden_solve_ws, AdjointFpOptions, SigmaChoice};
use crate::solvers::session::{
    Backward, BackwardSpec, FallbackBackward, ForwardHandle, FullBackward, JacobianFreeBackward,
    RefineBackward, RefineSeed, Session, ShineBackward, SolverSpec,
};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::cell::RefCell;

/// Backward-pass strategy for the DEQ (the Fig. 3 method axis).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackwardKind {
    /// Original method: iterative inversion (Broyden on VJPs) to `tol`,
    /// capped at `max_iters` ("limited backprop" when small).
    Original { tol: f64, max_iters: usize },
    JacobianFree,
    Shine,
    /// SHINE with the §3 fallback guard (ImageNet setting, ratio 1.3).
    ShineFallback { ratio: f64 },
    /// refine: `iters` extra Broyden-VJP steps warm-started from SHINE.
    ShineRefine { iters: usize },
    /// refine applied to the Jacobian-Free direction (Fig. 3's
    /// "Jacobian-Free refine" points).
    JacobianFreeRefine { iters: usize },
    /// Adjoint Broyden forward solver (+ optional OPA every `freq` iters);
    /// backward = SHINE on its inverse estimate (Table E.3).
    AdjointBroyden { opa_freq: Option<usize> },
}

impl BackwardKind {
    pub fn name(&self) -> String {
        match self {
            BackwardKind::Original { max_iters, .. } if *max_iters >= 1000 => "original".into(),
            BackwardKind::Original { max_iters, .. } => format!("original-limited-{max_iters}"),
            BackwardKind::JacobianFree => "jacobian-free".into(),
            BackwardKind::Shine => "shine".into(),
            BackwardKind::ShineFallback { .. } => "shine-fallback".into(),
            BackwardKind::ShineRefine { iters } => format!("shine-refine-{iters}"),
            BackwardKind::JacobianFreeRefine { iters } => format!("jf-refine-{iters}"),
            BackwardKind::AdjointBroyden { opa_freq: None } => "shine-adj-broyden".into(),
            BackwardKind::AdjointBroyden { opa_freq: Some(f) } => {
                format!("shine-adj-broyden-opa-{f}")
            }
        }
    }

    /// Lift a CLI-level [`BackwardSpec`] into the trainer's strategy with
    /// the DEQ stack's historical tolerance conventions (trainer-specific
    /// variants — adjoint Broyden, JF-refine — have no spec form and are
    /// constructed directly).
    pub fn from_spec(spec: &BackwardSpec) -> BackwardKind {
        match *spec {
            BackwardSpec::JacobianFree => BackwardKind::JacobianFree,
            BackwardSpec::Shine => BackwardKind::Shine,
            BackwardSpec::ShineFallback { ratio } => BackwardKind::ShineFallback { ratio },
            BackwardSpec::ShineRefine { iters } => BackwardKind::ShineRefine { iters },
            BackwardSpec::Full { tol, max_iters } => BackwardKind::Original { tol, max_iters },
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub variant: String,
    pub backward: BackwardKind,
    /// forward residual tolerance, relative to √d (MDEQ convention)
    pub fwd_tol: f64,
    pub fwd_max_iters: usize,
    /// Broyden memory (paper: 30)
    pub memory: usize,
    pub lr: f64,
    pub use_adam: bool,
    /// total optimizer steps for the cosine schedule
    pub total_steps: usize,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            variant: "cifar".into(),
            backward: BackwardKind::Shine,
            fwd_tol: 1e-4,
            fwd_max_iters: 30,
            memory: 30,
            lr: 1e-3,
            use_adam: true,
            total_steps: 1000,
            seed: 0,
        }
    }
}

/// Telemetry for one training step (feeds Table E.2 medians).
#[derive(Clone, Debug)]
pub struct StepStats {
    pub loss: f64,
    pub fwd_seconds: f64,
    pub bwd_seconds: f64,
    pub fwd_iters: usize,
    pub fwd_residual: f64,
    pub bwd_matvecs: usize,
    pub fallback_used: bool,
}

/// Result of a forward solve: flattened f32 fixed point + inverse estimate
/// (f32 panels — exactly what the f32 cotangent path applies).
pub struct ForwardOutcome {
    pub z: Vec<f32>,
    pub h: LowRank<f32>,
    pub iters: usize,
    pub residual: f64,
    pub seconds: f64,
}

pub struct Trainer<'e> {
    pub model: DeqModel<'e>,
    pub params: Params,
    opt: Box<dyn Optimizer>,
    pub cfg: TrainerConfig,
    pub step_count: usize,
    pub stats: Vec<StepStats>,
    /// Solve session shared across every forward/backward pass of this
    /// trainer (the session-API home of the scratch arena — the solver
    /// loops are allocation-free once it is warm). f32 storage pool + f64
    /// accumulator pool, matching the artifact precision. RefCell because
    /// forward/backward run behind `&self` (evaluation).
    sess: RefCell<Session<f32>>,
}

impl<'e> Trainer<'e> {
    pub fn new(eng: &'e Engine, cfg: TrainerConfig) -> Result<Trainer<'e>> {
        let model = DeqModel::new(eng, &cfg.variant)?;
        let mut rng = Rng::new(cfg.seed ^ 0xDE9);
        let params = Params::init(&model.v, &mut rng);
        let opt: Box<dyn Optimizer> = if cfg.use_adam {
            Box::new(Adam::new())
        } else {
            Box::new(Sgd::new(0.9))
        };
        Ok(Trainer {
            model,
            params,
            opt,
            cfg,
            step_count: 0,
            stats: Vec::new(),
            sess: RefCell::new(Session::new()),
        })
    }

    fn lr_now(&self) -> f64 {
        cosine_lr(self.cfg.lr, self.step_count, self.cfg.total_steps)
    }

    /// One unrolled pre-training step (App. D). Returns the loss.
    pub fn pretrain_step(&mut self, x: &[f32], labels: &[usize]) -> Result<f64> {
        let y = native::one_hot(labels, self.model.v.n_classes);
        let (loss, grads) = self.model.pretrain_grads(&self.params, x, &y)?;
        let lr = self.lr_now();
        self.opt.step(&mut self.params.tensors, &grads, lr);
        self.step_count += 1;
        Ok(loss)
    }

    /// Forward pass: Broyden solve of z = f(z; u) through the session API
    /// (`SolverSpec::broyden` → `FixedPointSolver::solve`), whose
    /// [`SolveOutcome`](crate::solvers::session::SolveOutcome) hands back
    /// the captured inverse-estimate handle — the SHINE share. The residual
    /// closure hands the solver's f32 iterate straight to the artifact call
    /// — no conversion buffers, no casts — and the solver runs at f32
    /// storage on the trainer's shared session.
    pub fn forward_solve(&self, u: &[f32]) -> Result<ForwardOutcome> {
        let d = self.model.v.fixed_point_dim;
        let sw = Stopwatch::start();
        let tol = self.cfg.fwd_tol * (d as f64).sqrt();
        let mut sess = self.sess.borrow_mut();
        // g(z) = z − f(z; u), f32 end-to-end.
        let mut err: Option<anyhow::Error> = None;
        let mut g = |z: &[f32], out: &mut [f32]| match self.model.f(&self.params, z, u) {
            Ok(f) => {
                for i in 0..z.len() {
                    out[i] = z[i] - f[i];
                }
            }
            Err(e) => {
                err = Some(e);
                out.iter_mut().for_each(|o| *o = 0.0);
            }
        };
        let res = match self.cfg.backward {
            BackwardKind::AdjointBroyden { opa_freq } => {
                // Forward with Adjoint Broyden (needs VJPs). This solver is
                // outside the SolverSpec family (Theorem 4 machinery), so it
                // runs on the session's raw workspace.
                let vjp = |z: &[f32], sigma: &[f32], out: &mut [f32]| {
                    match self.model.f_vjp_z(&self.params, z, u, sigma) {
                        Ok(j) => {
                            for i in 0..sigma.len() {
                                out[i] = sigma[i] - j[i];
                            }
                        }
                        Err(_) => out.copy_from_slice(sigma),
                    }
                };
                let opts = AdjointFpOptions {
                    tol,
                    max_iters: self.cfg.fwd_max_iters,
                    memory: self.cfg.memory,
                    sigma: SigmaChoice::Step,
                    opa_freq,
                };
                // OPA needs ∇L(z_n); the trainer provides it lazily through
                // the most recent head gradient — a fixed approximation that
                // avoids per-iteration head evaluations (cheap and faithful:
                // the direction only steers *extra* updates).
                let r = adjoint_broyden_solve_ws(
                    &mut g,
                    vjp,
                    None,
                    &vec![0.0f32; d],
                    &opts,
                    sess.workspace(),
                );
                ForwardOutcome {
                    z: r.z,
                    h: r.qn.low_rank().clone(),
                    iters: r.iters,
                    residual: r.g_norm,
                    seconds: sw.elapsed(),
                }
            }
            _ => {
                let spec = SolverSpec::broyden(self.cfg.memory)
                    .with_tol(tol)
                    .with_max_iters(self.cfg.fwd_max_iters);
                let mut solver = spec.build::<f32>();
                let out = solver.solve(&mut sess, &mut g, &vec![0.0f32; d]);
                ForwardOutcome {
                    z: out.z,
                    h: out
                        .estimate
                        .expect("Broyden outcome carries the SHINE estimate")
                        .into_low_rank(),
                    iters: out.iters,
                    residual: out.residual,
                    seconds: sw.elapsed(),
                }
            }
        };
        if let Some(e) = err {
            return Err(e);
        }
        Ok(res)
    }

    /// Backward pass: lower the configured [`BackwardKind`] to its
    /// [`Backward`] trait object and run it against the forward estimate
    /// handle — "share the inverse estimate" as a type-level contract, the
    /// same objects the bi-level stack and serving tier use. Entirely in
    /// f32 storage (the head gradient arrives as f32, the f32 panels apply
    /// it, and the result feeds the f32 pullback artifact — zero casts on
    /// the cotangent path). Returns (w, matvecs, fallback_used).
    pub fn backward_direction(
        &self,
        fwd: &ForwardOutcome,
        u: &[f32],
        dz: &[f32],
    ) -> (Vec<f32>, usize, bool) {
        let d = dz.len();
        let mut sess = self.sess.borrow_mut();
        let mut vjp = |w: &[f32], out: &mut [f32]| {
            match self.model.f_vjp_z(&self.params, &fwd.z, u, w) {
                Ok(j) => {
                    for i in 0..w.len() {
                        out[i] = w[i] - j[i];
                    }
                }
                Err(_) => out.copy_from_slice(w),
            }
        };
        let refine_tol = 1e-12 * (d as f64).sqrt().max(1.0);
        let mut backward: Box<dyn Backward<f32>> = match self.cfg.backward {
            BackwardKind::JacobianFree => Box::new(JacobianFreeBackward),
            // Adjoint Broyden's backward *is* SHINE on its own estimate.
            BackwardKind::Shine | BackwardKind::AdjointBroyden { .. } => Box::new(ShineBackward),
            BackwardKind::ShineFallback { ratio } => Box::new(FallbackBackward { ratio }),
            BackwardKind::Original { tol, max_iters } => {
                // Cap the budget like the bi-level path does: `--backward
                // full` spells an unbounded solve as usize::MAX, which must
                // not overflow the `+ 8` memory headroom.
                let mi = max_iters.min(100_000);
                Box::new(FullBackward {
                    tol,
                    max_iters: mi,
                    max_mem: mi + 8,
                    symmetric: false,
                })
            }
            BackwardKind::ShineRefine { iters } => Box::new(RefineBackward {
                iters,
                tol: refine_tol,
                max_mem: self.cfg.memory + iters + 8,
                seed: RefineSeed::Estimate,
                symmetric: false,
            }),
            BackwardKind::JacobianFreeRefine { iters } => Box::new(RefineBackward {
                iters,
                tol: refine_tol,
                max_mem: iters + 8,
                seed: RefineSeed::Identity,
                symmetric: false,
            }),
        };
        let handle = ForwardHandle {
            inv: Some(&fwd.h),
            low_rank: Some(&fwd.h),
        };
        let out = backward.direction(&mut sess, handle, dz, &mut vjp, None);
        (out.w, out.matvecs, out.fallback_used)
    }

    /// One equilibrium training step.
    pub fn train_step(&mut self, x: &[f32], labels: &[usize]) -> Result<StepStats> {
        let v = &self.model.v;
        let y = native::one_hot(labels, v.n_classes);
        let u = self.model.inject(&self.params, x)?;
        let fwd = self.forward_solve(&u)?;

        let sw = Stopwatch::start();
        let (loss, dz, dwhead, dbhead) = self.model.head_loss_grad(&self.params, &fwd.z, &y)?;
        let (w, matvecs, fallback_used) = self.backward_direction(&fwd, &u, &dz);
        // dθ_f = wᵀ ∂f/∂θ  (sign: dL/dθ = −wᵀ∂g/∂θ = +wᵀ∂f/∂θ since g = z−f)
        let (fgrads, du) = self.model.f_vjp_params_u(&self.params, &fwd.z, &u, &w)?;
        let (dwemb, dbemb) = self.model.inject_vjp(&self.params, x, &du)?;
        let bwd_seconds = sw.elapsed();

        // Assemble gradients in canonical parameter order.
        let mut grads: Vec<Tensor> = Vec::with_capacity(10);
        grads.push(dwemb);
        grads.push(dbemb);
        for gt in fgrads {
            grads.push(gt);
        }
        grads.push(dwhead);
        grads.push(dbhead);
        debug_assert_eq!(grads.len(), self.params.tensors.len());

        let lr = self.lr_now();
        self.opt.step(&mut self.params.tensors, &grads, lr);
        self.step_count += 1;

        let stats = StepStats {
            loss,
            fwd_seconds: fwd.seconds,
            bwd_seconds,
            fwd_iters: fwd.iters,
            fwd_residual: fwd.residual,
            bwd_matvecs: matvecs,
            fallback_used,
        };
        self.stats.push(stats.clone());
        Ok(stats)
    }

    /// Top-1 accuracy over up to `max_batches` batches of the dataset.
    pub fn evaluate(
        &self,
        ds: &crate::data::synth_images::ImageDataset,
        max_batches: usize,
        rng: &mut Rng,
    ) -> Result<f64> {
        let v = &self.model.v;
        let batches = ds.epoch_batches(v.batch, rng);
        let mut total = 0.0;
        let mut n = 0;
        for idx in batches.iter().take(max_batches) {
            let (x, labels) = ds.batch(idx);
            let u = self.model.inject(&self.params, &x)?;
            let fwd = self.forward_solve(&u)?;
            let logits = self.model.head_logits(&self.params, &fwd.z)?;
            total += native::accuracy(&logits, &labels, v.n_classes);
            n += 1;
        }
        Ok(if n == 0 { 0.0 } else { total / n as f64 })
    }
}
