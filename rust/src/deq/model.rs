//! Artifact-backed DEQ model: one typed method per AOT entry point.
//!
//! Parameter state lives in Rust ([`Params`]); each call ships the needed
//! parameters + activations to PJRT and gets f32 tensors back. Parameter
//! order follows the manifest (`param_names`), mirrored from
//! python/compile/model.py.

use crate::runtime::engine::{Engine, Tensor};
use crate::runtime::manifest::VariantCfg;
use crate::util::rng::Rng;
use anyhow::Result;

/// Model parameters in canonical order (wemb, bemb, w1, b1, w2, b2, gamma,
/// beta, whead, bhead).
#[derive(Clone, Debug)]
pub struct Params {
    pub tensors: Vec<Tensor>,
}

impl Params {
    /// He-style init matching model.init_params: gamma = 1, biases/beta = 0.
    pub fn init(v: &VariantCfg, rng: &mut Rng) -> Params {
        let tensors = v
            .param_shapes
            .iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name == "gamma" {
                    vec![1.0f32; n]
                } else if name.starts_with('b') || name == "beta" {
                    vec![0.0f32; n]
                } else {
                    let fan_in = shape[0] as f64;
                    let std = (2.0 / fan_in).sqrt() as f32;
                    rng.normal_vec_f32(n, std)
                };
                Tensor::new(shape.clone(), data)
            })
            .collect();
        Params { tensors }
    }

    pub fn get<'a>(&'a self, v: &VariantCfg, name: &str) -> &'a Tensor {
        &self.tensors[v.param_index(name)]
    }

    /// The six f_theta parameters, in artifact order.
    pub fn f_params(&self, v: &VariantCfg) -> Vec<Tensor> {
        v.f_param_names
            .iter()
            .map(|n| self.get(v, n).clone())
            .collect()
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Native-path view (slices in canonical order).
    pub fn native<'a>(&'a self, v: &VariantCfg) -> crate::deq::native::NativeParams<'a> {
        crate::deq::native::NativeParams {
            wemb: &self.get(v, "wemb").data,
            bemb: &self.get(v, "bemb").data,
            w1: &self.get(v, "w1").data,
            b1: &self.get(v, "b1").data,
            w2: &self.get(v, "w2").data,
            b2: &self.get(v, "b2").data,
            gamma: &self.get(v, "gamma").data,
            beta: &self.get(v, "beta").data,
            whead: &self.get(v, "whead").data,
            bhead: &self.get(v, "bhead").data,
        }
    }
}

/// The artifact-backed model for one variant.
pub struct DeqModel<'e> {
    pub eng: &'e Engine,
    pub v: VariantCfg,
}

impl<'e> DeqModel<'e> {
    pub fn new(eng: &'e Engine, variant: &str) -> Result<DeqModel<'e>> {
        let v = eng.manifest.variant(variant)?.clone();
        Ok(DeqModel { eng, v })
    }

    fn art(&self, entry: &str) -> String {
        format!("{}_{}", self.v.name, entry)
    }

    fn z_tensor(&self, z: &[f32]) -> Tensor {
        Tensor::new(self.v.z_shape(), z.to_vec())
    }

    /// u = inject(x); x is (B, h·w·c_in) flattened images.
    pub fn inject(&self, p: &Params, x: &[f32]) -> Result<Vec<f32>> {
        let out = self.eng.call(
            &self.art("inject"),
            &[
                p.get(&self.v, "wemb").clone(),
                p.get(&self.v, "bemb").clone(),
                Tensor::new(self.v.x_shape(), x.to_vec()),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// f_θ(z; u) — the fixed-point map (one Broyden iteration's work).
    pub fn f(&self, p: &Params, z: &[f32], u: &[f32]) -> Result<Vec<f32>> {
        let mut inputs = p.f_params(&self.v);
        inputs.push(self.z_tensor(z));
        inputs.push(self.z_tensor(u));
        let out = self.eng.call(&self.art("f_fwd"), &inputs)?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// vᵀ ∂f/∂z — the backward VJP (one iteration of the Original method).
    pub fn f_vjp_z(&self, p: &Params, z: &[f32], u: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let mut inputs = p.f_params(&self.v);
        inputs.push(self.z_tensor(z));
        inputs.push(self.z_tensor(u));
        inputs.push(self.z_tensor(v));
        let out = self.eng.call(&self.art("f_vjp_z"), &inputs)?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// ∂f/∂z · v — forward-mode JVP (power method, Table E.1).
    pub fn f_jvp(&self, p: &Params, z: &[f32], u: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let mut inputs = p.f_params(&self.v);
        inputs.push(self.z_tensor(z));
        inputs.push(self.z_tensor(u));
        inputs.push(self.z_tensor(v));
        let out = self.eng.call(&self.art("f_jvp"), &inputs)?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// (w1..beta grads, du) = pullback of f at cotangent w.
    pub fn f_vjp_params_u(
        &self,
        p: &Params,
        z: &[f32],
        u: &[f32],
        w: &[f32],
    ) -> Result<(Vec<Tensor>, Vec<f32>)> {
        let mut inputs = p.f_params(&self.v);
        inputs.push(self.z_tensor(z));
        inputs.push(self.z_tensor(u));
        inputs.push(self.z_tensor(w));
        let mut out = self.eng.call(&self.art("f_vjp_params_u"), &inputs)?;
        let du = out.pop().unwrap().data;
        Ok((out, du))
    }

    /// (dwemb, dbemb) = pullback of inject at cotangent du.
    pub fn inject_vjp(&self, p: &Params, x: &[f32], du: &[f32]) -> Result<(Tensor, Tensor)> {
        let out = self.eng.call(
            &self.art("inject_vjp"),
            &[
                p.get(&self.v, "wemb").clone(),
                p.get(&self.v, "bemb").clone(),
                Tensor::new(self.v.x_shape(), x.to_vec()),
                self.z_tensor(du),
            ],
        )?;
        let mut it = out.into_iter();
        Ok((it.next().unwrap(), it.next().unwrap()))
    }

    /// logits (B, K).
    pub fn head_logits(&self, p: &Params, z: &[f32]) -> Result<Vec<f32>> {
        let out = self.eng.call(
            &self.art("head_logits"),
            &[
                p.get(&self.v, "whead").clone(),
                p.get(&self.v, "bhead").clone(),
                self.z_tensor(z),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().data)
    }

    /// (loss, ∇_z L, dwhead, dbhead) on one batch.
    pub fn head_loss_grad(
        &self,
        p: &Params,
        z: &[f32],
        y_onehot: &[f32],
    ) -> Result<(f64, Vec<f32>, Tensor, Tensor)> {
        let out = self.eng.call(
            &self.art("head_loss_grad"),
            &[
                p.get(&self.v, "whead").clone(),
                p.get(&self.v, "bhead").clone(),
                self.z_tensor(z),
                Tensor::new(self.v.y_shape(), y_onehot.to_vec()),
            ],
        )?;
        let mut it = out.into_iter();
        let loss = it.next().unwrap().data[0] as f64;
        let dz = it.next().unwrap().data;
        let dwh = it.next().unwrap();
        let dbh = it.next().unwrap();
        Ok((loss, dz, dwh, dbh))
    }

    /// Unrolled pre-training step: (loss, grads for all 10 params).
    pub fn pretrain_grads(
        &self,
        p: &Params,
        x: &[f32],
        y_onehot: &[f32],
    ) -> Result<(f64, Vec<Tensor>)> {
        let mut inputs: Vec<Tensor> = p.tensors.clone();
        inputs.push(Tensor::new(self.v.x_shape(), x.to_vec()));
        inputs.push(Tensor::new(self.v.y_shape(), y_onehot.to_vec()));
        let mut out = self.eng.call(&self.art("pretrain_grads"), &inputs)?;
        let grads = out.split_off(1);
        let loss = out[0].data[0] as f64;
        Ok((loss, grads))
    }

    /// Low-rank (SHINE) application through the L1 Pallas artifact:
    /// out = v + Uᵀ(V v) with U, V of shape (30, d).
    pub fn lowrank_apply(&self, v: &[f32], us: &[f32], vs: &[f32]) -> Result<Vec<f32>> {
        let d = self.v.fixed_point_dim;
        let out = self.eng.call(
            &self.art("lowrank_apply"),
            &[
                Tensor::new(vec![d], v.to_vec()),
                Tensor::new(vec![30, d], us.to_vec()),
                Tensor::new(vec![30, d], vs.to_vec()),
            ],
        )?;
        Ok(out.into_iter().next().unwrap().data)
    }
}
