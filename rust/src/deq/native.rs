//! Pure-Rust mirror of the JAX DEQ model (python/compile/model.py).
//!
//! Bit-for-bit architecture parity (patchify layout, LayerNorm eps, pooling,
//! softmax CE). Everything at this boundary speaks **f32 storage with f64
//! accumulation** — the same contract as the precision-generic qN stack
//! ([`crate::linalg::vecops::Elem`]): inputs/outputs are f32 tensors, while
//! each row's matmul/LayerNorm reductions are carried in f64 before the
//! single narrowing write. Since the solver stack runs at `E = f32`, the
//! residual/cotangent path between this module and the panel kernels is
//! cast-free end-to-end (the trainer hands solver iterates straight to
//! `f_theta`/VJP calls). The integration tests assert the PJRT artifacts
//! agree with this mirror to f32 tolerance on random inputs — the strongest
//! end-to-end check that the three-layer stack computes the model the
//! paper's math assumes.

use crate::runtime::manifest::VariantCfg;

const LN_EPS: f64 = 1e-5;

/// Named parameter access for the native path: slices in canonical order.
pub struct NativeParams<'a> {
    pub wemb: &'a [f32],
    pub bemb: &'a [f32],
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: &'a [f32],
    pub gamma: &'a [f32],
    pub beta: &'a [f32],
    pub whead: &'a [f32],
    pub bhead: &'a [f32],
}

/// patchify + embed: x (B, h·w·c_in) → u (B, P, C).
pub fn inject(v: &VariantCfg, wemb: &[f32], bemb: &[f32], x: &[f32]) -> Vec<f32> {
    let (b, h, w, cin, s, c) = (v.batch, v.h, v.w, v.c_in, v.patch, v.c);
    let cp = v.patch_channels;
    let p = v.pixels;
    let wpatches = w / s;
    let mut u = vec![0.0f32; b * p * c];
    let mut patch = vec![0.0f64; cp];
    for bi in 0..b {
        for pi in 0..p {
            let py = pi / wpatches;
            let px = pi % wpatches;
            // gather the patch in the JAX layout: ((dy*s)+dx)*c_in + ci
            for dy in 0..s {
                for dx in 0..s {
                    for ci in 0..cin {
                        let yy = py * s + dy;
                        let xx = px * s + dx;
                        patch[(dy * s + dx) * cin + ci] =
                            x[bi * (h * w * cin) + yy * (w * cin) + xx * cin + ci] as f64;
                    }
                }
            }
            // u = patch @ wemb + bemb
            for cj in 0..c {
                let mut acc = bemb[cj] as f64;
                for ck in 0..cp {
                    acc += patch[ck] * wemb[ck * c + cj] as f64;
                }
                u[bi * (p * c) + pi * c + cj] = acc as f32;
            }
        }
    }
    u
}

/// One row (pixel site) of the fixed-point map — the shared body of
/// [`f_theta`] and [`f_theta_batch_into`]: h = relu(z W1 + u + b1),
/// x = z + h W2 + b2, then LayerNorm over channels, all accumulated in f64
/// before the single narrowing write per output element.
#[inline]
fn f_theta_row(
    np: &NativeParams,
    c: usize,
    zr: &[f32],
    ur: &[f32],
    hrow: &mut [f64],
    xrow: &mut [f64],
    orow: &mut [f32],
) {
    // h = relu(z W1 + u + b1)
    for j in 0..c {
        let mut acc = ur[j] as f64 + np.b1[j] as f64;
        for k in 0..c {
            acc += zr[k] as f64 * np.w1[k * c + j] as f64;
        }
        hrow[j] = acc.max(0.0);
    }
    // x = z + h W2 + b2
    for j in 0..c {
        let mut acc = zr[j] as f64 + np.b2[j] as f64;
        for k in 0..c {
            acc += hrow[k] * np.w2[k * c + j] as f64;
        }
        xrow[j] = acc;
    }
    // layer norm over channels
    let mean: f64 = xrow.iter().sum::<f64>() / c as f64;
    let var: f64 = xrow.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / c as f64;
    let inv = 1.0 / (var + LN_EPS).sqrt();
    for j in 0..c {
        orow[j] = (((xrow[j] - mean) * inv) * np.gamma[j] as f64 + np.beta[j] as f64) as f32;
    }
}

/// The fixed-point map f_θ(z; u) = LN(z + relu(z W1 + u + b1) W2 + b2).
///
/// Rows (batch × pixel sites) are independent, so above a size threshold the
/// row loop fans out over threads with whole-row chunks; per-row f64
/// accumulation makes the result bit-identical to the serial path.
pub fn f_theta(v: &VariantCfg, np: &NativeParams, z: &[f32], u: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; v.batch * v.pixels * v.c];
    f_theta_batch_into(v, np, z, u, 1, &mut out);
    out
}

/// Batched write-into form of [`f_theta`] over `k` stacked request states:
/// `zs`/`us`/`out` are `k` contiguous blocks of `batch·pixels·c` (the
/// serving engine's d × k state block). Every row of every request is
/// independent, so the whole k-wide block fans out in ONE parallel region —
/// the thread-spawn cost a single request's block may be too small to
/// amortize is paid once per batch iteration instead of once per request.
/// Per-row f64 accumulation keeps the result bit-identical to `k`
/// independent [`f_theta`] calls at any worker count.
pub fn f_theta_batch_into(
    v: &VariantCfg,
    np: &NativeParams,
    zs: &[f32],
    us: &[f32],
    k: usize,
    out: &mut [f32],
) {
    let c = v.c;
    let rows = v.batch * v.pixels * k;
    debug_assert_eq!(zs.len(), rows * c);
    debug_assert_eq!(us.len(), rows * c);
    debug_assert_eq!(out.len(), rows * c);
    let workers = crate::util::threads::workers_for(rows * c, 1 << 14, 8);
    crate::util::threads::par_row_chunks_mut(out, c, workers, |row0, chunk| {
        let mut hrow = vec![0.0f64; c];
        let mut xrow = vec![0.0f64; c];
        for (i, orow) in chunk.chunks_exact_mut(c).enumerate() {
            let r = row0 + i;
            f_theta_row(
                np,
                c,
                &zs[r * c..(r + 1) * c],
                &us[r * c..(r + 1) * c],
                &mut hrow,
                &mut xrow,
                orow,
            );
        }
    });
}

/// Allocating convenience form of [`f_theta_batch_into`].
pub fn f_theta_batch(
    v: &VariantCfg,
    np: &NativeParams,
    zs: &[f32],
    us: &[f32],
    k: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; zs.len()];
    f_theta_batch_into(v, np, zs, us, k, &mut out);
    out
}

/// logits (B, K) from z (B, P, C): mean-pool over P then linear head.
pub fn head_logits(v: &VariantCfg, whead: &[f32], bhead: &[f32], z: &[f32]) -> Vec<f32> {
    let (b, p, c, k) = (v.batch, v.pixels, v.c, v.n_classes);
    let mut logits = vec![0.0f32; b * k];
    let mut pooled = vec![0.0f64; c];
    for bi in 0..b {
        for cj in 0..c {
            pooled[cj] = 0.0;
        }
        for pi in 0..p {
            for cj in 0..c {
                pooled[cj] += z[bi * (p * c) + pi * c + cj] as f64;
            }
        }
        for cj in 0..c {
            pooled[cj] /= p as f64;
        }
        for kj in 0..k {
            let mut acc = bhead[kj] as f64;
            for cj in 0..c {
                acc += pooled[cj] * whead[cj * k + kj] as f64;
            }
            logits[bi * k + kj] = acc as f32;
        }
    }
    logits
}

/// Mean softmax cross-entropy given one-hot labels (B, K).
pub fn ce_loss(logits: &[f32], y_onehot: &[f32], b: usize, k: usize) -> f64 {
    let mut total = 0.0f64;
    for bi in 0..b {
        let row = &logits[bi * k..(bi + 1) * k];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let logsum: f64 = (row.iter().map(|&l| ((l as f64) - max).exp()).sum::<f64>()).ln() + max;
        for kj in 0..k {
            if y_onehot[bi * k + kj] > 0.0 {
                total += (logsum - row[kj] as f64) * y_onehot[bi * k + kj] as f64;
            }
        }
    }
    total / b as f64
}

/// Top-1 accuracy of logits against integer labels.
pub fn accuracy(logits: &[f32], labels: &[usize], k: usize) -> f64 {
    let b = labels.len();
    let mut correct = 0usize;
    for bi in 0..b {
        let row = &logits[bi * k..(bi + 1) * k];
        let mut best = 0;
        for j in 1..k {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[bi] {
            correct += 1;
        }
    }
    correct as f64 / b as f64
}

/// One-hot encode labels to (B, K) f32.
pub fn one_hot(labels: &[usize], k: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; labels.len() * k];
    for (i, &l) in labels.iter().enumerate() {
        y[i * k + l] = 1.0;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> VariantCfg {
        VariantCfg {
            name: "tiny".into(),
            batch: 2,
            h: 4,
            w: 4,
            c_in: 3,
            patch: 2,
            c: 8,
            n_classes: 4,
            unroll: 4,
            pixels: 4,
            patch_channels: 12,
            fixed_point_dim: 2 * 4 * 8,
            param_shapes: vec![],
            f_param_names: vec![],
        }
    }

    #[test]
    fn layer_norm_inside_f_theta_normalizes() {
        let v = tiny_cfg();
        let c = v.c;
        let rows = v.batch * v.pixels;
        let mut rng = crate::util::rng::Rng::new(1);
        let z: Vec<f32> = (0..rows * c).map(|_| rng.normal() as f32).collect();
        let u: Vec<f32> = (0..rows * c).map(|_| rng.normal() as f32).collect();
        let w1: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
        let w2: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
        let zeros = vec![0.0f32; c];
        let ones = vec![1.0f32; c];
        let np = NativeParams {
            wemb: &[],
            bemb: &[],
            w1: &w1,
            b1: &zeros,
            w2: &w2,
            b2: &zeros,
            gamma: &ones,
            beta: &zeros,
            whead: &[],
            bhead: &[],
        };
        let out = f_theta(&v, &np, &z, &u);
        // Every row of out must have ~zero mean and ~unit variance.
        for r in 0..rows {
            let row = &out[r * c..(r + 1) * c];
            let mean: f64 = row.iter().map(|&x| x as f64).sum::<f64>() / c as f64;
            let var: f64 =
                row.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / c as f64;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn patchify_covers_all_pixels() {
        let v = tiny_cfg();
        // wemb = identity-ish: embed dim == patch dim is not true (12 vs 8),
        // so instead check inject sums: with wemb all-ones and bemb 0, every
        // u entry equals the patch sum.
        let wemb = vec![1.0f32; v.patch_channels * v.c];
        let bemb = vec![0.0f32; v.c];
        let x: Vec<f32> = (0..v.batch * v.h * v.w * v.c_in)
            .map(|i| i as f32)
            .collect();
        let u = inject(&v, &wemb, &bemb, &x);
        // Each patch sum equals u[b,p,0] (all output channels identical).
        for bi in 0..v.batch {
            for pi in 0..v.pixels {
                let u0 = u[bi * v.pixels * v.c + pi * v.c];
                for cj in 1..v.c {
                    assert_eq!(u[bi * v.pixels * v.c + pi * v.c + cj], u0);
                }
            }
        }
        // Total: sum over all u channels/c == sum of x per batch.
        let total_x: f64 = x.iter().map(|&v| v as f64).sum();
        let total_u: f64 = u.iter().map(|&v| v as f64).sum::<f64>() / v.c as f64;
        assert!((total_x - total_u).abs() / total_x < 1e-5);
    }

    #[test]
    fn f_theta_batch_matches_stacked_singles() {
        // k stacked requests through one batched evaluation must equal k
        // independent f_theta calls bit-for-bit (per-row f64 accumulation is
        // worker-count independent).
        let v = tiny_cfg();
        let c = v.c;
        let d = v.batch * v.pixels * c;
        let k = 3;
        let mut rng = crate::util::rng::Rng::new(7);
        let zs: Vec<f32> = (0..k * d).map(|_| rng.normal() as f32).collect();
        let us: Vec<f32> = (0..k * d).map(|_| rng.normal() as f32).collect();
        let w1: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
        let w2: Vec<f32> = (0..c * c).map(|_| (rng.normal() * 0.3) as f32).collect();
        let b1: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let b2: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let gamma: Vec<f32> = (0..c).map(|_| (1.0 + 0.1 * rng.normal()) as f32).collect();
        let beta: Vec<f32> = (0..c).map(|_| rng.normal() as f32).collect();
        let np = NativeParams {
            wemb: &[],
            bemb: &[],
            w1: &w1,
            b1: &b1,
            w2: &w2,
            b2: &b2,
            gamma: &gamma,
            beta: &beta,
            whead: &[],
            bhead: &[],
        };
        let batched = f_theta_batch(&v, &np, &zs, &us, k);
        for r in 0..k {
            let single = f_theta(&v, &np, &zs[r * d..(r + 1) * d], &us[r * d..(r + 1) * d]);
            assert_eq!(&batched[r * d..(r + 1) * d], &single[..], "request {r}");
        }
    }

    #[test]
    fn ce_loss_uniform_is_log_k() {
        let b = 3;
        let k = 4;
        let logits = vec![0.0f32; b * k];
        let y = one_hot(&[0, 1, 2], k);
        let loss = ce_loss(&logits, &y, b, k);
        assert!((loss - (k as f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn accuracy_counts() {
        let logits = vec![
            1.0, 0.0, 0.0, // -> 0
            0.0, 2.0, 0.0, // -> 1
            0.0, 0.0, 3.0, // -> 2
        ];
        assert_eq!(accuracy(&logits, &[0, 1, 0], 3), 2.0 / 3.0);
    }
}
