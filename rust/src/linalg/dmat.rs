//! Row-major dense matrix (f64) with the operations the experiments need:
//! matvec, transposed matvec, matmul, symmetric generation helpers. Small
//! dimensions only (exact-inverse ground truth, test oracles) — the large
//! DEQ matmuls live in the AOT-compiled XLA artifacts, not here.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct DMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>, // row-major
}

impl DMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DMat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = DMat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows[0].len();
        let mut m = DMat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// iid standard normal entries scaled by `std`.
    pub fn randn(rows: usize, cols: usize, std: f64, rng: &mut Rng) -> Self {
        DMat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal() * std).collect(),
        }
    }

    /// Random symmetric positive definite matrix: A = QᵀDQ with eigenvalues
    /// log-uniform in [eig_lo, eig_hi] (controls conditioning in tests).
    pub fn random_spd(n: usize, eig_lo: f64, eig_hi: f64, rng: &mut Rng) -> Self {
        // Random orthogonal Q via Gram-Schmidt on a Gaussian matrix.
        let g = DMat::randn(n, n, 1.0, rng);
        let q = g.gram_schmidt();
        let eigs: Vec<f64> = (0..n)
            .map(|_| {
                let t = rng.uniform();
                (eig_lo.ln() + t * (eig_hi.ln() - eig_lo.ln())).exp()
            })
            .collect();
        // A = Qᵀ diag(eigs) Q
        let mut dq = q.clone();
        for i in 0..n {
            for j in 0..n {
                dq[(i, j)] *= eigs[i];
            }
        }
        q.transpose().matmul(&dq)
    }

    /// Orthonormalize rows (classical Gram-Schmidt with re-orthogonalization).
    pub fn gram_schmidt(&self) -> DMat {
        let mut q = self.clone();
        let n = self.rows;
        let c = self.cols;
        for i in 0..n {
            for _pass in 0..2 {
                for j in 0..i {
                    let mut proj = 0.0;
                    for k in 0..c {
                        proj += q[(i, k)] * q[(j, k)];
                    }
                    for k in 0..c {
                        let v = q[(j, k)];
                        q[(i, k)] -= proj * v;
                    }
                }
            }
            let mut nrm = 0.0;
            for k in 0..c {
                nrm += q[(i, k)] * q[(i, k)];
            }
            let nrm = nrm.sqrt().max(1e-300);
            for k in 0..c {
                q[(i, k)] /= nrm;
            }
        }
        q
    }

    /// out = A x
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            out[i] = crate::linalg::vecops::dot(row, x);
        }
    }

    /// out = Aᵀ x
    pub fn matvec_t(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        crate::linalg::vecops::zero(out);
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            for j in 0..self.cols {
                out[j] += xi * row[j];
            }
        }
    }

    pub fn matmul(&self, other: &DMat) -> DMat {
        assert_eq!(self.cols, other.rows);
        let mut out = DMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += aik * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> DMat {
        let mut t = DMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

impl std::ops::Index<(usize, usize)> for DMat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DMat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matvec_and_transpose() {
        let a = DMat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let mut y = vec![0.0; 3];
        a.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0, 11.0]);
        let mut z = vec![0.0; 2];
        a.matvec_t(&[1.0, 1.0, 1.0], &mut z);
        assert_eq!(z, vec![9.0, 12.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = DMat::randn(4, 4, 1.0, &mut rng);
        let i4 = DMat::eye(4);
        let prod = a.matmul(&i4);
        for (x, y) in prod.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::new(3);
        let g = DMat::randn(6, 6, 1.0, &mut rng);
        let q = g.gram_schmidt();
        let qqt = q.matmul(&q.transpose());
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((qqt[(i, j)] - expect).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn spd_is_symmetric_positive() {
        prop::check("spd", 10, |rng| {
            let a = DMat::random_spd(8, 0.1, 10.0, rng);
            for i in 0..8 {
                for j in 0..8 {
                    prop::ensure_close(a[(i, j)], a[(j, i)], 1e-9, "symmetry")?;
                }
            }
            // xᵀAx > 0 for random x.
            let x = rng.normal_vec(8);
            let mut ax = vec![0.0; 8];
            a.matvec(&x, &mut ax);
            prop::ensure(crate::linalg::vecops::dot(&x, &ax) > 0.0, "pos def")
        });
    }
}
