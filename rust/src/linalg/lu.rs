//! Partial-pivot LU factorization + solve.
//!
//! Used exclusively for *ground truth*: the inversion-quality experiments
//! (Fig. 2-right, Fig. E.3-reduced) compare the quasi-Newton inverse estimate
//! against the exact `J⁻¹ v` computed by a dense solve on a small problem.

use crate::linalg::dmat::DMat;

/// LU factorization with row pivoting. Holds L\U packed + permutation.
pub struct Lu {
    lu: DMat,
    piv: Vec<usize>,
    n: usize,
}

#[derive(Debug)]
pub struct SingularError(pub usize);

impl std::fmt::Display for SingularError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "matrix is singular at pivot {}", self.0)
    }
}

impl std::error::Error for SingularError {}

impl Lu {
    /// Factor a square matrix. O(n³).
    pub fn factor(a: &DMat) -> Result<Lu, SingularError> {
        assert_eq!(a.rows, a.cols, "LU requires square matrix");
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Pivot: largest |value| in column k at/below diagonal.
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > best {
                    best = v;
                    p = i;
                }
            }
            if best < 1e-300 {
                return Err(SingularError(k));
            }
            if p != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
                piv.swap(k, p);
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let factor = lu[(i, k)] / pivot;
                lu[(i, k)] = factor;
                for j in (k + 1)..n {
                    let v = lu[(k, j)];
                    lu[(i, j)] -= factor * v;
                }
            }
        }
        Ok(Lu { lu, piv, n })
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // Forward substitution (L has unit diagonal).
        for i in 1..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in (i + 1)..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        x
    }

    /// Solve Aᵀ x = b (needed for the left-inverse direction `J⁻ᵀ ∇L`).
    pub fn solve_t(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        // A = P⁻¹ L U  ⇒  Aᵀ = Uᵀ Lᵀ P  ⇒ solve Uᵀ y = b, Lᵀ z = y, x = Pᵀ z.
        // Forward substitution with Uᵀ (lower triangular with diag of U).
        for i in 0..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= self.lu[(j, i)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        // Back substitution with Lᵀ (upper triangular, unit diagonal).
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in (i + 1)..n {
                acc -= self.lu[(j, i)] * x[j];
            }
            x[i] = acc;
        }
        // Undo permutation: x = Pᵀ z  (z was indexed in permuted row order).
        let mut out = vec![0.0; n];
        for (i, &p) in self.piv.iter().enumerate() {
            out[p] = x[i];
        }
        out
    }

    /// Dense inverse (test/oracle use only).
    pub fn inverse(&self) -> DMat {
        let n = self.n;
        let mut inv = DMat::zeros(n, n);
        let mut e = vec![0.0; n];
        for j in 0..n {
            e[j] = 1.0;
            let col = self.solve(&e);
            for i in 0..n {
                inv[(i, j)] = col[i];
            }
            e[j] = 0.0;
        }
        inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::dist2;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn solves_known_system() {
        let a = DMat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve(&[3.0, 5.0]);
        // 2x + y = 3; x + 3y = 5 → x = 4/5, y = 7/5
        assert!((x[0] - 0.8).abs() < 1e-12);
        assert!((x[1] - 1.4).abs() < 1e-12);
    }

    #[test]
    fn detects_singular() {
        let a = DMat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn property_solve_roundtrip() {
        prop::check("lu-roundtrip", 20, |rng| {
            let n = 3 + rng.below(12);
            let a = DMat::randn(n, n, 1.0, rng);
            let x_true = rng.normal_vec(n);
            let mut b = vec![0.0; n];
            a.matvec(&x_true, &mut b);
            let lu = match Lu::factor(&a) {
                Ok(l) => l,
                Err(_) => return Ok(()), // exceedingly unlikely random singular
            };
            let x = lu.solve(&b);
            prop::ensure(dist2(&x, &x_true) < 1e-6 * (1.0 + crate::linalg::vecops::nrm2(&x_true)), "roundtrip")
        });
    }

    #[test]
    fn property_transpose_solve() {
        prop::check("lu-transpose", 20, |rng| {
            let n = 3 + rng.below(10);
            let a = DMat::randn(n, n, 1.0, rng);
            let x_true = rng.normal_vec(n);
            let mut b = vec![0.0; n];
            a.matvec_t(&x_true, &mut b); // b = Aᵀ x_true
            let lu = match Lu::factor(&a) {
                Ok(l) => l,
                Err(_) => return Ok(()),
            };
            let x = lu.solve_t(&b);
            prop::ensure_close_vec(&x, &x_true, 1e-6, "Aᵀx=b solve")
        });
    }

    #[test]
    fn inverse_matches_identity() {
        let mut rng = Rng::new(5);
        let a = DMat::random_spd(6, 0.5, 5.0, &mut rng);
        let inv = Lu::factor(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - expect).abs() < 1e-8);
            }
        }
    }
}
