//! BLAS-1 style vector kernels over plain slices (f64 for the optimization
//! stack, a few f32 variants for the DEQ/artifact path). These are the hot
//! inner loops of the quasi-Newton updates; they are written allocation-free
//! and auto-vectorize cleanly (verified in the §Perf pass).

/// dot(a, b)
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = x
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = a - b
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// out = a + b
#[inline]
pub fn add(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// ||x||_2
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ||a - b||_2
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc.sqrt()
}

/// Fill with zeros.
#[inline]
pub fn zero(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

// ---- panel (flat row-major m×d) kernels -----------------------------------
//
// These two primitives are the whole of SHINE's O(m·d) backward cost once the
// factors live in a `FactorPanel`: `H x = x + Uᵀ (V x)` is one `panel_gemv`
// (the coefficient sweep `c = V x`) followed by one `panel_gemv_t` (the
// accumulation sweep `out += Uᵀ c`). Both stream the panel front to back, so
// they run at memory bandwidth and auto-vectorize.

/// `coeffs[i] = Σ_j panel[i·dim + j] · x[j]` for `i in 0..rows`
/// (row-major panel–vector products; phase 1 of the low-rank apply).
#[inline]
pub fn panel_gemv(panel: &[f64], rows: usize, dim: usize, x: &[f64], coeffs: &mut [f64]) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(x.len(), dim);
    debug_assert!(coeffs.len() >= rows);
    for i in 0..rows {
        coeffs[i] = dot(&panel[i * dim..i * dim + dim], x);
    }
}

/// `y[j] += Σ_i coeffs[i] · panel[i·dim + j]` (transposed panel–vector
/// product; phase 2 of the low-rank apply — one contiguous axpy per row).
#[inline]
pub fn panel_gemv_t(panel: &[f64], rows: usize, dim: usize, coeffs: &[f64], y: &mut [f64]) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert!(coeffs.len() >= rows);
    debug_assert_eq!(y.len(), dim);
    for i in 0..rows {
        let c = coeffs[i];
        if c != 0.0 {
            axpy(c, &panel[i * dim..i * dim + dim], y);
        }
    }
}

/// Multi-RHS variant of [`panel_gemv`]: `coeffs[i·k + r] = ⟨panelᵢ, xᵣ⟩` for
/// `k` right-hand sides stored row-major in `xs` (`k × dim`). One pass over
/// the panel serves every RHS — this is what makes a batch of SHINE backward
/// cotangents a single panel sweep.
#[inline]
pub fn panel_gemv_multi(
    panel: &[f64],
    rows: usize,
    dim: usize,
    xs: &[f64],
    k: usize,
    coeffs: &mut [f64],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(xs.len(), k * dim);
    debug_assert!(coeffs.len() >= rows * k);
    for i in 0..rows {
        let row = &panel[i * dim..i * dim + dim];
        for (r, x) in xs.chunks_exact(dim).enumerate() {
            coeffs[i * k + r] = dot(row, x);
        }
    }
}

/// Multi-RHS variant of [`panel_gemv_t`]: `ys[r] += Σ_i coeffs[i·k + r] ·
/// panelᵢ` for `k` outputs stored row-major in `ys` (`k × dim`). Each panel
/// row is read once and applied to all RHS while it is hot in cache.
#[inline]
pub fn panel_gemv_t_multi(
    panel: &[f64],
    rows: usize,
    dim: usize,
    coeffs: &[f64],
    k: usize,
    ys: &mut [f64],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(ys.len(), k * dim);
    debug_assert!(coeffs.len() >= rows * k);
    for i in 0..rows {
        let row = &panel[i * dim..i * dim + dim];
        for (r, y) in ys.chunks_exact_mut(dim).enumerate() {
            let c = coeffs[i * k + r];
            if c != 0.0 {
                axpy(c, row, y);
            }
        }
    }
}

// ---- f32 variants (DEQ hot path; accumulate dots in f64 for stability) ----

#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc
}

#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

#[inline]
pub fn sub_f32(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

#[inline]
pub fn nrm2_f32(x: &[f32]) -> f64 {
    dot_f32(x, x).sqrt()
}

#[inline]
pub fn scale_f32(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((nrm2(&a) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sub_add_dist() {
        let a = [3.0, 4.0];
        let b = [0.0, 0.0];
        let mut out = [0.0; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, a);
        add(&a, &a, &mut out);
        assert_eq!(out, [6.0, 8.0]);
        assert!((dist2(&a, &b) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn panel_kernels_match_naive() {
        // 3 factors of dim 4, panel row-major.
        let panel = [
            1.0, 2.0, 3.0, 4.0, //
            0.5, -1.0, 0.0, 2.0, //
            -1.0, 1.0, -1.0, 1.0,
        ];
        let x = [1.0, 0.0, -1.0, 2.0];
        let mut c = [0.0; 3];
        panel_gemv(&panel, 3, 4, &x, &mut c);
        assert_eq!(c, [6.0, 4.5, 2.0]);
        let mut y = [1.0; 4];
        panel_gemv_t(&panel, 3, 4, &c, &mut y);
        // y[j] = 1 + Σ_i c[i] * panel[i][j]
        for j in 0..4 {
            let want = 1.0 + c[0] * panel[j] + c[1] * panel[4 + j] + c[2] * panel[8 + j];
            assert!((y[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn panel_multi_matches_single() {
        let panel = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows × dim 2
        let xs = [1.0, -1.0, 2.0, 0.5]; // 2 RHS × dim 2
        let mut cm = [0.0; 6];
        panel_gemv_multi(&panel, 3, 2, &xs, 2, &mut cm);
        for r in 0..2 {
            let x = &xs[r * 2..r * 2 + 2];
            let mut c1 = [0.0; 3];
            panel_gemv(&panel, 3, 2, x, &mut c1);
            for i in 0..3 {
                assert_eq!(cm[i * 2 + r], c1[i]);
            }
        }
        let mut ym = [0.0; 4];
        panel_gemv_t_multi(&panel, 3, 2, &cm, 2, &mut ym);
        for r in 0..2 {
            let mut y1 = [0.0; 2];
            let c1: Vec<f64> = (0..3).map(|i| cm[i * 2 + r]).collect();
            panel_gemv_t(&panel, 3, 2, &c1, &mut y1);
            assert_eq!(&ym[r * 2..r * 2 + 2], &y1);
        }
    }

    #[test]
    fn f32_ops_accumulate_in_f64() {
        // 1e6 elements of 1e-3: f32 naive accumulation loses precision badly.
        let n = 1_000_000;
        let a = vec![1e-3f32; n];
        let d = dot_f32(&a, &a);
        assert!((d - 1e-6 * n as f64).abs() / (1e-6 * n as f64) < 1e-6);
    }
}
