//! Precision-generic BLAS-1 / panel kernels over plain slices.
//!
//! # The `Elem` precision contract
//!
//! Every vector kernel in this module — and through it the whole qN /
//! solver / DEQ stack — is generic over a storage scalar [`Elem`] with four
//! instantiations: `f64`, `f32`, and the half-width bit-level newtypes
//! [`Bf16`] (bfloat16: 1+8+7, f32's exponent range) and [`F16`] (IEEE
//! binary16: 1+5+10). The contract is **store narrow, accumulate wide**:
//!
//! * *storage* (panels, iterates, residuals, cotangents) is `E`;
//! * every *reduction* (dot products, norms, Gram entries) is carried in the
//!   wide accumulator `Elem::Acc` — pinned to `f64` for every instantiation —
//!   and every *coefficient* derived from a reduction (Sherman–Morrison
//!   denominators, two-loop α/β, `ρ = 1/yᵀs`, mixing weights) stays `f64`
//!   until the final element-wise write-back narrows it to `E`.
//!
//! This is exactly the trade the DEQ literature shows the backward pass
//! tolerates (Jacobian-Free training, inexact/implicit gradients): f32
//! panels halve the memory traffic of the O(m·d) low-rank sweeps that
//! dominate SHINE's backward cost at MDEQ scale, and bf16/f16 panels halve
//! it again, while f64 accumulation keeps the dot products as accurate as
//! the old all-f64 path. The bi-level experiments instantiate the same code
//! at `E = f64` and are bit-compatible with the pre-generic implementation
//! (`to_f64`/`from_f64` are identity for `f64` and compile away).
//!
//! # Kernels
//!
//! The BLAS-1 kernels (`dot`, `axpy`, …) are the hot inner loops of the
//! quasi-Newton updates; they are allocation-free and auto-vectorize
//! cleanly. The panel kernels (`panel_gemv` / `panel_gemv_t` and their
//! `_multi` variants) stream flat row-major `m × d` factor panels front to
//! back and are the whole of SHINE's backward cost once the factors live in
//! a [`crate::qn::FactorPanel`]. The `_multi` variants shard across threads
//! (via [`crate::util::threads::par_row_chunks_mut`]) once the panel
//! exceeds [`PAR_MIN_ELEMS`], so a large batch of cotangents uses every
//! core.
//!
//! The kernels that touch two buffers take **two independent storage
//! parameters** (the panel's and the vector's): since every element is
//! widened to f64 before any arithmetic, a bf16 panel can sweep an f32
//! state vector in one pass with no intermediate buffer. Same-typed call
//! sites infer both parameters identically, so the single-precision API is
//! unchanged; mixed instantiations are what let `MixedPanel`-style layouts
//! (bf16 U factors, f32 V factors — see [`crate::qn::FactorPanel`]) put the
//! byte savings where the error is cheap.

use crate::util::threads;

/// Storage scalar of the low-rank engine: `f64`, `f32`, [`Bf16`] or [`F16`]
/// panels, always with `f64` accumulation (see the module docs for the full
/// contract).
///
/// `to_f64`/`from_f64` are the only arithmetic surface — generic code widens
/// operands, computes in `f64`, and narrows results. For `E = f64` both are
/// identities and the optimizer erases them; for `E = f32` they compile to
/// single convert instructions; for the half-width newtypes they are a few
/// integer ops that still vanish inside the memory-bound sweeps.
pub trait Elem:
    Copy + PartialEq + PartialOrd + Send + Sync + std::fmt::Debug + 'static
{
    /// Wide accumulator type for reductions. Pinned to `f64` for every
    /// supported storage type — including the half-width `Bf16`/`F16`
    /// storages; the contract is that `Acc` never narrows below f64.
    /// Because every impl pins it, the kernel/coefficient signatures below
    /// spell the accumulator as plain `f64`; the associated type exists to
    /// mark the contract (and the seam a non-f64 accumulator would thread
    /// through), not as a second code path.
    type Acc: Copy + Send + Sync + std::fmt::Debug + 'static;
    /// Additive identity in storage precision.
    const ZERO: Self;
    /// Multiplicative identity in storage precision.
    const ONE: Self;
    /// Narrow an accumulator value to storage precision.
    fn from_f64(x: f64) -> Self;
    /// Widen a stored value into the accumulator.
    fn to_f64(self) -> f64;
}

impl Elem for f64 {
    type Acc = f64;
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    #[inline(always)]
    fn from_f64(x: f64) -> f64 {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
}

impl Elem for f32 {
    type Acc = f64;
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    #[inline(always)]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

// ---- half-width storage scalars -------------------------------------------
//
// Pure-Rust bit-level bfloat16 and IEEE binary16, per the vendored-dependency
// idiom: no `half` crate, just `u16` newtypes whose entire arithmetic surface
// is `to_f64`/`from_f64`. Narrowing is round-to-nearest-even with subnormals,
// ±Inf and NaN handled; widening is exact (every bf16/f16 value is exactly
// representable in f32, hence f64). `from_f64` narrows through f32 first
// (`as f32` is RNE in Rust), then RNE again to 16 bits — the composition can
// double-round a ≤1-ulp sliver of f64 inputs sitting within 2⁻¹⁶ of a
// halfway point, which is irrelevant at 8/11 bits of mantissa; for f32
// inputs (all panel traffic) the narrowing is exactly RNE.

/// Narrow an f32 to bfloat16 bits: round-to-nearest-even by add-with-carry
/// on the upper half (bf16 is f32 truncated to 16 bits, so subnormals and
/// overflow-to-Inf fall out of the same add).
#[inline(always)]
fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep the sign, force a quiet NaN that survives the truncation.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + lsb) >> 16) as u16
}

/// Widen bfloat16 bits to f32 — exact for every class (bf16 ⊂ f32).
#[inline(always)]
fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Narrow an f32 to IEEE binary16 bits with round-to-nearest-even.
/// Branches: Inf/NaN, normal (≥ 2⁻¹⁴, RNE by add-with-carry on the rebased
/// bits, overflow to Inf), underflow-to-zero (≤ 2⁻²⁵, the tie rounds to the
/// even zero), and subnormal (explicit RNE on the shifted-out mantissa; a
/// carry into the exponent field yields the smallest normal, which is the
/// correct encoding).
#[inline(always)]
fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7FFF_FFFF;
    if abs >= 0x7F80_0000 {
        // Inf or NaN; preserve a NaN payload sliver and quietness.
        return if abs > 0x7F80_0000 {
            sign | 0x7E00 | ((abs >> 13) & 0x3FF) as u16
        } else {
            sign | 0x7C00
        };
    }
    if abs >= 0x3880_0000 {
        // Normal range: rebias 127→15 (subtract 112 exponents), then RNE on
        // the 13 dropped mantissa bits; a carry past the top overflows to Inf.
        let adjusted = abs - 0x3800_0000;
        let lsb = (adjusted >> 13) & 1;
        let rounded = (adjusted + 0xFFF + lsb) >> 13;
        return if rounded >= 0x7C00 {
            sign | 0x7C00
        } else {
            sign | rounded as u16
        };
    }
    if abs <= 0x3300_0000 {
        // ≤ 2⁻²⁵: underflows to (signed) zero; the exact tie at 2⁻²⁵ rounds
        // to the even candidate, which is zero.
        return sign;
    }
    // Subnormal range (2⁻²⁵, 2⁻¹⁴): value = man·2^(exp32−150), target ulp is
    // 2⁻²⁴, so shift the 24-bit significand right by 126 − exp32 ∈ [14, 24]
    // with explicit round-to-nearest-even on the dropped bits.
    let exp32 = (abs >> 23) as i32;
    let man = (abs & 0x007F_FFFF) | 0x0080_0000;
    let shift = (126 - exp32) as u32;
    let halfway = 1u32 << (shift - 1);
    let kept = man >> shift;
    let dropped = man & ((1u32 << shift) - 1);
    let round_up = dropped > halfway || (dropped == halfway && kept & 1 == 1);
    sign | (kept + round_up as u32) as u16
}

/// Widen IEEE binary16 bits to f32 — exact for every class (f16 ⊂ f32).
#[inline(always)]
fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign); // ±0
        }
        // Subnormal: man · 2⁻²⁴, exact as an f32 product (man ≤ 1023).
        let mag = (man as f32) * f32::from_bits(0x3380_0000); // 2⁻²⁴
        return if sign != 0 { -mag } else { mag };
    }
    if exp == 31 {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13)); // Inf / NaN
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

/// bfloat16 storage scalar: f32's 8-bit exponent with a 7-bit mantissa, so
/// narrowing from f32 never over/underflows new ranges — the dynamic range
/// of the panels survives and only resolution (~0.4% relative) is lost.
/// This is the default half-width panel storage (see ADR-003).
#[derive(Copy, Clone)]
pub struct Bf16(u16);

impl Bf16 {
    /// Wrap raw bfloat16 bits.
    #[inline(always)]
    pub const fn from_bits(bits: u16) -> Self {
        Bf16(bits)
    }
    /// The raw bfloat16 bit pattern.
    #[inline(always)]
    pub const fn to_bits(self) -> u16 {
        self.0
    }
    /// Narrow an f32 with round-to-nearest-even.
    #[inline(always)]
    pub fn from_f32(x: f32) -> Self {
        Bf16(f32_to_bf16_bits(x))
    }
    /// Widen to f32 (exact).
    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        bf16_bits_to_f32(self.0)
    }
}

impl Elem for Bf16 {
    type Acc = f64;
    const ZERO: Self = Bf16(0x0000);
    const ONE: Self = Bf16(0x3F80);
    #[inline(always)]
    fn from_f64(x: f64) -> Bf16 {
        Bf16(f32_to_bf16_bits(x as f32))
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        bf16_bits_to_f32(self.0) as f64
    }
}

/// IEEE binary16 storage scalar: 5-bit exponent (range ±65504, subnormals
/// down to 2⁻²⁴) with a 10-bit mantissa — finer resolution than [`Bf16`]
/// but a range that large panel factors can overflow; the scale-aware
/// representability guards in the qN updates skip such factors.
#[derive(Copy, Clone)]
pub struct F16(u16);

impl F16 {
    /// Wrap raw binary16 bits.
    #[inline(always)]
    pub const fn from_bits(bits: u16) -> Self {
        F16(bits)
    }
    /// The raw binary16 bit pattern.
    #[inline(always)]
    pub const fn to_bits(self) -> u16 {
        self.0
    }
    /// Narrow an f32 with round-to-nearest-even.
    #[inline(always)]
    pub fn from_f32(x: f32) -> Self {
        F16(f32_to_f16_bits(x))
    }
    /// Widen to f32 (exact).
    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        f16_bits_to_f32(self.0)
    }
}

impl Elem for F16 {
    type Acc = f64;
    const ZERO: Self = F16(0x0000);
    const ONE: Self = F16(0x3C00);
    #[inline(always)]
    fn from_f64(x: f64) -> F16 {
        F16(f32_to_f16_bits(x as f32))
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        f16_bits_to_f32(self.0) as f64
    }
}

// Value comparison (not bit comparison): derived ordering on the raw bits
// would misorder negatives, distinguish ±0 and equate NaNs. Widening is
// exact, so comparing through f64 gives exactly IEEE semantics.
impl PartialEq for Bf16 {
    #[inline(always)]
    fn eq(&self, other: &Self) -> bool {
        self.to_f64() == other.to_f64()
    }
}

impl PartialOrd for Bf16 {
    #[inline(always)]
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.to_f64().partial_cmp(&other.to_f64())
    }
}

impl std::fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}bf16", self.to_f32())
    }
}

impl PartialEq for F16 {
    #[inline(always)]
    fn eq(&self, other: &Self) -> bool {
        self.to_f64() == other.to_f64()
    }
}

impl PartialOrd for F16 {
    #[inline(always)]
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.to_f64().partial_cmp(&other.to_f64())
    }
}

impl std::fmt::Debug for F16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:?}f16", self.to_f32())
    }
}

/// dot(a, b), accumulated in f64 regardless of storage precision. The two
/// operands may use different storage scalars (both widen per element), so a
/// reduced-precision panel row can sweep a wider state vector directly;
/// same-typed call sites infer `A = B` as before.
#[inline]
pub fn dot<A: Elem, B: Elem>(a: &[A], b: &[B]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i].to_f64() * b[i].to_f64();
    }
    acc
}

/// y += alpha * x (alpha in accumulator precision, one narrowing per write).
/// `x` and `y` may use different storage scalars — the accumulation side `y`
/// keeps its own precision while a narrower `x` panel row widens per element.
#[inline]
pub fn axpy<X: Elem, Y: Elem>(alpha: f64, x: &[X], y: &mut [Y]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = Y::from_f64(y[i].to_f64() + alpha * x[i].to_f64());
    }
}

/// y = x
#[inline]
pub fn copy<E: Elem>(x: &[E], y: &mut [E]) {
    y.copy_from_slice(x);
}

/// x *= alpha
#[inline]
pub fn scale<E: Elem>(alpha: f64, x: &mut [E]) {
    for v in x.iter_mut() {
        *v = E::from_f64(v.to_f64() * alpha);
    }
}

/// x = −x
#[inline]
pub fn negate<E: Elem>(x: &mut [E]) {
    for v in x.iter_mut() {
        *v = E::from_f64(-v.to_f64());
    }
}

/// out = a - b
#[inline]
pub fn sub<E: Elem>(a: &[E], b: &[E], out: &mut [E]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = E::from_f64(a[i].to_f64() - b[i].to_f64());
    }
}

/// out = a + b
#[inline]
pub fn add<E: Elem>(a: &[E], b: &[E], out: &mut [E]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = E::from_f64(a[i].to_f64() + b[i].to_f64());
    }
}

/// out = a + alpha·b — the step-update idiom of every solver loop
/// (`z⁺ = z + α p`), computed in accumulator precision.
#[inline]
pub fn add_scaled<E: Elem>(a: &[E], alpha: f64, b: &[E], out: &mut [E]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = E::from_f64(a[i].to_f64() + alpha * b[i].to_f64());
    }
}

/// ||x||_2 (f64 accumulation).
#[inline]
pub fn nrm2<E: Elem>(x: &[E]) -> f64 {
    dot(x, x).sqrt()
}

/// ||a - b||_2 (f64 accumulation).
#[inline]
pub fn dist2<E: Elem>(a: &[E], b: &[E]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = a[i].to_f64() - b[i].to_f64();
        acc += d * d;
    }
    acc.sqrt()
}

/// Fill with zeros.
#[inline]
pub fn zero<E: Elem>(x: &mut [E]) {
    for v in x.iter_mut() {
        *v = E::ZERO;
    }
}

// ---- panel (flat row-major m×d) kernels -----------------------------------
//
// These primitives are the whole of SHINE's O(m·d) backward cost once the
// factors live in a `FactorPanel`: `H x = x + Uᵀ (V x)` is one `panel_gemv`
// (the coefficient sweep `c = V x`) followed by one `panel_gemv_t` (the
// accumulation sweep `out += Uᵀ c`). Both stream the panel front to back, so
// they run at memory bandwidth and auto-vectorize. Coefficients live in f64
// (they are dot results — accumulator precision per the `Elem` contract)
// while the panels and vectors are in storage precision.

/// Panels above this many elements (`rank × dim`) may be swept with scoped
/// threads (the `_multi` kernels below and the single-RHS paths in
/// [`crate::qn::low_rank`]). Below it the kernels stay single-threaded:
/// spawning scoped threads costs more than the sweep and would break the
/// allocation-free guarantee of the solver inner loops.
pub const PAR_MIN_ELEMS: usize = 1 << 17;

/// `coeffs[i] = Σ_j panel[i·dim + j] · x[j]` for `i in 0..rows`
/// (row-major panel–vector products; phase 1 of the low-rank apply).
/// The panel and vector storage scalars are independent (both widen to f64
/// per element), so reduced-precision panels sweep wider state directly.
#[inline]
pub fn panel_gemv<P: Elem, X: Elem>(
    panel: &[P],
    rows: usize,
    dim: usize,
    x: &[X],
    coeffs: &mut [f64],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(x.len(), dim);
    debug_assert!(coeffs.len() >= rows);
    for i in 0..rows {
        coeffs[i] = dot(&panel[i * dim..i * dim + dim], x);
    }
}

/// `y[j] += Σ_i coeffs[i] · panel[i·dim + j]` (transposed panel–vector
/// product; phase 2 of the low-rank apply — one contiguous axpy per row).
/// Panel and output storage scalars are independent, as in [`panel_gemv`].
#[inline]
pub fn panel_gemv_t<P: Elem, Y: Elem>(
    panel: &[P],
    rows: usize,
    dim: usize,
    coeffs: &[f64],
    y: &mut [Y],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert!(coeffs.len() >= rows);
    debug_assert_eq!(y.len(), dim);
    for i in 0..rows {
        let c = coeffs[i];
        if c != 0.0 {
            axpy(c, &panel[i * dim..i * dim + dim], y);
        }
    }
}

/// Multi-RHS variant of [`panel_gemv`]: `coeffs[i·k + r] = ⟨panelᵢ, xᵣ⟩` for
/// `k` right-hand sides stored row-major in `xs` (`k × dim`). One pass over
/// the panel serves every RHS — this is what makes a batch of SHINE backward
/// cotangents a single panel sweep. Above [`PAR_MIN_ELEMS`] panel elements
/// the sweep is sharded across threads by blocks of panel rows (each block
/// owns a contiguous run of `coeffs` rows, so workers never share a write).
#[inline]
pub fn panel_gemv_multi<P: Elem, X: Elem>(
    panel: &[P],
    rows: usize,
    dim: usize,
    xs: &[X],
    k: usize,
    coeffs: &mut [f64],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(xs.len(), k * dim);
    debug_assert!(coeffs.len() >= rows * k);
    if rows * dim >= PAR_MIN_ELEMS && rows >= 2 {
        let workers = threads::ncpus().min(16).min(rows);
        threads::par_row_chunks_mut(&mut coeffs[..rows * k], k, workers, |row0, cc| {
            gemv_multi_serial(&panel[row0 * dim..], cc.len() / k, dim, xs, k, cc);
        });
    } else {
        gemv_multi_serial(panel, rows, dim, xs, k, coeffs);
    }
}

#[inline]
fn gemv_multi_serial<P: Elem, X: Elem>(
    panel: &[P],
    rows: usize,
    dim: usize,
    xs: &[X],
    k: usize,
    coeffs: &mut [f64],
) {
    for i in 0..rows {
        let row = &panel[i * dim..i * dim + dim];
        for (r, x) in xs.chunks_exact(dim).enumerate() {
            coeffs[i * k + r] = dot(row, x);
        }
    }
}

/// Multi-RHS variant of [`panel_gemv_t`]: `ys[r] += Σ_i coeffs[i·k + r] ·
/// panelᵢ` for `k` outputs stored row-major in `ys` (`k × dim`). Each panel
/// row is read once per worker and applied to that worker's RHS rows while
/// it is hot in cache. Above [`PAR_MIN_ELEMS`] panel elements the kernel is
/// sharded across threads over the RHS rows (the output rows are disjoint
/// whole rows of `ys`, so the split is a `par_row_chunks_mut`) — the useful
/// regime is large `k`, where each of up to `k` workers streams the panel
/// once for `k/workers` outputs.
#[inline]
pub fn panel_gemv_t_multi<P: Elem, Y: Elem>(
    panel: &[P],
    rows: usize,
    dim: usize,
    coeffs: &[f64],
    k: usize,
    ys: &mut [Y],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(ys.len(), k * dim);
    debug_assert!(coeffs.len() >= rows * k);
    if rows * dim >= PAR_MIN_ELEMS && k >= 2 {
        let workers = threads::ncpus().min(16).min(k);
        threads::par_row_chunks_mut(ys, dim, workers, |r0, chunk| {
            gemv_t_multi_sharded(panel, rows, dim, coeffs, k, r0, chunk);
        });
    } else {
        gemv_t_multi_sharded(panel, rows, dim, coeffs, k, 0, ys);
    }
}

/// Serial body of [`panel_gemv_t_multi`] over the RHS rows `r0..` held in
/// `ys_chunk` (whole rows of the full `k × dim` output).
#[inline]
fn gemv_t_multi_sharded<P: Elem, Y: Elem>(
    panel: &[P],
    rows: usize,
    dim: usize,
    coeffs: &[f64],
    k: usize,
    r0: usize,
    ys_chunk: &mut [Y],
) {
    for i in 0..rows {
        let row = &panel[i * dim..i * dim + dim];
        for (rl, y) in ys_chunk.chunks_exact_mut(dim).enumerate() {
            let c = coeffs[i * k + r0 + rl];
            if c != 0.0 {
                axpy(c, row, y);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((nrm2(&a) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sub_add_dist() {
        let a = [3.0, 4.0];
        let b = [0.0, 0.0];
        let mut out = [0.0; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, a);
        add(&a, &a, &mut out);
        assert_eq!(out, [6.0, 8.0]);
        assert!((dist2(&a, &b) - 5.0).abs() < 1e-12);
        add_scaled(&a, 2.0, &a, &mut out);
        assert_eq!(out, [9.0, 12.0]);
        let mut n = a;
        negate(&mut n);
        assert_eq!(n, [-3.0, -4.0]);
    }

    #[test]
    fn panel_kernels_match_naive() {
        // 3 factors of dim 4, panel row-major.
        let panel = [
            1.0, 2.0, 3.0, 4.0, //
            0.5, -1.0, 0.0, 2.0, //
            -1.0, 1.0, -1.0, 1.0,
        ];
        let x = [1.0, 0.0, -1.0, 2.0];
        let mut c = [0.0; 3];
        panel_gemv(&panel, 3, 4, &x, &mut c);
        assert_eq!(c, [6.0, 4.5, 2.0]);
        let mut y = [1.0; 4];
        panel_gemv_t(&panel, 3, 4, &c, &mut y);
        // y[j] = 1 + Σ_i c[i] * panel[i][j]
        for j in 0..4 {
            let want = 1.0 + c[0] * panel[j] + c[1] * panel[4 + j] + c[2] * panel[8 + j];
            assert!((y[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn panel_multi_matches_single() {
        let panel = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows × dim 2
        let xs = [1.0, -1.0, 2.0, 0.5]; // 2 RHS × dim 2
        let mut cm = [0.0; 6];
        panel_gemv_multi(&panel, 3, 2, &xs, 2, &mut cm);
        for r in 0..2 {
            let x = &xs[r * 2..r * 2 + 2];
            let mut c1 = [0.0; 3];
            panel_gemv(&panel, 3, 2, x, &mut c1);
            for i in 0..3 {
                assert_eq!(cm[i * 2 + r], c1[i]);
            }
        }
        let mut ym = [0.0; 4];
        panel_gemv_t_multi(&panel, 3, 2, &cm, 2, &mut ym);
        for r in 0..2 {
            let mut y1 = [0.0; 2];
            let c1: Vec<f64> = (0..3).map(|i| cm[i * 2 + r]).collect();
            panel_gemv_t(&panel, 3, 2, &c1, &mut y1);
            assert_eq!(&ym[r * 2..r * 2 + 2], &y1);
        }
    }

    #[test]
    fn f32_kernels_accumulate_in_f64() {
        // 1e6 elements of 1e-3: f32 naive accumulation loses precision badly;
        // the generic dot must carry the reduction in f64.
        let n = 1_000_000;
        let a = vec![1e-3f32; n];
        let d = dot(&a, &a);
        assert!((d - 1e-6 * n as f64).abs() / (1e-6 * n as f64) < 1e-6);
    }

    #[test]
    fn f32_panel_matches_f64_panel() {
        // Same factors in both precisions: the f32 sweep must agree with the
        // f64 one to f32 storage tolerance (exactly-representable inputs keep
        // the dots identical; only output narrowing differs).
        let panel64 = [0.5, -1.25, 2.0, 0.75, 1.5, -0.5];
        let panel32: Vec<f32> = panel64.iter().map(|&x| x as f32).collect();
        let x64 = [1.0, -2.0, 0.5];
        let x32: Vec<f32> = x64.iter().map(|&x| x as f32).collect();
        let mut c64 = [0.0; 2];
        let mut c32 = [0.0; 2];
        panel_gemv(&panel64, 2, 3, &x64, &mut c64);
        panel_gemv(&panel32, 2, 3, &x32, &mut c32);
        assert_eq!(c64, c32); // dyadic inputs: f64-accumulated dots match exactly
        let mut y64 = [0.25; 3];
        let mut y32 = [0.25f32; 3];
        panel_gemv_t(&panel64, 2, 3, &c64, &mut y64);
        panel_gemv_t(&panel32, 2, 3, &c32, &mut y32);
        for j in 0..3 {
            assert!((y64[j] - y32[j] as f64).abs() < 1e-6);
        }
    }

    #[test]
    fn bf16_conversion_edge_cases() {
        // Exact values survive the round trip bit-for-bit.
        assert_eq!(Bf16::ONE.to_bits(), 0x3F80);
        assert_eq!(Bf16::from_f32(1.0).to_bits(), 0x3F80);
        assert_eq!(Bf16::from_f32(-0.0).to_bits(), 0x8000);
        assert_eq!(Bf16::from_f32(0.0).to_bits(), 0x0000);
        for v in [1.0f32, -2.5, 0.15625, 3.0e38, 1.0e-38, -7.0] {
            let b = Bf16::from_f32(v);
            assert_eq!(Bf16::from_f32(b.to_f32()).to_bits(), b.to_bits());
        }
        // Round-to-nearest-even at the 2⁻⁸ tie around 1.0: the tie with an
        // even kept-lsb truncates, the tie with an odd kept-lsb rounds up,
        // and anything past the tie rounds up.
        assert_eq!(Bf16::from_f32(f32::from_bits(0x3F80_8000)).to_bits(), 0x3F80);
        assert_eq!(Bf16::from_f32(f32::from_bits(0x3F81_8000)).to_bits(), 0x3F82);
        assert_eq!(Bf16::from_f32(f32::from_bits(0x3F80_8001)).to_bits(), 0x3F81);
        // Range: bf16 shares f32's exponent field, so f32::MIN_POSITIVE is
        // exactly representable and f32::MAX rounds up to +Inf.
        assert_eq!(Bf16::from_f32(f32::MIN_POSITIVE).to_bits(), 0x0080);
        assert_eq!(Bf16::from_f32(f32::MAX).to_bits(), 0x7F80);
        assert_eq!(Bf16::from_f32(-f32::MAX).to_bits(), 0xFF80);
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_bits(), 0x7F80);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert!(Bf16::from_f64(f64::NAN).to_f64().is_nan());
    }

    #[test]
    fn f16_conversion_edge_cases() {
        assert_eq!(F16::ONE.to_bits(), 0x3C00);
        assert_eq!(F16::from_f32(1.5).to_bits(), 0x3E00);
        assert_eq!(F16::from_f32(-0.0).to_bits(), 0x8000);
        for v in [1.0f32, -2.5, 0.15625, 65504.0, -1024.0] {
            let h = F16::from_f32(v);
            assert_eq!(F16::from_f32(h.to_f32()).to_bits(), h.to_bits());
        }
        // Largest finite value and the overflow tie: 65520 sits exactly
        // between 65504 and 2¹⁶; the even candidate is 2¹⁶, which overflows
        // to +Inf. Anything below the tie stays at 65504.
        assert_eq!(F16::from_f32(65504.0).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32(65519.0).to_bits(), 0x7BFF);
        assert_eq!(F16::from_f32(65520.0).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(1.0e9).to_bits(), 0x7C00);
        // Smallest normal, subnormals, and the underflow tie: 2⁻²⁵ is the
        // halfway point between 0 and the smallest subnormal 2⁻²⁴ — it
        // rounds to the even zero; 0.75·2⁻²⁴ rounds up to 2⁻²⁴.
        assert_eq!(F16::from_f32(f32::from_bits(0x3880_0000)).to_bits(), 0x0400);
        assert_eq!(F16::from_f64((2.0f64).powi(-24)).to_bits(), 0x0001);
        assert_eq!(F16::from_f64((2.0f64).powi(-25)).to_bits(), 0x0000);
        assert_eq!(F16::from_f64(0.75 * (2.0f64).powi(-24)).to_bits(), 0x0001);
        assert_eq!(F16::from_f64((2.0f64).powi(-26)).to_bits(), 0x0000);
        // Subnormal RNE ties round to even mantissas.
        assert_eq!(F16::from_f64(100.5 * (2.0f64).powi(-24)).to_bits(), 0x0064);
        assert_eq!(F16::from_f64(101.5 * (2.0f64).powi(-24)).to_bits(), 0x0066);
        // Just below the normal boundary the carry lands on the smallest
        // normal encoding.
        assert_eq!(F16::from_f32(f32::from_bits(0x387F_FFFF)).to_bits(), 0x0400);
        // Subnormal round trips are exact.
        for bits in [0x0001u16, 0x0064, 0x03FF, 0x8001, 0x83FF] {
            let h = F16::from_bits(bits);
            assert_eq!(F16::from_f32(h.to_f32()).to_bits(), bits);
        }
        assert_eq!(F16::from_f32(f32::INFINITY).to_bits(), 0x7C00);
        assert_eq!(F16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
        assert!(F16::from_f64(f64::NAN).to_f64().is_nan());
    }

    #[test]
    fn mixed_storage_kernels_widen_per_element() {
        // A bf16 panel sweeping f32 state: every operand widens to f64, so
        // the mixed kernel must agree exactly with widening the panel by
        // hand first (bf16 → f64 is exact).
        let panel64 = [0.5, -1.25, 2.0, 0.75, 1.5, -0.5];
        let panel: Vec<Bf16> = panel64.iter().map(|&x| Bf16::from_f64(x)).collect();
        let widened: Vec<f64> = panel.iter().map(|b| b.to_f64()).collect();
        let x = [1.0f32, -2.0, 0.5];
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let mut c = [0.0; 2];
        let mut c_ref = [0.0; 2];
        panel_gemv(&panel, 2, 3, &x, &mut c);
        panel_gemv(&widened, 2, 3, &x64, &mut c_ref);
        assert_eq!(c, c_ref);
        let mut y = [0.25f32; 3];
        let mut y_ref = [0.25f64; 3];
        panel_gemv_t(&panel, 2, 3, &c, &mut y);
        panel_gemv_t(&widened, 2, 3, &c_ref, &mut y_ref);
        for j in 0..3 {
            assert_eq!(y[j] as f64, y_ref[j], "dyadic values narrow exactly");
        }
        // dot/axpy accept mixed operands directly.
        let a16: Vec<F16> = [1.0f64, 2.0, -0.5].iter().map(|&v| F16::from_f64(v)).collect();
        let b32 = [4.0f32, 0.5, 2.0];
        assert_eq!(dot(&a16, &b32), 4.0);
        let mut acc = [1.0f32; 3];
        axpy(2.0, &a16, &mut acc);
        assert_eq!(acc, [3.0, 5.0, 0.0]);
    }

    #[test]
    fn multi_parallel_path_matches_serial() {
        // Cross the PAR_MIN_ELEMS threshold so the sharded path runs, and
        // compare against per-RHS serial kernels. f64 dots are computed
        // identically regardless of chunking, so results are exact.
        let rows = 6;
        let dim = PAR_MIN_ELEMS / 4; // rows*dim comfortably above threshold
        let k = 3;
        let mut rng = crate::util::rng::Rng::new(0x9E37);
        let panel: Vec<f64> = (0..rows * dim).map(|_| rng.normal()).collect();
        let xs: Vec<f64> = (0..k * dim).map(|_| rng.normal()).collect();
        let mut cm = vec![0.0; rows * k];
        panel_gemv_multi(&panel, rows, dim, &xs, k, &mut cm);
        for r in 0..k {
            let mut c1 = vec![0.0; rows];
            panel_gemv(&panel, rows, dim, &xs[r * dim..(r + 1) * dim], &mut c1);
            for i in 0..rows {
                assert_eq!(cm[i * k + r], c1[i], "coeff ({i},{r})");
            }
        }
        let mut ym = vec![0.0; k * dim];
        panel_gemv_t_multi(&panel, rows, dim, &cm, k, &mut ym);
        for r in 0..k {
            let mut y1 = vec![0.0; dim];
            let c1: Vec<f64> = (0..rows).map(|i| cm[i * k + r]).collect();
            panel_gemv_t(&panel, rows, dim, &c1, &mut y1);
            assert_eq!(&ym[r * dim..(r + 1) * dim], &y1[..], "rhs {r}");
        }
    }
}
