//! BLAS-1 style vector kernels over plain slices (f64 for the optimization
//! stack, a few f32 variants for the DEQ/artifact path). These are the hot
//! inner loops of the quasi-Newton updates; they are written allocation-free
//! and auto-vectorize cleanly (verified in the §Perf pass).

/// dot(a, b)
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// y = x
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// out = a - b
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// out = a + b
#[inline]
pub fn add(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = a[i] + b[i];
    }
}

/// ||x||_2
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ||a - b||_2
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc.sqrt()
}

/// Fill with zeros.
#[inline]
pub fn zero(x: &mut [f64]) {
    for v in x.iter_mut() {
        *v = 0.0;
    }
}

// ---- f32 variants (DEQ hot path; accumulate dots in f64 for stability) ----

#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc
}

#[inline]
pub fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

#[inline]
pub fn sub_f32(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

#[inline]
pub fn nrm2_f32(x: &[f32]) -> f64 {
    dot_f32(x, x).sqrt()
}

#[inline]
pub fn scale_f32(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((nrm2(&a) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sub_add_dist() {
        let a = [3.0, 4.0];
        let b = [0.0, 0.0];
        let mut out = [0.0; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, a);
        add(&a, &a, &mut out);
        assert_eq!(out, [6.0, 8.0]);
        assert!((dist2(&a, &b) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn f32_ops_accumulate_in_f64() {
        // 1e6 elements of 1e-3: f32 naive accumulation loses precision badly.
        let n = 1_000_000;
        let a = vec![1e-3f32; n];
        let d = dot_f32(&a, &a);
        assert!((d - 1e-6 * n as f64).abs() / (1e-6 * n as f64) < 1e-6);
    }
}
