//! Precision-generic BLAS-1 / panel kernels over plain slices.
//!
//! # The `Elem` precision contract
//!
//! Every vector kernel in this module — and through it the whole qN /
//! solver / DEQ stack — is generic over a storage scalar [`Elem`] with two
//! instantiations, `f64` and `f32`. The contract is **store narrow,
//! accumulate wide**:
//!
//! * *storage* (panels, iterates, residuals, cotangents) is `E`;
//! * every *reduction* (dot products, norms, Gram entries) is carried in the
//!   wide accumulator `Elem::Acc` — pinned to `f64` for both instantiations —
//!   and every *coefficient* derived from a reduction (Sherman–Morrison
//!   denominators, two-loop α/β, `ρ = 1/yᵀs`, mixing weights) stays `f64`
//!   until the final element-wise write-back narrows it to `E`.
//!
//! This is exactly the trade the DEQ literature shows the backward pass
//! tolerates (Jacobian-Free training, inexact/implicit gradients): f32
//! panels halve the memory traffic of the O(m·d) low-rank sweeps that
//! dominate SHINE's backward cost at MDEQ scale, while f64 accumulation
//! keeps the dot products as accurate as the old all-f64 path. The bi-level
//! experiments instantiate the same code at `E = f64` and are bit-compatible
//! with the pre-generic implementation (`to_f64`/`from_f64` are identity for
//! `f64` and compile away).
//!
//! # Kernels
//!
//! The BLAS-1 kernels (`dot`, `axpy`, …) are the hot inner loops of the
//! quasi-Newton updates; they are allocation-free and auto-vectorize
//! cleanly. The panel kernels (`panel_gemv` / `panel_gemv_t` and their
//! `_multi` variants) stream flat row-major `m × d` factor panels front to
//! back and are the whole of SHINE's backward cost once the factors live in
//! a [`crate::qn::FactorPanel`]. The `_multi` variants shard across threads
//! (via [`crate::util::threads::par_row_chunks_mut`]) once the panel
//! exceeds [`PAR_MIN_ELEMS`], so a large batch of cotangents uses every
//! core.

use crate::util::threads;

/// Storage scalar of the low-rank engine: `f32` or `f64` panels, always with
/// `f64` accumulation (see the module docs for the full contract).
///
/// `to_f64`/`from_f64` are the only arithmetic surface — generic code widens
/// operands, computes in `f64`, and narrows results. For `E = f64` both are
/// identities and the optimizer erases them; for `E = f32` they compile to
/// single convert instructions that vanish inside the memory-bound sweeps.
pub trait Elem:
    Copy + PartialEq + PartialOrd + Send + Sync + std::fmt::Debug + 'static
{
    /// Wide accumulator type for reductions. Pinned to `f64` for every
    /// supported storage type; a future f16/bf16 storage would keep it at
    /// `f64` too — the contract is that `Acc` never narrows below f64.
    /// Because every impl pins it, the kernel/coefficient signatures below
    /// spell the accumulator as plain `f64`; the associated type exists to
    /// mark the contract (and the seam a non-f64 accumulator would thread
    /// through), not as a second code path.
    type Acc: Copy + Send + Sync + std::fmt::Debug + 'static;
    /// Additive identity in storage precision.
    const ZERO: Self;
    /// Multiplicative identity in storage precision.
    const ONE: Self;
    /// Narrow an accumulator value to storage precision.
    fn from_f64(x: f64) -> Self;
    /// Widen a stored value into the accumulator.
    fn to_f64(self) -> f64;
}

impl Elem for f64 {
    type Acc = f64;
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    #[inline(always)]
    fn from_f64(x: f64) -> f64 {
        x
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
}

impl Elem for f32 {
    type Acc = f64;
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    #[inline(always)]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// dot(a, b), accumulated in f64 regardless of storage precision.
#[inline]
pub fn dot<E: Elem>(a: &[E], b: &[E]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i].to_f64() * b[i].to_f64();
    }
    acc
}

/// y += alpha * x (alpha in accumulator precision, one narrowing per write).
#[inline]
pub fn axpy<E: Elem>(alpha: f64, x: &[E], y: &mut [E]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = E::from_f64(y[i].to_f64() + alpha * x[i].to_f64());
    }
}

/// y = x
#[inline]
pub fn copy<E: Elem>(x: &[E], y: &mut [E]) {
    y.copy_from_slice(x);
}

/// x *= alpha
#[inline]
pub fn scale<E: Elem>(alpha: f64, x: &mut [E]) {
    for v in x.iter_mut() {
        *v = E::from_f64(v.to_f64() * alpha);
    }
}

/// x = −x
#[inline]
pub fn negate<E: Elem>(x: &mut [E]) {
    for v in x.iter_mut() {
        *v = E::from_f64(-v.to_f64());
    }
}

/// out = a - b
#[inline]
pub fn sub<E: Elem>(a: &[E], b: &[E], out: &mut [E]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = E::from_f64(a[i].to_f64() - b[i].to_f64());
    }
}

/// out = a + b
#[inline]
pub fn add<E: Elem>(a: &[E], b: &[E], out: &mut [E]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        out[i] = E::from_f64(a[i].to_f64() + b[i].to_f64());
    }
}

/// out = a + alpha·b — the step-update idiom of every solver loop
/// (`z⁺ = z + α p`), computed in accumulator precision.
#[inline]
pub fn add_scaled<E: Elem>(a: &[E], alpha: f64, b: &[E], out: &mut [E]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = E::from_f64(a[i].to_f64() + alpha * b[i].to_f64());
    }
}

/// ||x||_2 (f64 accumulation).
#[inline]
pub fn nrm2<E: Elem>(x: &[E]) -> f64 {
    dot(x, x).sqrt()
}

/// ||a - b||_2 (f64 accumulation).
#[inline]
pub fn dist2<E: Elem>(a: &[E], b: &[E]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = a[i].to_f64() - b[i].to_f64();
        acc += d * d;
    }
    acc.sqrt()
}

/// Fill with zeros.
#[inline]
pub fn zero<E: Elem>(x: &mut [E]) {
    for v in x.iter_mut() {
        *v = E::ZERO;
    }
}

// ---- panel (flat row-major m×d) kernels -----------------------------------
//
// These primitives are the whole of SHINE's O(m·d) backward cost once the
// factors live in a `FactorPanel`: `H x = x + Uᵀ (V x)` is one `panel_gemv`
// (the coefficient sweep `c = V x`) followed by one `panel_gemv_t` (the
// accumulation sweep `out += Uᵀ c`). Both stream the panel front to back, so
// they run at memory bandwidth and auto-vectorize. Coefficients live in f64
// (they are dot results — accumulator precision per the `Elem` contract)
// while the panels and vectors are in storage precision.

/// Panels above this many elements (`rank × dim`) may be swept with scoped
/// threads (the `_multi` kernels below and the single-RHS paths in
/// [`crate::qn::low_rank`]). Below it the kernels stay single-threaded:
/// spawning scoped threads costs more than the sweep and would break the
/// allocation-free guarantee of the solver inner loops.
pub const PAR_MIN_ELEMS: usize = 1 << 17;

/// `coeffs[i] = Σ_j panel[i·dim + j] · x[j]` for `i in 0..rows`
/// (row-major panel–vector products; phase 1 of the low-rank apply).
#[inline]
pub fn panel_gemv<E: Elem>(panel: &[E], rows: usize, dim: usize, x: &[E], coeffs: &mut [f64]) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(x.len(), dim);
    debug_assert!(coeffs.len() >= rows);
    for i in 0..rows {
        coeffs[i] = dot(&panel[i * dim..i * dim + dim], x);
    }
}

/// `y[j] += Σ_i coeffs[i] · panel[i·dim + j]` (transposed panel–vector
/// product; phase 2 of the low-rank apply — one contiguous axpy per row).
#[inline]
pub fn panel_gemv_t<E: Elem>(panel: &[E], rows: usize, dim: usize, coeffs: &[f64], y: &mut [E]) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert!(coeffs.len() >= rows);
    debug_assert_eq!(y.len(), dim);
    for i in 0..rows {
        let c = coeffs[i];
        if c != 0.0 {
            axpy(c, &panel[i * dim..i * dim + dim], y);
        }
    }
}

/// Multi-RHS variant of [`panel_gemv`]: `coeffs[i·k + r] = ⟨panelᵢ, xᵣ⟩` for
/// `k` right-hand sides stored row-major in `xs` (`k × dim`). One pass over
/// the panel serves every RHS — this is what makes a batch of SHINE backward
/// cotangents a single panel sweep. Above [`PAR_MIN_ELEMS`] panel elements
/// the sweep is sharded across threads by blocks of panel rows (each block
/// owns a contiguous run of `coeffs` rows, so workers never share a write).
#[inline]
pub fn panel_gemv_multi<E: Elem>(
    panel: &[E],
    rows: usize,
    dim: usize,
    xs: &[E],
    k: usize,
    coeffs: &mut [f64],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(xs.len(), k * dim);
    debug_assert!(coeffs.len() >= rows * k);
    if rows * dim >= PAR_MIN_ELEMS && rows >= 2 {
        let workers = threads::ncpus().min(16).min(rows);
        threads::par_row_chunks_mut(&mut coeffs[..rows * k], k, workers, |row0, cc| {
            gemv_multi_serial(&panel[row0 * dim..], cc.len() / k, dim, xs, k, cc);
        });
    } else {
        gemv_multi_serial(panel, rows, dim, xs, k, coeffs);
    }
}

#[inline]
fn gemv_multi_serial<E: Elem>(
    panel: &[E],
    rows: usize,
    dim: usize,
    xs: &[E],
    k: usize,
    coeffs: &mut [f64],
) {
    for i in 0..rows {
        let row = &panel[i * dim..i * dim + dim];
        for (r, x) in xs.chunks_exact(dim).enumerate() {
            coeffs[i * k + r] = dot(row, x);
        }
    }
}

/// Multi-RHS variant of [`panel_gemv_t`]: `ys[r] += Σ_i coeffs[i·k + r] ·
/// panelᵢ` for `k` outputs stored row-major in `ys` (`k × dim`). Each panel
/// row is read once per worker and applied to that worker's RHS rows while
/// it is hot in cache. Above [`PAR_MIN_ELEMS`] panel elements the kernel is
/// sharded across threads over the RHS rows (the output rows are disjoint
/// whole rows of `ys`, so the split is a `par_row_chunks_mut`) — the useful
/// regime is large `k`, where each of up to `k` workers streams the panel
/// once for `k/workers` outputs.
#[inline]
pub fn panel_gemv_t_multi<E: Elem>(
    panel: &[E],
    rows: usize,
    dim: usize,
    coeffs: &[f64],
    k: usize,
    ys: &mut [E],
) {
    debug_assert!(panel.len() >= rows * dim);
    debug_assert_eq!(ys.len(), k * dim);
    debug_assert!(coeffs.len() >= rows * k);
    if rows * dim >= PAR_MIN_ELEMS && k >= 2 {
        let workers = threads::ncpus().min(16).min(k);
        threads::par_row_chunks_mut(ys, dim, workers, |r0, chunk| {
            gemv_t_multi_sharded(panel, rows, dim, coeffs, k, r0, chunk);
        });
    } else {
        gemv_t_multi_sharded(panel, rows, dim, coeffs, k, 0, ys);
    }
}

/// Serial body of [`panel_gemv_t_multi`] over the RHS rows `r0..` held in
/// `ys_chunk` (whole rows of the full `k × dim` output).
#[inline]
fn gemv_t_multi_sharded<E: Elem>(
    panel: &[E],
    rows: usize,
    dim: usize,
    coeffs: &[f64],
    k: usize,
    r0: usize,
    ys_chunk: &mut [E],
) {
    for i in 0..rows {
        let row = &panel[i * dim..i * dim + dim];
        for (rl, y) in ys_chunk.chunks_exact_mut(dim).enumerate() {
            let c = coeffs[i * k + r0 + rl];
            if c != 0.0 {
                axpy(c, row, y);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(dot(&a, &b), 32.0);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, 9.0, 12.0]);
        assert!((nrm2(&a) - 14f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn sub_add_dist() {
        let a = [3.0, 4.0];
        let b = [0.0, 0.0];
        let mut out = [0.0; 2];
        sub(&a, &b, &mut out);
        assert_eq!(out, a);
        add(&a, &a, &mut out);
        assert_eq!(out, [6.0, 8.0]);
        assert!((dist2(&a, &b) - 5.0).abs() < 1e-12);
        add_scaled(&a, 2.0, &a, &mut out);
        assert_eq!(out, [9.0, 12.0]);
        let mut n = a;
        negate(&mut n);
        assert_eq!(n, [-3.0, -4.0]);
    }

    #[test]
    fn panel_kernels_match_naive() {
        // 3 factors of dim 4, panel row-major.
        let panel = [
            1.0, 2.0, 3.0, 4.0, //
            0.5, -1.0, 0.0, 2.0, //
            -1.0, 1.0, -1.0, 1.0,
        ];
        let x = [1.0, 0.0, -1.0, 2.0];
        let mut c = [0.0; 3];
        panel_gemv(&panel, 3, 4, &x, &mut c);
        assert_eq!(c, [6.0, 4.5, 2.0]);
        let mut y = [1.0; 4];
        panel_gemv_t(&panel, 3, 4, &c, &mut y);
        // y[j] = 1 + Σ_i c[i] * panel[i][j]
        for j in 0..4 {
            let want = 1.0 + c[0] * panel[j] + c[1] * panel[4 + j] + c[2] * panel[8 + j];
            assert!((y[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn panel_multi_matches_single() {
        let panel = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3 rows × dim 2
        let xs = [1.0, -1.0, 2.0, 0.5]; // 2 RHS × dim 2
        let mut cm = [0.0; 6];
        panel_gemv_multi(&panel, 3, 2, &xs, 2, &mut cm);
        for r in 0..2 {
            let x = &xs[r * 2..r * 2 + 2];
            let mut c1 = [0.0; 3];
            panel_gemv(&panel, 3, 2, x, &mut c1);
            for i in 0..3 {
                assert_eq!(cm[i * 2 + r], c1[i]);
            }
        }
        let mut ym = [0.0; 4];
        panel_gemv_t_multi(&panel, 3, 2, &cm, 2, &mut ym);
        for r in 0..2 {
            let mut y1 = [0.0; 2];
            let c1: Vec<f64> = (0..3).map(|i| cm[i * 2 + r]).collect();
            panel_gemv_t(&panel, 3, 2, &c1, &mut y1);
            assert_eq!(&ym[r * 2..r * 2 + 2], &y1);
        }
    }

    #[test]
    fn f32_kernels_accumulate_in_f64() {
        // 1e6 elements of 1e-3: f32 naive accumulation loses precision badly;
        // the generic dot must carry the reduction in f64.
        let n = 1_000_000;
        let a = vec![1e-3f32; n];
        let d = dot(&a, &a);
        assert!((d - 1e-6 * n as f64).abs() / (1e-6 * n as f64) < 1e-6);
    }

    #[test]
    fn f32_panel_matches_f64_panel() {
        // Same factors in both precisions: the f32 sweep must agree with the
        // f64 one to f32 storage tolerance (exactly-representable inputs keep
        // the dots identical; only output narrowing differs).
        let panel64 = [0.5, -1.25, 2.0, 0.75, 1.5, -0.5];
        let panel32: Vec<f32> = panel64.iter().map(|&x| x as f32).collect();
        let x64 = [1.0, -2.0, 0.5];
        let x32: Vec<f32> = x64.iter().map(|&x| x as f32).collect();
        let mut c64 = [0.0; 2];
        let mut c32 = [0.0; 2];
        panel_gemv(&panel64, 2, 3, &x64, &mut c64);
        panel_gemv(&panel32, 2, 3, &x32, &mut c32);
        assert_eq!(c64, c32); // dyadic inputs: f64-accumulated dots match exactly
        let mut y64 = [0.25; 3];
        let mut y32 = [0.25f32; 3];
        panel_gemv_t(&panel64, 2, 3, &c64, &mut y64);
        panel_gemv_t(&panel32, 2, 3, &c32, &mut y32);
        for j in 0..3 {
            assert!((y64[j] - y32[j] as f64).abs() < 1e-6);
        }
    }

    #[test]
    fn multi_parallel_path_matches_serial() {
        // Cross the PAR_MIN_ELEMS threshold so the sharded path runs, and
        // compare against per-RHS serial kernels. f64 dots are computed
        // identically regardless of chunking, so results are exact.
        let rows = 6;
        let dim = PAR_MIN_ELEMS / 4; // rows*dim comfortably above threshold
        let k = 3;
        let mut rng = crate::util::rng::Rng::new(0x9E37);
        let panel: Vec<f64> = (0..rows * dim).map(|_| rng.normal()).collect();
        let xs: Vec<f64> = (0..k * dim).map(|_| rng.normal()).collect();
        let mut cm = vec![0.0; rows * k];
        panel_gemv_multi(&panel, rows, dim, &xs, k, &mut cm);
        for r in 0..k {
            let mut c1 = vec![0.0; rows];
            panel_gemv(&panel, rows, dim, &xs[r * dim..(r + 1) * dim], &mut c1);
            for i in 0..rows {
                assert_eq!(cm[i * k + r], c1[i], "coeff ({i},{r})");
            }
        }
        let mut ym = vec![0.0; k * dim];
        panel_gemv_t_multi(&panel, rows, dim, &cm, k, &mut ym);
        for r in 0..k {
            let mut y1 = vec![0.0; dim];
            let c1: Vec<f64> = (0..rows).map(|i| cm[i * k + r]).collect();
            panel_gemv_t(&panel, rows, dim, &c1, &mut y1);
            assert_eq!(&ym[r * dim..(r + 1) * dim], &y1[..], "rhs {r}");
        }
    }
}
