//! Compressed Sparse Row matrix (f64).
//!
//! The paper's bi-level LR experiments run on sparse text datasets (20news,
//! real-sim). Our synthetic analogues preserve that sparsity, and the inner
//! problem's gradient/Hessian-vector products are CSR matvecs — the hot loop
//! of the Fig. 1/2/E.1/E.2 experiments.

#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from per-row (col, value) triplets; entries within a row may be
    /// unsorted and duplicated (duplicates are summed).
    pub fn from_rows(rows: usize, cols: usize, mut entries: Vec<(usize, usize, f64)>) -> Csr {
        entries.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(entries.len());
        for (r, c, v) in entries {
            assert!(r < rows && c < cols, "entry out of bounds");
            if indptr[r + 1] > 0
                && indices.len() > indptr[r]
                && *indices.last().unwrap() == c
                && indptr[r + 1] == indices.len()
            {
                // duplicate within the same row: accumulate
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
                indptr[r + 1] = indices.len();
            }
        }
        // prefix-max to fill empty rows
        for r in 1..=rows {
            if indptr[r] < indptr[r - 1] {
                indptr[r] = indptr[r - 1];
            }
        }
        Csr {
            rows,
            cols,
            indptr,
            indices,
            values,
        }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// out = A x   (out: rows)
    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for r in 0..self.rows {
            let mut acc = 0.0;
            for k in self.indptr[r]..self.indptr[r + 1] {
                acc += self.values[k] * x[self.indices[k]];
            }
            out[r] = acc;
        }
    }

    /// out = Aᵀ x   (out: cols)
    pub fn matvec_t(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        crate::linalg::vecops::zero(out);
        for r in 0..self.rows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for k in self.indptr[r]..self.indptr[r + 1] {
                out[self.indices[k]] += self.values[k] * xr;
            }
        }
    }

    /// out = Aᵀ (d ⊙ (A x)) — the LR Hessian-vector product core,
    /// fused to avoid materializing A x twice. `tmp` must have `rows` slots.
    pub fn hvp(&self, d: &[f64], x: &[f64], tmp: &mut [f64], out: &mut [f64]) {
        self.matvec(x, tmp);
        for r in 0..self.rows {
            tmp[r] *= d[r];
        }
        self.matvec_t(tmp, out);
    }

    /// Dot product of row r with x.
    pub fn row_dot(&self, r: usize, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for k in self.indptr[r]..self.indptr[r + 1] {
            acc += self.values[k] * x[self.indices[k]];
        }
        acc
    }

    /// Scale each row to unit l2 norm (tf-idf-style normalization).
    pub fn normalize_rows(&mut self) {
        for r in 0..self.rows {
            let lo = self.indptr[r];
            let hi = self.indptr[r + 1];
            let nrm: f64 = self.values[lo..hi].iter().map(|v| v * v).sum::<f64>().sqrt();
            if nrm > 0.0 {
                for v in &mut self.values[lo..hi] {
                    *v /= nrm;
                }
            }
        }
    }

    /// Extract a row-subset as a new CSR (dataset train/val/test splits).
    pub fn select_rows(&self, rows: &[usize]) -> Csr {
        let mut entries = Vec::new();
        for (new_r, &r) in rows.iter().enumerate() {
            for k in self.indptr[r]..self.indptr[r + 1] {
                entries.push((new_r, self.indices[k], self.values[k]));
            }
        }
        Csr::from_rows(rows.len(), self.cols, entries)
    }

    /// Dense conversion (tests only).
    pub fn to_dense(&self) -> crate::linalg::dmat::DMat {
        let mut m = crate::linalg::dmat::DMat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.indptr[r]..self.indptr[r + 1] {
                m[(r, self.indices[k])] += self.values[k];
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Csr {
        let mut entries = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.uniform() < density {
                    entries.push((r, c, rng.normal()));
                }
            }
        }
        Csr::from_rows(rows, cols, entries)
    }

    #[test]
    fn matvec_matches_dense() {
        prop::check("csr-matvec", 20, |rng| {
            let (r, c) = (2 + rng.below(20), 2 + rng.below(20));
            let a = random_csr(rng, r, c, 0.3);
            let d = a.to_dense();
            let x = rng.normal_vec(c);
            let mut y1 = vec![0.0; r];
            let mut y2 = vec![0.0; r];
            a.matvec(&x, &mut y1);
            d.matvec(&x, &mut y2);
            prop::ensure_close_vec(&y1, &y2, 1e-10, "matvec")?;
            let xt = rng.normal_vec(r);
            let mut z1 = vec![0.0; c];
            let mut z2 = vec![0.0; c];
            a.matvec_t(&xt, &mut z1);
            d.matvec_t(&xt, &mut z2);
            prop::ensure_close_vec(&z1, &z2, 1e-10, "matvec_t")
        });
    }

    #[test]
    fn duplicates_sum() {
        let a = Csr::from_rows(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 5.0)]);
        assert_eq!(a.nnz(), 2);
        let d = a.to_dense();
        assert_eq!(d[(0, 0)], 3.0);
        assert_eq!(d[(1, 1)], 5.0);
    }

    #[test]
    fn empty_rows_ok() {
        let a = Csr::from_rows(3, 2, vec![(2, 1, 4.0)]);
        let mut y = vec![0.0; 3];
        a.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![0.0, 0.0, 4.0]);
    }

    #[test]
    fn hvp_fused_matches_composed() {
        let mut rng = Rng::new(17);
        let a = random_csr(&mut rng, 15, 8, 0.4);
        let d: Vec<f64> = (0..15).map(|_| rng.uniform() + 0.1).collect();
        let x = rng.normal_vec(8);
        let mut tmp = vec![0.0; 15];
        let mut out = vec![0.0; 8];
        a.hvp(&d, &x, &mut tmp, &mut out);
        // composed
        let mut ax = vec![0.0; 15];
        a.matvec(&x, &mut ax);
        for i in 0..15 {
            ax[i] *= d[i];
        }
        let mut out2 = vec![0.0; 8];
        a.matvec_t(&ax, &mut out2);
        for (u, v) in out.iter().zip(&out2) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn normalize_and_select() {
        let mut a = Csr::from_rows(2, 3, vec![(0, 0, 3.0), (0, 2, 4.0), (1, 1, 2.0)]);
        a.normalize_rows();
        assert!((a.row_dot(0, &[3.0, 0.0, 4.0]) - 5.0).abs() < 1e-12); // (3/5)*3+(4/5)*4 = 5
        let sub = a.select_rows(&[1]);
        assert_eq!(sub.rows, 1);
        assert_eq!(sub.nnz(), 1);
    }
}
