//! Dense + sparse linear-algebra substrate.
//!
//! Everything the optimization stack needs, self-contained: BLAS-1 vector
//! kernels over `&[f64]`, a small row-major dense matrix, CSR sparse
//! matrices (the synthetic text datasets are sparse like 20news/real-sim),
//! and a partial-pivot LU solve used to compute *exact* `J⁻¹ v` ground truth
//! for the inversion-quality experiments (Fig. 2-right, Fig. E.3).

pub mod csr;
pub mod dmat;
pub mod lu;
pub mod vecops;

pub use csr::Csr;
pub use dmat::DMat;
pub use vecops::*;
