//! ℓ2-regularized logistic regression — the paper's flagship bi-level
//! benchmark (eq. 2; Fig. 1, Fig. 2-left, Fig. E.1).
//!
//! Inner problem (θ is the *log* regularization strength, as in HOAG):
//!
//! ```text
//! r_θ(z) = (1/n) Σᵢ log(1 + exp(−yᵢ xᵢᵀz)) + ½ e^θ ‖z‖²
//! g_θ(z) = ∇_z r_θ(z) = (1/n) Xᵀ σ' + e^θ z
//! J_{g_θ}(z) = (1/n) Xᵀ D X + e^θ I    (symmetric positive definite)
//! ```
//!
//! Outer loss: unregularized validation logistic loss; the test split is
//! only used for the reported curves, exactly as footnote 5 warns.

use crate::linalg::csr::Csr;
use crate::problems::{InnerProblem, OuterLoss};

/// σ(x) numerically-stable.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// log(1 + exp(−m)) numerically-stable.
#[inline]
pub fn log1pexp_neg(m: f64) -> f64 {
    if m > 0.0 {
        (-m).exp().ln_1p()
    } else {
        -m + m.exp().ln_1p()
    }
}

/// A labelled sparse dataset split. Labels in {−1, +1}.
pub struct LogRegData {
    pub x: Csr,
    pub y: Vec<f64>,
}

impl LogRegData {
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Mean logistic loss (no regularization).
    pub fn loss(&self, z: &[f64]) -> f64 {
        let n = self.n();
        let mut acc = 0.0;
        for i in 0..n {
            let m = self.y[i] * self.x.row_dot(i, z);
            acc += log1pexp_neg(m);
        }
        acc / n as f64
    }

    /// ∇ of the mean logistic loss.
    pub fn loss_grad(&self, z: &[f64]) -> Vec<f64> {
        let n = self.n();
        let mut coeff = vec![0.0; n];
        for i in 0..n {
            let m = self.y[i] * self.x.row_dot(i, z);
            // dℓ/dm = −σ(−m); chain through m = y·xᵀz.
            coeff[i] = -self.y[i] * sigmoid(-m) / n as f64;
        }
        let mut out = vec![0.0; self.x.cols];
        self.x.matvec_t(&coeff, &mut out);
        out
    }

    /// Classification error rate (for accuracy reporting).
    pub fn error_rate(&self, z: &[f64]) -> f64 {
        let n = self.n();
        let wrong = (0..n)
            .filter(|&i| self.y[i] * self.x.row_dot(i, z) <= 0.0)
            .count();
        wrong as f64 / n as f64
    }
}

/// The bi-level LR problem: train split defines the inner problem.
pub struct LogRegInner {
    pub train: LogRegData,
}

impl LogRegInner {
    fn reg(&self, theta: &[f64]) -> f64 {
        theta[0].exp()
    }

    /// The per-sample Hessian weights D_ii = σ(mᵢ)(1 − σ(mᵢ)).
    fn hess_weights(&self, z: &[f64]) -> Vec<f64> {
        let n = self.train.n();
        (0..n)
            .map(|i| {
                let m = self.train.x.row_dot(i, z);
                let s = sigmoid(m);
                s * (1.0 - s) / n as f64
            })
            .collect()
    }
}

impl InnerProblem for LogRegInner {
    fn dim(&self) -> usize {
        self.train.x.cols
    }
    fn theta_dim(&self) -> usize {
        1
    }
    fn is_symmetric(&self) -> bool {
        true
    }
    fn g(&self, theta: &[f64], z: &[f64]) -> Vec<f64> {
        let mut g = self.train.loss_grad(z);
        let lam = self.reg(theta);
        for (gi, zi) in g.iter_mut().zip(z) {
            *gi += lam * zi;
        }
        g
    }
    fn inner_value(&self, theta: &[f64], z: &[f64]) -> Option<f64> {
        let lam = self.reg(theta);
        Some(self.train.loss(z) + 0.5 * lam * crate::linalg::vecops::dot(z, z))
    }
    fn jvp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64> {
        // (1/n) Xᵀ D X v + e^θ v
        let d = self.hess_weights(z);
        let mut tmp = vec![0.0; self.train.n()];
        let mut out = vec![0.0; self.dim()];
        self.train.x.hvp(&d, v, &mut tmp, &mut out);
        let lam = self.reg(theta);
        for (oi, vi) in out.iter_mut().zip(v) {
            *oi += lam * vi;
        }
        out
    }
    fn vjp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64> {
        self.jvp(theta, z, v) // Hessian is symmetric
    }
    fn vjp_theta(&self, theta: &[f64], z: &[f64], w: &[f64]) -> Vec<f64> {
        // ∂g/∂θ = e^θ z
        vec![self.reg(theta) * crate::linalg::vecops::dot(w, z)]
    }
    fn dg_dtheta_col(&self, theta: &[f64], z: &[f64], j: usize) -> Vec<f64> {
        assert_eq!(j, 0);
        let lam = self.reg(theta);
        z.iter().map(|&x| lam * x).collect()
    }
}

/// Outer loss: validation logistic loss (gradient used for the
/// hypergradient), test logistic loss for reporting.
pub struct LogRegOuter {
    pub val: LogRegData,
    pub test: LogRegData,
}

impl OuterLoss for LogRegOuter {
    fn value(&self, z: &[f64]) -> f64 {
        self.val.loss(z)
    }
    fn grad(&self, z: &[f64]) -> Vec<f64> {
        self.val.loss_grad(z)
    }
    fn test_value(&self, z: &[f64]) -> f64 {
        self.test.loss(z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::csr::Csr;
    use crate::problems::fd_check_jvp;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn toy_data(rng: &mut Rng, n: usize, d: usize) -> LogRegData {
        let mut entries = Vec::new();
        let truth = rng.normal_vec(d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let mut m = 0.0;
            for j in 0..d {
                if rng.uniform() < 0.5 {
                    let v = rng.normal();
                    entries.push((i, j, v));
                    m += v * truth[j];
                }
            }
            y.push(if m + 0.3 * rng.normal() > 0.0 { 1.0 } else { -1.0 });
        }
        LogRegData {
            x: Csr::from_rows(n, d, entries),
            y,
        }
    }

    #[test]
    fn gradient_matches_fd() {
        prop::check("lr-grad-fd", 8, |rng| {
            let data = toy_data(rng, 20, 6);
            let prob = LogRegInner { train: data };
            let theta = [rng.normal() * 0.5 - 1.0];
            let z = rng.normal_vec(6);
            let g = prob.g(&theta, &z);
            let eps = 1e-6;
            for i in 0..6 {
                let mut zp = z.clone();
                zp[i] += eps;
                let mut zm = z.clone();
                zm[i] -= eps;
                let fd = (prob.inner_value(&theta, &zp).unwrap()
                    - prob.inner_value(&theta, &zm).unwrap())
                    / (2.0 * eps);
                prop::ensure_close(g[i], fd, 1e-4, "grad vs fd")?;
            }
            Ok(())
        });
    }

    #[test]
    fn hessian_vp_matches_fd() {
        prop::check("lr-hvp-fd", 8, |rng| {
            let data = toy_data(rng, 25, 5);
            let prob = LogRegInner { train: data };
            let theta = [-1.0];
            let z = rng.normal_vec(5);
            let v = rng.normal_vec(5);
            let (fd, jvp) = fd_check_jvp(&prob, &theta, &z, &v, 1e-5);
            prop::ensure_close_vec(&fd, &jvp, 1e-4, "hvp vs fd")
        });
    }

    #[test]
    fn dg_dtheta_matches_fd() {
        prop::check("lr-dgdtheta-fd", 8, |rng| {
            let data = toy_data(rng, 15, 4);
            let prob = LogRegInner { train: data };
            let theta = [0.2];
            let z = rng.normal_vec(4);
            let eps = 1e-6;
            let gp = prob.g(&[theta[0] + eps], &z);
            let gm = prob.g(&[theta[0] - eps], &z);
            let fd: Vec<f64> = gp.iter().zip(&gm).map(|(a, b)| (a - b) / (2.0 * eps)).collect();
            prop::ensure_close_vec(&fd, &prob.dg_dtheta_col(&theta, &z, 0), 1e-5, "∂g/∂θ")?;
            // and wᵀ∂g/∂θ consistency
            let w = rng.normal_vec(4);
            let via_col = crate::linalg::vecops::dot(&w, &prob.dg_dtheta_col(&theta, &z, 0));
            prop::ensure_close(prob.vjp_theta(&theta, &z, &w)[0], via_col, 1e-10, "vjp_theta")
        });
    }

    #[test]
    fn sigmoid_stable() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(-800.0) >= 0.0 && sigmoid(-800.0) < 1e-300);
        assert!((sigmoid(800.0) - 1.0).abs() < 1e-15);
        assert!(log1pexp_neg(800.0) >= 0.0);
        assert!((log1pexp_neg(-800.0) - 800.0).abs() < 1e-9);
    }

    #[test]
    fn training_reduces_loss_and_error() {
        let mut rng = Rng::new(33);
        let data = toy_data(&mut rng, 200, 10);
        let prob = LogRegInner { train: data };
        let theta = [(-4.0f64)];
        let obj = (10usize, |z: &[f64]| {
            (
                prob.inner_value(&theta, z).unwrap(),
                prob.g(&theta, z),
            )
        });
        let res = crate::solvers::minimize::lbfgs_minimize(
            &obj,
            &vec![0.0; 10],
            &crate::solvers::minimize::MinimizeOptions::default(),
            None,
            None,
        );
        assert!(res.converged, "grad_norm={}", res.grad_norm);
        let loss0 = prob.train.loss(&vec![0.0; 10]);
        assert!(prob.train.loss(&res.z) < loss0 * 0.9);
        assert!(prob.train.error_rate(&res.z) < 0.3);
    }
}
