//! Regularized nonlinear least squares (eq. 12; Fig. E.2).
//!
//! Inner problem (θ = log regularization, σ = sigmoid):
//!
//! ```text
//! r_θ(z) = (1/2n) Σⱼ (yⱼ − σ(zᵀxⱼ))² + ½ e^θ ‖z‖²,   y ∈ {0, 1}
//! ```
//!
//! The inner problem is **non-convex** (its Hessian can be indefinite) —
//! the paper uses it precisely because qN inverse-Hessian estimates are
//! harder here, making OPA's benefit more pronounced (§E.2).

use crate::linalg::csr::Csr;
use crate::problems::{logreg::sigmoid, InnerProblem, OuterLoss};

/// A labelled dataset with y ∈ {0, 1} (note: different label convention
/// from LogReg's ±1, matching eq. 12).
pub struct NlsData {
    pub x: Csr,
    pub y: Vec<f64>,
}

impl NlsData {
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// (1/2n) Σ (y − σ(m))².
    pub fn loss(&self, z: &[f64]) -> f64 {
        let n = self.n();
        let mut acc = 0.0;
        for i in 0..n {
            let s = sigmoid(self.x.row_dot(i, z));
            acc += (self.y[i] - s) * (self.y[i] - s);
        }
        0.5 * acc / n as f64
    }

    /// Gradient of `loss`.
    pub fn loss_grad(&self, z: &[f64]) -> Vec<f64> {
        let n = self.n();
        let mut coeff = vec![0.0; n];
        for i in 0..n {
            let s = sigmoid(self.x.row_dot(i, z));
            // d/dm ½(y−σ)² = (σ−y)·σ(1−σ)
            coeff[i] = (s - self.y[i]) * s * (1.0 - s) / n as f64;
        }
        let mut out = vec![0.0; self.x.cols];
        self.x.matvec_t(&coeff, &mut out);
        out
    }
}

pub struct NlsInner {
    pub train: NlsData,
}

impl NlsInner {
    fn reg(&self, theta: &[f64]) -> f64 {
        theta[0].exp()
    }

    /// Per-sample second-derivative weights of ℓ(m) = ½(y−σ(m))²:
    /// ℓ''(m) = σ'(m)² + (σ−y)·σ''(m),  σ'' = σ(1−σ)(1−2σ).
    fn hess_weights(&self, z: &[f64]) -> Vec<f64> {
        let n = self.train.n();
        (0..n)
            .map(|i| {
                let s = sigmoid(self.train.x.row_dot(i, z));
                let sp = s * (1.0 - s);
                let spp = sp * (1.0 - 2.0 * s);
                (sp * sp + (s - self.train.y[i]) * spp) / n as f64
            })
            .collect()
    }
}

impl InnerProblem for NlsInner {
    fn dim(&self) -> usize {
        self.train.x.cols
    }
    fn theta_dim(&self) -> usize {
        1
    }
    fn is_symmetric(&self) -> bool {
        true
    }
    fn g(&self, theta: &[f64], z: &[f64]) -> Vec<f64> {
        let mut g = self.train.loss_grad(z);
        let lam = self.reg(theta);
        for (gi, zi) in g.iter_mut().zip(z) {
            *gi += lam * zi;
        }
        g
    }
    fn inner_value(&self, theta: &[f64], z: &[f64]) -> Option<f64> {
        Some(self.train.loss(z) + 0.5 * self.reg(theta) * crate::linalg::vecops::dot(z, z))
    }
    fn jvp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64> {
        let d = self.hess_weights(z);
        let mut tmp = vec![0.0; self.train.n()];
        let mut out = vec![0.0; self.dim()];
        self.train.x.hvp(&d, v, &mut tmp, &mut out);
        let lam = self.reg(theta);
        for (oi, vi) in out.iter_mut().zip(v) {
            *oi += lam * vi;
        }
        out
    }
    fn vjp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64> {
        self.jvp(theta, z, v)
    }
    fn vjp_theta(&self, theta: &[f64], z: &[f64], w: &[f64]) -> Vec<f64> {
        vec![self.reg(theta) * crate::linalg::vecops::dot(w, z)]
    }
    fn dg_dtheta_col(&self, theta: &[f64], z: &[f64], j: usize) -> Vec<f64> {
        assert_eq!(j, 0);
        let lam = self.reg(theta);
        z.iter().map(|&x| lam * x).collect()
    }
}

pub struct NlsOuter {
    pub val: NlsData,
    pub test: NlsData,
}

impl OuterLoss for NlsOuter {
    fn value(&self, z: &[f64]) -> f64 {
        self.val.loss(z)
    }
    fn grad(&self, z: &[f64]) -> Vec<f64> {
        self.val.loss_grad(z)
    }
    fn test_value(&self, z: &[f64]) -> f64 {
        self.test.loss(z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::csr::Csr;
    use crate::problems::fd_check_jvp;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn toy(rng: &mut Rng, n: usize, d: usize) -> NlsData {
        let truth = rng.normal_vec(d);
        let mut entries = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let mut m = 0.0;
            for j in 0..d {
                if rng.uniform() < 0.6 {
                    let v = rng.normal();
                    entries.push((i, j, v));
                    m += v * truth[j];
                }
            }
            y.push(if m > 0.0 { 1.0 } else { 0.0 });
        }
        NlsData {
            x: Csr::from_rows(n, d, entries),
            y,
        }
    }

    #[test]
    fn gradient_matches_fd() {
        prop::check("nls-grad-fd", 8, |rng| {
            let prob = NlsInner { train: toy(rng, 20, 5) };
            let theta = [-1.0];
            let z = rng.normal_vec(5);
            let g = prob.g(&theta, &z);
            let eps = 1e-6;
            for i in 0..5 {
                let mut zp = z.clone();
                zp[i] += eps;
                let mut zm = z.clone();
                zm[i] -= eps;
                let fd = (prob.inner_value(&theta, &zp).unwrap()
                    - prob.inner_value(&theta, &zm).unwrap())
                    / (2.0 * eps);
                prop::ensure_close(g[i], fd, 1e-4, "grad vs fd")?;
            }
            Ok(())
        });
    }

    #[test]
    fn hvp_matches_fd() {
        prop::check("nls-hvp-fd", 8, |rng| {
            let prob = NlsInner { train: toy(rng, 30, 6) };
            let theta = [-0.5];
            let z = rng.normal_vec(6);
            let v = rng.normal_vec(6);
            let (fd, jvp) = fd_check_jvp(&prob, &theta, &z, &v, 1e-5);
            prop::ensure_close_vec(&fd, &jvp, 1e-3, "hvp vs fd")
        });
    }

    #[test]
    fn hessian_can_be_indefinite() {
        // The defining feature of this benchmark: find a point where some
        // per-sample weight is negative (so the unregularized Hessian can be
        // indefinite). With y=1 and large positive margin, (σ−y)σ'' > 0 but
        // at y=0, small margins give negative curvature contributions.
        let mut rng = Rng::new(12);
        let prob = NlsInner { train: toy(&mut rng, 50, 8) };
        let mut found_negative = false;
        for _ in 0..50 {
            let z = rng.normal_vec(8);
            let w = prob.hess_weights(&z);
            if w.iter().any(|&x| x < 0.0) {
                found_negative = true;
                break;
            }
        }
        assert!(found_negative, "nonconvexity witness not found");
    }
}
