//! Inner problems for the bi-level experiments.
//!
//! A bi-level problem (eq. 1 of the paper) is specified by an
//! [`InnerProblem`] (`g_θ(z) = 0` defines `z*(θ)`) and an [`OuterLoss`]
//! (`L(z*)` evaluated on validation data; test data used for reporting).
//!
//! * [`logreg`] — ℓ2-regularized logistic regression (eq. 2; Fig. 1, 2, E.1)
//! * [`nls`] — regularized nonlinear least squares (eq. 12; Fig. E.2)
//! * [`quadratic`] — synthetic quadratic with a closed-form hypergradient,
//!   the oracle against which all hypergradient strategies are tested.

pub mod logreg;
pub mod nls;
pub mod quadratic;

/// The inner problem: `g_θ(z) = 0`. For smooth convex inner problems,
/// `g_θ = ∇_z r_θ` and `J_{g_θ}` is the (symmetric) Hessian; for DEQs it is
/// the (nonsymmetric) Jacobian of the root equation.
pub trait InnerProblem: Sync {
    /// dimension d of z
    fn dim(&self) -> usize;
    /// number of hyperparameters
    fn theta_dim(&self) -> usize;
    /// whether J_{g_θ} is symmetric (Hessian case → CG backward solver)
    fn is_symmetric(&self) -> bool;
    /// residual g_θ(z)
    fn g(&self, theta: &[f64], z: &[f64]) -> Vec<f64>;
    /// inner objective value r_θ(z), if this is a minimization problem
    fn inner_value(&self, theta: &[f64], z: &[f64]) -> Option<f64>;
    /// J_{g_θ}(z) · v
    fn jvp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64>;
    /// J_{g_θ}(z)ᵀ · v  (== jvp for symmetric problems)
    fn vjp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64>;
    /// wᵀ · ∂g_θ/∂θ|_z — returns a `theta_dim()` vector
    fn vjp_theta(&self, theta: &[f64], z: &[f64], w: &[f64]) -> Vec<f64>;
    /// column j of ∂g_θ/∂θ|_z — the OPA direction (eq. 5) for scalar θ
    fn dg_dtheta_col(&self, theta: &[f64], z: &[f64], j: usize) -> Vec<f64>;
}

/// The outer objective `L` and its reporting twin.
pub trait OuterLoss: Sync {
    /// validation loss — the quantity hypergradient descent minimizes
    fn value(&self, z: &[f64]) -> f64;
    /// ∇_z L(z) on validation data
    fn grad(&self, z: &[f64]) -> Vec<f64>;
    /// held-out test loss — what the paper's figures plot
    fn test_value(&self, z: &[f64]) -> f64;
}

/// Finite-difference check utility shared by the problem tests: directional
/// derivative of g against jvp.
#[cfg(test)]
pub(crate) fn fd_check_jvp(
    prob: &dyn InnerProblem,
    theta: &[f64],
    z: &[f64],
    v: &[f64],
    eps: f64,
) -> (Vec<f64>, Vec<f64>) {
    let mut z_p = z.to_vec();
    let mut z_m = z.to_vec();
    for i in 0..z.len() {
        z_p[i] += eps * v[i];
        z_m[i] -= eps * v[i];
    }
    let gp = prob.g(theta, &z_p);
    let gm = prob.g(theta, &z_m);
    let fd: Vec<f64> = gp
        .iter()
        .zip(&gm)
        .map(|(a, b)| (a - b) / (2.0 * eps))
        .collect();
    (fd, prob.jvp(theta, z, v))
}
