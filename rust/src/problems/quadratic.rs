//! Synthetic quadratic bi-level problem with closed-form everything —
//! the oracle for testing hypergradient strategies.
//!
//! Inner:  r_θ(z) = ½ zᵀ A z − bᵀ z + ½ e^θ ‖z‖²
//!   ⇒ g_θ(z) = (A + e^θ I) z − b,  J_{g_θ} = A + e^θ I (symmetric),
//!     z*(θ) = (A + e^θ I)⁻¹ b.
//! Outer:  L(z) = ½ ‖z − t‖²  (t = validation target)
//!   ⇒ exact hypergradient via implicit differentiation:
//!     dL/dθ = −∇L(z*)ᵀ J⁻¹ (e^θ z*) = −e^θ (z*−t)ᵀ (A+e^θI)⁻¹ z*.

use crate::linalg::dmat::DMat;
use crate::linalg::lu::Lu;
use crate::problems::{InnerProblem, OuterLoss};
use crate::util::rng::Rng;

pub struct QuadraticBilevel {
    pub a: DMat,
    pub b: Vec<f64>,
    pub target: Vec<f64>,
}

impl QuadraticBilevel {
    pub fn random(n: usize, rng: &mut Rng) -> Self {
        QuadraticBilevel {
            a: DMat::random_spd(n, 0.3, 5.0, rng),
            b: rng.normal_vec(n),
            target: rng.normal_vec(n),
        }
    }

    fn reg(&self, theta: &[f64]) -> f64 {
        theta[0].exp()
    }

    /// Closed-form inner solution z*(θ).
    pub fn z_star(&self, theta: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut m = self.a.clone();
        let lam = self.reg(theta);
        for i in 0..n {
            m[(i, i)] += lam;
        }
        Lu::factor(&m).unwrap().solve(&self.b)
    }

    /// Exact hypergradient dL/dθ at θ (oracle).
    pub fn exact_hypergrad(&self, theta: &[f64]) -> f64 {
        let n = self.dim();
        let lam = self.reg(theta);
        let z = self.z_star(theta);
        let mut m = self.a.clone();
        for i in 0..n {
            m[(i, i)] += lam;
        }
        let lu = Lu::factor(&m).unwrap();
        // w = J⁻ᵀ ∇L = J⁻¹ ∇L (symmetric)
        let grad_l: Vec<f64> = z.iter().zip(&self.target).map(|(a, b)| a - b).collect();
        let w = lu.solve(&grad_l);
        // dL/dθ = − wᵀ ∂g/∂θ = − wᵀ (λ z)
        -lam * crate::linalg::vecops::dot(&w, &z)
    }
}

impl InnerProblem for QuadraticBilevel {
    fn dim(&self) -> usize {
        self.b.len()
    }
    fn theta_dim(&self) -> usize {
        1
    }
    fn is_symmetric(&self) -> bool {
        true
    }
    fn g(&self, theta: &[f64], z: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut out = vec![0.0; n];
        self.a.matvec(z, &mut out);
        let lam = self.reg(theta);
        for i in 0..n {
            out[i] += lam * z[i] - self.b[i];
        }
        out
    }
    fn inner_value(&self, theta: &[f64], z: &[f64]) -> Option<f64> {
        let n = self.dim();
        let mut az = vec![0.0; n];
        self.a.matvec(z, &mut az);
        let quad = 0.5 * crate::linalg::vecops::dot(z, &az);
        let lin = crate::linalg::vecops::dot(&self.b, z);
        let reg = 0.5 * self.reg(theta) * crate::linalg::vecops::dot(z, z);
        Some(quad - lin + reg)
    }
    fn jvp(&self, theta: &[f64], _z: &[f64], v: &[f64]) -> Vec<f64> {
        let n = self.dim();
        let mut out = vec![0.0; n];
        self.a.matvec(v, &mut out);
        let lam = self.reg(theta);
        for i in 0..n {
            out[i] += lam * v[i];
        }
        out
    }
    fn vjp(&self, theta: &[f64], z: &[f64], v: &[f64]) -> Vec<f64> {
        self.jvp(theta, z, v) // symmetric
    }
    fn vjp_theta(&self, theta: &[f64], z: &[f64], w: &[f64]) -> Vec<f64> {
        // ∂g/∂θ = e^θ z  ⇒  wᵀ ∂g/∂θ = e^θ ⟨w, z⟩
        vec![self.reg(theta) * crate::linalg::vecops::dot(w, z)]
    }
    fn dg_dtheta_col(&self, theta: &[f64], z: &[f64], j: usize) -> Vec<f64> {
        assert_eq!(j, 0);
        let lam = self.reg(theta);
        z.iter().map(|&x| lam * x).collect()
    }
}

/// Outer loss for the quadratic oracle problem.
pub struct QuadraticOuter {
    pub target: Vec<f64>,
}

impl OuterLoss for QuadraticOuter {
    fn value(&self, z: &[f64]) -> f64 {
        0.5 * z
            .iter()
            .zip(&self.target)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
    }
    fn grad(&self, z: &[f64]) -> Vec<f64> {
        z.iter().zip(&self.target).map(|(a, b)| a - b).collect()
    }
    fn test_value(&self, z: &[f64]) -> f64 {
        self.value(z)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::fd_check_jvp;
    use crate::util::prop;

    #[test]
    fn g_is_gradient_of_inner_value() {
        prop::check("quad-grad", 10, |rng| {
            let p = QuadraticBilevel::random(6, rng);
            let theta = [rng.normal() * 0.5];
            let z = rng.normal_vec(6);
            let g = p.g(&theta, &z);
            let eps = 1e-6;
            for i in 0..6 {
                let mut zp = z.clone();
                zp[i] += eps;
                let mut zm = z.clone();
                zm[i] -= eps;
                let fd = (p.inner_value(&theta, &zp).unwrap()
                    - p.inner_value(&theta, &zm).unwrap())
                    / (2.0 * eps);
                prop::ensure_close(g[i], fd, 1e-5, "∇r vs fd")?;
            }
            Ok(())
        });
    }

    #[test]
    fn jvp_matches_fd() {
        prop::check("quad-jvp", 10, |rng| {
            let p = QuadraticBilevel::random(8, rng);
            let theta = [0.1];
            let z = rng.normal_vec(8);
            let v = rng.normal_vec(8);
            let (fd, jvp) = fd_check_jvp(&p, &theta, &z, &v, 1e-6);
            prop::ensure_close_vec(&fd, &jvp, 1e-5, "jvp vs fd")
        });
    }

    #[test]
    fn z_star_is_root() {
        let mut rng = crate::util::rng::Rng::new(8);
        let p = QuadraticBilevel::random(10, &mut rng);
        let theta = [-0.3];
        let z = p.z_star(&theta);
        let g = p.g(&theta, &z);
        assert!(crate::linalg::vecops::nrm2(&g) < 1e-9);
    }

    #[test]
    fn exact_hypergrad_matches_fd_on_outer() {
        prop::check("quad-hypergrad-fd", 10, |rng| {
            let p = QuadraticBilevel::random(7, rng);
            let outer = QuadraticOuter {
                target: p.target.clone(),
            };
            let theta = [rng.normal() * 0.3];
            let eps = 1e-6;
            let lp = outer.value(&p.z_star(&[theta[0] + eps]));
            let lm = outer.value(&p.z_star(&[theta[0] - eps]));
            let fd = (lp - lm) / (2.0 * eps);
            prop::ensure_close(p.exact_hypergrad(&theta), fd, 1e-4, "hypergrad vs fd")
        });
    }
}
