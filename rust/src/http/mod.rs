//! Pure-Rust HTTP/1.1 front for the sharded serving tier.
//!
//! The serve stack ([`crate::serve`]) ends at an in-process API:
//! [`ShardedRouter::submit`](crate::serve::ShardedRouter::submit) /
//! `collect`. This module puts a network edge on it with **zero new
//! dependencies** — std `TcpListener`, the crate's own thread/Condvar
//! idioms, and a hand-rolled JSON layer — so the whole binary stays a
//! single self-contained artifact.
//!
//! * [`proto`] — HTTP/1.1 framing: bounded request parsing with typed
//!   4xx errors ([`proto::HttpError`]), `Content-Length`-only bodies
//!   (no chunked smuggling surface), header-injection hardening on
//!   ingress and egress.
//! * [`json`] — a **lazy path-scanner** ([`json::LazyDoc`]): `/v1/solve`
//!   bodies are scanned for the few known paths and decoded straight
//!   into `f64` buffers, without materializing a document tree; strict
//!   on every byte it touches, silent on bytes after the last hit. Plus
//!   [`json::JsonBuilder`], the allocation-light response writer whose
//!   number format round-trips `f64` bits exactly (shortest-round-trip
//!   `Display`, pinned by its unit tests).
//! * [`gateway`] — the typed bridge: [`gateway::Gateway`] wraps a
//!   [`ShardedRouter`](crate::serve::ShardedRouter) with a collector
//!   thread for per-request rendezvous, and [`gateway::serve_status`] is
//!   the **canonical** `ServeError → HTTP status` mapping (exactly one
//!   status per variant, exhaustively matched).
//! * [`server`] — accept thread + worker pool + **admission control**:
//!   connections beyond the budget shed with an inline `429 +
//!   Retry-After` before any parse runs; `/healthz` and `/metrics`
//!   expose supervision, breaker, staleness and quarantine telemetry.
//! * [`client`] — the minimal blocking client the loopback load driver
//!   and integration tests use, so everything is exercised over real
//!   sockets.
//!
//! Endpoints: `POST /v1/solve`, `GET /healthz`, `GET /metrics` — see
//! `docs/adr/005-http-front-end.md` for the design record and
//! `README.md` for the wire format.

pub mod client;
pub mod gateway;
pub mod json;
pub mod proto;
pub mod server;

pub use client::{ClientResponse, HttpClient};
pub use gateway::{
    breaker_code, parse_solve_call, serve_status, Gateway, SolveBackend, SolveCall, SolveReply,
};
pub use json::{JsonBuilder, LazyDoc, ScanError, MAX_DEPTH};
pub use proto::{
    read_request, status_reason, HttpError, RecvError, Request, Response, DEFAULT_MAX_BODY,
    MAX_HEADERS, MAX_LINE_BYTES,
};
pub use server::{HttpConfig, HttpCounters, HttpServer};
