//! Minimal blocking HTTP/1.1 client for the loopback drivers and tests.
//!
//! Just enough protocol to talk to [`crate::http::server::HttpServer`]:
//! keep-alive connections, `Content-Length` framing, no redirects, no
//! TLS. The load generator's TCP driver and the integration tests both
//! sit on it, so the server is always exercised through real sockets
//! rather than hand-built byte strings.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

/// One parsed response.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    /// Lower-cased header names.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == lower)
            .map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (lossy — diagnostics only).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// A keep-alive connection to one server. Reconnects transparently if
/// the server closed the previous exchange (`Connection: close`).
pub struct HttpClient {
    addr: SocketAddr,
    conn: Option<BufReader<TcpStream>>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<HttpClient> {
        Ok(HttpClient {
            addr,
            conn: Some(BufReader::new(TcpStream::connect(addr)?)),
        })
    }

    /// `POST path` with a JSON body (plus optional extra headers).
    pub fn post_json(
        &mut self,
        path: &str,
        body: &str,
        extra: &[(&str, &str)],
    ) -> std::io::Result<ClientResponse> {
        self.request("POST", path, extra, Some(body.as_bytes()))
    }

    /// `GET path`.
    pub fn get(&mut self, path: &str) -> std::io::Result<ClientResponse> {
        self.request("GET", path, &[], None)
    }

    /// Issue one request, reconnecting once if the pooled connection was
    /// closed server-side between exchanges.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        extra: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> std::io::Result<ClientResponse> {
        for attempt in 0..2 {
            if self.conn.is_none() {
                self.conn = Some(BufReader::new(TcpStream::connect(self.addr)?));
            }
            match self.exchange(method, path, extra, body) {
                Ok(resp) => {
                    if resp.header("connection") == Some("close") {
                        self.conn = None;
                    }
                    return Ok(resp);
                }
                Err(e) if attempt == 0 => {
                    // A keep-alive connection the server dropped between
                    // exchanges surfaces as EOF/reset on the next use —
                    // retry once on a fresh connection.
                    self.conn = None;
                    let retriable = matches!(
                        e.kind(),
                        std::io::ErrorKind::UnexpectedEof
                            | std::io::ErrorKind::ConnectionReset
                            | std::io::ErrorKind::BrokenPipe
                    );
                    if !retriable {
                        return Err(e);
                    }
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!("loop returns on the second attempt");
    }

    fn exchange(
        &mut self,
        method: &str,
        path: &str,
        extra: &[(&str, &str)],
        body: Option<&[u8]>,
    ) -> std::io::Result<ClientResponse> {
        let reader = self.conn.as_mut().expect("connection established above");
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: shine\r\n");
        for (k, v) in extra {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        if let Some(b) = body {
            head.push_str(&format!("content-type: application/json\r\ncontent-length: {}\r\n", b.len()));
        }
        head.push_str("\r\n");
        {
            let w = reader.get_mut();
            w.write_all(head.as_bytes())?;
            if let Some(b) = body {
                w.write_all(b)?;
            }
            w.flush()?;
        }
        read_response(reader)
    }
}

fn read_response<R: BufRead>(r: &mut R) -> std::io::Result<ClientResponse> {
    let status_line = read_line(r)?;
    let status = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| bad(format!("malformed status line: {status_line:?}")))?;
    let mut headers = Vec::new();
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            break;
        }
        let Some(colon) = line.find(':') else {
            return Err(bad(format!("malformed header: {line:?}")));
        };
        headers.push((
            line[..colon].trim().to_ascii_lowercase(),
            line[colon + 1..].trim().to_string(),
        ));
    }
    let len = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .ok_or_else(|| bad("response without content-length".to_string()))?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(ClientResponse {
        status,
        headers,
        body,
    })
}

fn read_line<R: BufRead>(r: &mut R) -> std::io::Result<String> {
    let mut buf = Vec::new();
    let got = r.read_until(b'\n', &mut buf)?;
    if got == 0 || buf.last() != Some(&b'\n') {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "connection closed mid-response",
        ));
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| bad("non-UTF-8 response head".to_string()))
}

fn bad(msg: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}
