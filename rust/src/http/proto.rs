//! Minimal HTTP/1.1 framing: request parsing with hard caps and typed 4xx
//! errors, response writing with header sanitization.
//!
//! This is deliberately a *subset* of HTTP/1.1 — exactly what a JSON solve
//! API needs and nothing a parser can be confused by:
//!
//! * `Content-Length` bodies only; `Transfer-Encoding` is rejected with a
//!   typed 400 (chunked parsing is the classic request-smuggling surface,
//!   and no serve client needs it).
//! * Every limit is explicit: request-line and header-line length
//!   ([`MAX_LINE_BYTES`]), header count ([`MAX_HEADERS`]), body size (the
//!   server's configured cap → 413). Overload degrades to a typed status,
//!   never to unbounded buffering.
//! * Header names must be RFC 7230 tokens and values must be free of
//!   control bytes — a value containing CR/LF is a 400 at ingress, and
//!   [`Response`] strips CR/LF from outgoing values, so header injection
//!   dies at both ends (pinned by `rust/tests/http_parse.rs`).
//!
//! Parsing failures are [`HttpError`]s carrying the status to serve; IO
//! and connection teardown are kept separate in [`RecvError`] so the
//! connection loop can distinguish "send a 4xx and close" from "peer went
//! away".

use std::fmt;
use std::io::{BufRead, Read, Write};

/// Cap on one request/status/header line, bytes (includes the CRLF).
pub const MAX_LINE_BYTES: usize = 8 * 1024;
/// Cap on the number of headers per request.
pub const MAX_HEADERS: usize = 64;
/// Default cap on a request body, bytes (a d=4096 solve request with z0 +
/// cotangent at ~25 bytes/float is ~200 KiB; 8 MiB leaves headroom
/// without letting one connection hold the box).
pub const DEFAULT_MAX_BODY: usize = 8 << 20;

/// A typed protocol failure: the status to answer with and a short,
/// header-safe message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub msg: String,
}

impl HttpError {
    pub fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError {
            status,
            msg: msg.into(),
        }
    }
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}: {}", self.status, status_reason(self.status), self.msg)
    }
}

impl std::error::Error for HttpError {}

/// Why a request could not be read off the connection.
#[derive(Debug)]
pub enum RecvError {
    /// Clean end of stream between requests (keep-alive close).
    Closed,
    /// Transport error (or the peer vanished mid-request).
    Io(std::io::Error),
    /// Malformed request: answer with the typed status, then close.
    Proto(HttpError),
}

/// One parsed request. Header names are stored lower-cased (HTTP headers
/// are case-insensitive); values have surrounding whitespace trimmed.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub target: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the client asked to keep the connection open (HTTP/1.1
    /// default unless `Connection: close`).
    pub keep_alive: bool,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let lower = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == lower)
            .map(|(_, v)| v.as_str())
    }
}

/// Read one request. `Ok(None)` never occurs — absence is signalled via
/// [`RecvError::Closed`] so the match in the connection loop is total.
pub fn read_request<R: BufRead>(r: &mut R, max_body: usize) -> Result<Request, RecvError> {
    let line = match read_line(r, true)? {
        Some(l) => l,
        None => return Err(RecvError::Closed),
    };
    let (method, target, version) = parse_request_line(&line).map_err(RecvError::Proto)?;
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, false)? {
            Some(l) => l,
            None => {
                return Err(RecvError::Proto(HttpError::new(
                    400,
                    "truncated request head",
                )))
            }
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(RecvError::Proto(HttpError::new(431, "too many headers")));
        }
        headers.push(parse_header_line(&line).map_err(RecvError::Proto)?);
    }
    if headers.iter().any(|(k, _)| k == "transfer-encoding") {
        // Refuse rather than mis-frame: chunked bodies are the classic
        // smuggling surface and no solve client needs them.
        return Err(RecvError::Proto(HttpError::new(
            400,
            "transfer-encoding is not supported; use content-length",
        )));
    }
    let mut content_length = 0usize;
    let cl_headers: Vec<&str> = headers
        .iter()
        .filter(|(k, _)| k == "content-length")
        .map(|(_, v)| v.as_str())
        .collect();
    if cl_headers.len() > 1 {
        return Err(RecvError::Proto(HttpError::new(
            400,
            "conflicting content-length headers",
        )));
    }
    if let Some(v) = cl_headers.first() {
        content_length = v
            .parse::<usize>()
            .map_err(|_| RecvError::Proto(HttpError::new(400, "malformed content-length")))?;
    } else if method == "POST" || method == "PUT" {
        return Err(RecvError::Proto(HttpError::new(
            411,
            "content-length required",
        )));
    }
    if content_length > max_body {
        return Err(RecvError::Proto(HttpError::new(
            413,
            format!("body exceeds the {max_body}-byte cap"),
        )));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        r.read_exact(&mut body).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                RecvError::Proto(HttpError::new(400, "truncated body"))
            } else {
                RecvError::Io(e)
            }
        })?;
    }
    let keep_alive = {
        let conn = headers
            .iter()
            .find(|(k, _)| k == "connection")
            .map(|(_, v)| v.to_ascii_lowercase());
        match conn.as_deref() {
            Some("close") => false,
            Some("keep-alive") => true,
            // HTTP/1.1 defaults to keep-alive, HTTP/1.0 to close.
            _ => version >= 1,
        }
    };
    Ok(Request {
        method,
        target,
        headers,
        body,
        keep_alive,
    })
}

/// Read one CRLF-terminated line (tolerating bare LF), without the
/// terminator. `Ok(None)` = clean EOF before any byte; EOF mid-line is a
/// typed 400 via the caller. `at_boundary` marks the gap between requests,
/// where EOF is a normal keep-alive close rather than truncation.
fn read_line<R: BufRead>(r: &mut R, at_boundary: bool) -> Result<Option<Vec<u8>>, RecvError> {
    let mut buf = Vec::new();
    // Cap the read: a line longer than MAX_LINE_BYTES is rejected without
    // buffering the rest of it.
    let got = r
        .by_ref()
        .take(MAX_LINE_BYTES as u64)
        .read_until(b'\n', &mut buf)
        .map_err(RecvError::Io)?;
    if got == 0 {
        return if at_boundary {
            Ok(None)
        } else {
            Err(RecvError::Proto(HttpError::new(400, "truncated request")))
        };
    }
    if buf.last() != Some(&b'\n') {
        return Err(RecvError::Proto(if buf.len() >= MAX_LINE_BYTES {
            HttpError::new(431, "header line too long")
        } else {
            HttpError::new(400, "truncated request")
        }));
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    Ok(Some(buf))
}

/// `METHOD SP target SP HTTP/1.x` — returns (method, target, minor).
fn parse_request_line(line: &[u8]) -> Result<(String, String, u8), HttpError> {
    let s = std::str::from_utf8(line)
        .map_err(|_| HttpError::new(400, "request line is not UTF-8"))?;
    let mut parts = s.split(' ');
    let (Some(method), Some(target), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(HttpError::new(400, "malformed request line"));
    };
    if method.is_empty() || !method.bytes().all(|c| c.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed method"));
    }
    if !target.starts_with('/') || target.bytes().any(|c| c <= 0x20 || c == 0x7f) {
        return Err(HttpError::new(400, "malformed request target"));
    }
    let minor = match version {
        "HTTP/1.1" => 1u8,
        "HTTP/1.0" => 0u8,
        _ => return Err(HttpError::new(400, "unsupported HTTP version")),
    };
    Ok((method.to_string(), target.to_string(), minor))
}

/// `name: value` with an RFC 7230 token name and a control-free value —
/// the ingress half of header-injection hardening.
fn parse_header_line(line: &[u8]) -> Result<(String, String), HttpError> {
    let s =
        std::str::from_utf8(line).map_err(|_| HttpError::new(400, "header is not UTF-8"))?;
    let Some(colon) = s.find(':') else {
        return Err(HttpError::new(400, "malformed header"));
    };
    let (name, rest) = s.split_at(colon);
    if name.is_empty() || !name.bytes().all(is_token_byte) {
        return Err(HttpError::new(400, "malformed header name"));
    }
    let value = rest[1..].trim();
    if value.bytes().any(|c| c < 0x20 || c == 0x7f) {
        return Err(HttpError::new(400, "control byte in header value"));
    }
    Ok((name.to_ascii_lowercase(), value.to_string()))
}

fn is_token_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric()
        || matches!(
            c,
            b'!' | b'#' | b'$' | b'%' | b'&' | b'\'' | b'*' | b'+' | b'-' | b'.' | b'^' | b'_'
                | b'`' | b'|' | b'~'
        )
}

/// One response, written with `Content-Length` framing.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            headers: vec![("content-type".into(), "application/json".into())],
            body: body.into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            headers: vec![(
                "content-type".into(),
                "text/plain; version=0.0.4".into(),
            )],
            body: body.as_bytes().to_vec(),
        }
    }

    /// Add a header. The egress half of injection hardening: CR/LF/NUL in
    /// the value are stripped, so a hostile string can never mint a header
    /// or split the response.
    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        let clean: String = value.chars().filter(|c| !matches!(c, '\r' | '\n' | '\0')).collect();
        self.headers.push((name.to_ascii_lowercase(), clean));
        self
    }

    /// Serialize to `w`. `keep_alive` controls the `Connection` header the
    /// client sees (the server closes after writing when it is `false`).
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\n",
            self.status,
            status_reason(self.status)
        );
        for (k, v) in &self.headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str(&format!("content-length: {}\r\n", self.body.len()));
        head.push_str(if keep_alive {
            "connection: keep-alive\r\n"
        } else {
            "connection: close\r\n"
        });
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Canonical reason phrases for every status this server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(bytes: &[u8]) -> Result<Request, RecvError> {
        read_request(&mut BufReader::new(bytes), DEFAULT_MAX_BODY)
    }

    #[test]
    fn parses_a_simple_post() {
        let req = parse(
            b"POST /v1/solve HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\n{\"a\"",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/solve");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"{\"a\"");
        assert!(req.keep_alive);
    }

    #[test]
    fn eof_between_requests_is_a_clean_close() {
        assert!(matches!(parse(b""), Err(RecvError::Closed)));
    }

    #[test]
    fn truncation_and_framing_failures_are_typed_4xx() {
        let cases: [&[u8]; 8] = [
            b"POST /v1/solve HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort",
            b"POST /v1/solve HTTP/1.1\r\nHost: x\r\n",
            b"GARBAGE\r\n\r\n",
            b"GET /x HTTP/2.0\r\n\r\n",
            b"POST /v1/solve HTTP/1.1\r\n\r\n",
            b"POST / HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\nxx",
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
            b"GET /sp ace HTTP/1.1\r\n\r\n",
        ];
        for c in cases {
            match parse(c) {
                Err(RecvError::Proto(e)) => {
                    assert!((400..500).contains(&e.status), "{c:?} -> {e:?}")
                }
                other => panic!("{c:?} parsed as {other:?}"),
            }
        }
    }

    #[test]
    fn header_injection_is_rejected_on_both_sides() {
        // Ingress: a raw CR inside a header value cannot arrive intact —
        // read_line splits on LF, so an embedded CRLF mints a *new* line
        // that must itself parse as a header; a lone CR is a control byte.
        let r = parse(b"GET / HTTP/1.1\r\nx-a: ok\revil: 1\r\n\r\n");
        assert!(matches!(r, Err(RecvError::Proto(e)) if e.status == 400));
        // Egress: CR/LF stripped from values before writing.
        let mut out = Vec::new();
        Response::json(200, "{}".into())
            .with_header("x-echo", "a\r\nx-fake: 1")
            .write_to(&mut out, true)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(!s.contains("x-fake: 1\r\n"), "{s}");
        assert!(s.contains("x-echo: ax-fake: 1\r\n"), "{s}");
    }

    #[test]
    fn oversized_lines_and_bodies_are_capped() {
        let mut big = b"GET /".to_vec();
        big.extend(std::iter::repeat(b'a').take(MAX_LINE_BYTES));
        big.extend_from_slice(b" HTTP/1.1\r\n\r\n");
        assert!(matches!(
            parse(&big),
            Err(RecvError::Proto(e)) if e.status == 431
        ));
        let r = parse(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n");
        assert!(matches!(r, Err(RecvError::Proto(e)) if e.status == 413));
    }

    #[test]
    fn http_1_0_defaults_to_close() {
        let req = parse(b"GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert!(!req.keep_alive);
    }
}
