//! Lazy JSON for the serve front door: a path-scanner that extracts the
//! few fields a solve request needs **without building a tree**, and a
//! streaming builder for responses.
//!
//! A `POST /v1/solve` body is dominated by two long float arrays (`z0`,
//! `cotangent` — the fixed-point seed and the SHINE backward right-hand
//! side). A full-tree parse ([`crate::util::json::parse`]) would allocate
//! a `Json::Arr` of boxed `Json::Num`s per element and then immediately
//! flatten it back into a `Vec<f64>` — most of the work is building a
//! structure the handler never looks at. [`LazyDoc`] instead *scans*: it
//! walks the object's keys with a validating cursor, skips values it was
//! not asked for, and parses numbers directly out of the byte slice into
//! the caller's `Vec<f64>` (the mik-sdk ADR-002 observation: lazy
//! path-scanning beats full-tree parsing by an order of magnitude when
//! only a few paths are read).
//!
//! The scanner is **strict on what it touches and silent on what it
//! skips**: every byte on the path to a requested value (including skipped
//! sibling values) is grammar-checked — malformed input, truncation,
//! nesting beyond [`MAX_DEPTH`], lone surrogates, unescaped control
//! characters, and out-of-range numbers all surface as a typed
//! [`ScanError`] (never a panic — pinned by the fuzz loops in
//! `rust/tests/http_parse.rs`) — but bytes *after* the last requested
//! value are never read. Duplicate keys resolve first-match-wins, the
//! natural order for a single forward scan.
//!
//! Responses use [`JsonBuilder`], which streams fields into one `String`
//! with the same number formatting as [`crate::util::json`] (shortest
//! round-trip float `Display`, integral values as integers) — the bit-
//! parity contract between the wire and the in-process router rides on
//! every `f64` surviving the format/parse round trip exactly.

use crate::util::json::{write_escaped, write_num};
use std::fmt;

/// Maximum value-nesting depth the scanner will follow. Deeper documents
/// are rejected with a typed error instead of recursing toward a stack
/// overflow (the classic deep-nesting attack on recursive parsers).
pub const MAX_DEPTH: usize = 64;

/// Typed scan failure: byte offset plus a static message. The HTTP layer
/// maps every `ScanError` to a 400 response.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScanError {
    /// Byte offset in the document where the error was detected.
    pub pos: usize,
    pub msg: &'static str,
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} at byte {}", self.msg, self.pos)
    }
}

impl std::error::Error for ScanError {}

/// A JSON document scanned lazily, by path. Borrowing, zero-copy: the
/// document bytes are walked per query and only requested values are
/// materialized.
pub struct LazyDoc<'a> {
    b: &'a [u8],
}

impl<'a> LazyDoc<'a> {
    pub fn new(bytes: &'a [u8]) -> LazyDoc<'a> {
        LazyDoc { b: bytes }
    }

    /// Raw bytes of the value at `path` (object keys, outermost first):
    /// `Ok(None)` when any key on the path is absent, `Err` when the bytes
    /// walked to reach it are not valid JSON. Bytes after the found value
    /// are not scanned — that is the lazy contract.
    pub fn path(&self, path: &[&str]) -> Result<Option<&'a [u8]>, ScanError> {
        assert!(!path.is_empty(), "empty path");
        let mut c = Cur { b: self.b, i: 0 };
        let mut seg = 0usize;
        loop {
            c.ws();
            if c.peek() != Some(b'{') {
                if seg == 0 {
                    return Err(c.err("document is not a JSON object"));
                }
                // An intermediate value of a non-object type: the path
                // cannot continue, so it is absent (not malformed).
                return Ok(None);
            }
            c.i += 1;
            c.ws();
            if c.peek() == Some(b'}') {
                return Ok(None);
            }
            'members: loop {
                c.ws();
                if c.peek() != Some(b'"') {
                    return Err(c.err("expected object key"));
                }
                let (ks, ke) = c.skip_string()?;
                let hit = key_matches(&self.b[ks..ke], path[seg], ks)?;
                c.ws();
                if c.bump()? != b':' {
                    return Err(c.err_at(c.i - 1, "expected ':' after object key"));
                }
                c.ws();
                if hit {
                    if seg + 1 == path.len() {
                        let start = c.i;
                        c.skip_value(0)?;
                        return Ok(Some(&self.b[start..c.i]));
                    }
                    seg += 1;
                    // Descend: the outer loop re-enters expecting '{'.
                    break 'members;
                }
                c.skip_value(0)?;
                c.ws();
                match c.bump()? {
                    b',' => continue,
                    b'}' => return Ok(None),
                    _ => return Err(c.err_at(c.i - 1, "expected ',' or '}' in object")),
                }
            }
        }
    }

    /// The number at `path`, rejecting non-number values and overflow
    /// (`1e999` is a typed error, never an `inf` smuggled into a solve).
    pub fn f64_at(&self, path: &[&str]) -> Result<Option<f64>, ScanError> {
        match self.path(path)? {
            None => Ok(None),
            Some(sl) => {
                let pos = offset_in(self.b, sl);
                Ok(Some(parse_number(sl, pos)?))
            }
        }
    }

    /// The non-negative integer at `path` (accepts any integral JSON
    /// number representation, e.g. `1e2`).
    pub fn u32_at(&self, path: &[&str]) -> Result<Option<u32>, ScanError> {
        match self.path(path)? {
            None => Ok(None),
            Some(sl) => {
                let pos = offset_in(self.b, sl);
                let x = parse_number(sl, pos)?;
                if x < 0.0 || x != x.trunc() || x > u32::MAX as f64 {
                    return Err(ScanError {
                        pos,
                        msg: "expected a non-negative integer",
                    });
                }
                Ok(Some(x as u32))
            }
        }
    }

    /// The string at `path`, unescaped.
    pub fn str_at(&self, path: &[&str]) -> Result<Option<String>, ScanError> {
        match self.path(path)? {
            None => Ok(None),
            Some(sl) => {
                let pos = offset_in(self.b, sl);
                if sl.first() != Some(&b'"') {
                    return Err(ScanError {
                        pos,
                        msg: "expected a string",
                    });
                }
                Ok(Some(unescape(&sl[1..sl.len() - 1], pos + 1)?))
            }
        }
    }

    /// The flat number array at `path`, parsed straight into a `Vec<f64>`
    /// — the hot path for `z0`/cotangent payloads. `max_len` bounds the
    /// allocation (the handler passes the model dimension, so an oversized
    /// array is a typed error before any memory is committed to it).
    pub fn f64_vec_at(
        &self,
        path: &[&str],
        max_len: usize,
    ) -> Result<Option<Vec<f64>>, ScanError> {
        let Some(sl) = self.path(path)? else {
            return Ok(None);
        };
        let base = offset_in(self.b, sl);
        let mut c = Cur { b: sl, i: 0 };
        c.ws();
        if c.peek() != Some(b'[') {
            return Err(ScanError {
                pos: base + c.i,
                msg: "expected an array of numbers",
            });
        }
        c.i += 1;
        let mut out = Vec::new();
        c.ws();
        if c.peek() == Some(b']') {
            return Ok(Some(out));
        }
        loop {
            c.ws();
            let start = c.i;
            c.skip_number()
                .map_err(|e| ScanError { pos: base + e.pos, msg: e.msg })?;
            if out.len() >= max_len {
                return Err(ScanError {
                    pos: base + start,
                    msg: "array longer than the model dimension",
                });
            }
            out.push(parse_number(&sl[start..c.i], base + start)?);
            c.ws();
            match c.bump().map_err(|e| ScanError { pos: base + e.pos, msg: e.msg })? {
                b',' => continue,
                b']' => return Ok(Some(out)),
                _ => {
                    return Err(ScanError {
                        pos: base + c.i - 1,
                        msg: "expected ',' or ']' in array",
                    })
                }
            }
        }
    }

    /// Strict full validation: exactly one JSON value plus whitespace.
    /// Not used on the serve hot path (that is the point of laziness);
    /// the differential fuzz tests use it to compare scanner strictness
    /// against the tree parser.
    pub fn validate(&self) -> Result<(), ScanError> {
        let mut c = Cur { b: self.b, i: 0 };
        c.ws();
        c.skip_value(0)?;
        c.ws();
        if c.i != c.b.len() {
            return Err(c.err("trailing bytes after JSON value"));
        }
        Ok(())
    }
}

/// Byte offset of subslice `sl` within `b` (both borrow the same buffer).
fn offset_in(b: &[u8], sl: &[u8]) -> usize {
    sl.as_ptr() as usize - b.as_ptr() as usize
}

/// Parse one grammar-validated number token, rejecting anything else and
/// overflow to infinity.
fn parse_number(sl: &[u8], pos: usize) -> Result<f64, ScanError> {
    let mut c = Cur { b: sl, i: 0 };
    c.skip_number().map_err(|e| ScanError {
        pos: pos + e.pos,
        msg: e.msg,
    })?;
    if c.i != sl.len() {
        return Err(ScanError {
            pos,
            msg: "expected a number",
        });
    }
    let s = std::str::from_utf8(sl).map_err(|_| ScanError {
        pos,
        msg: "invalid UTF-8 in number",
    })?;
    let x: f64 = s.parse().map_err(|_| ScanError {
        pos,
        msg: "malformed number",
    })?;
    if !x.is_finite() {
        return Err(ScanError {
            pos,
            msg: "number out of range",
        });
    }
    Ok(x)
}

/// Whether the raw (still-escaped) key bytes equal `want`. The fast path
/// is a direct byte compare (real keys are plain ASCII); keys containing
/// escapes are unescaped first so `"mo..."` still routes.
fn key_matches(raw: &[u8], want: &str, pos: usize) -> Result<bool, ScanError> {
    if !raw.contains(&b'\\') {
        return Ok(raw == want.as_bytes());
    }
    Ok(unescape(raw, pos)? == want)
}

/// Unescape the content bytes of a JSON string (quotes already stripped,
/// escapes already grammar-checked by `skip_string`). Handles `\uXXXX`
/// including surrogate pairs; lone surrogates are a typed error.
fn unescape(raw: &[u8], pos: usize) -> Result<String, ScanError> {
    let mut out = String::with_capacity(raw.len());
    let mut i = 0usize;
    while i < raw.len() {
        let c = raw[i];
        if c != b'\\' {
            // Raw UTF-8 passthrough: collect the longest escape-free run
            // and validate it as UTF-8 once.
            let start = i;
            while i < raw.len() && raw[i] != b'\\' {
                i += 1;
            }
            let s = std::str::from_utf8(&raw[start..i]).map_err(|_| ScanError {
                pos: pos + start,
                msg: "invalid UTF-8 in string",
            })?;
            out.push_str(s);
            continue;
        }
        // skip_string guarantees a valid escape head follows.
        i += 1;
        match raw[i] {
            b'"' => out.push('"'),
            b'\\' => out.push('\\'),
            b'/' => out.push('/'),
            b'b' => out.push('\u{0008}'),
            b'f' => out.push('\u{000C}'),
            b'n' => out.push('\n'),
            b'r' => out.push('\r'),
            b't' => out.push('\t'),
            b'u' => {
                let hi = hex4(raw, i + 1, pos)?;
                i += 4;
                let cp = if (0xD800..0xDC00).contains(&hi) {
                    // High surrogate: require the paired low surrogate.
                    if raw.len() < i + 7 || raw[i + 1] != b'\\' || raw[i + 2] != b'u' {
                        return Err(ScanError {
                            pos: pos + i,
                            msg: "lone surrogate in \\u escape",
                        });
                    }
                    let lo = hex4(raw, i + 3, pos)?;
                    if !(0xDC00..0xE000).contains(&lo) {
                        return Err(ScanError {
                            pos: pos + i,
                            msg: "lone surrogate in \\u escape",
                        });
                    }
                    i += 6;
                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                } else if (0xDC00..0xE000).contains(&hi) {
                    return Err(ScanError {
                        pos: pos + i,
                        msg: "lone surrogate in \\u escape",
                    });
                } else {
                    hi
                };
                out.push(char::from_u32(cp).ok_or(ScanError {
                    pos: pos + i,
                    msg: "invalid \\u escape",
                })?);
            }
            _ => {
                return Err(ScanError {
                    pos: pos + i,
                    msg: "invalid escape",
                })
            }
        }
        i += 1;
    }
    Ok(out)
}

fn hex4(raw: &[u8], at: usize, pos: usize) -> Result<u32, ScanError> {
    if raw.len() < at + 4 {
        return Err(ScanError {
            pos: pos + at,
            msg: "truncated \\u escape",
        });
    }
    let mut v = 0u32;
    for k in 0..4 {
        let d = match raw[at + k] {
            c @ b'0'..=b'9' => (c - b'0') as u32,
            c @ b'a'..=b'f' => (c - b'a') as u32 + 10,
            c @ b'A'..=b'F' => (c - b'A') as u32 + 10,
            _ => {
                return Err(ScanError {
                    pos: pos + at + k,
                    msg: "invalid \\u escape",
                })
            }
        };
        v = v * 16 + d;
    }
    Ok(v)
}

/// The validating cursor all scans share.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn err(&self, msg: &'static str) -> ScanError {
        ScanError { pos: self.i, msg }
    }

    fn err_at(&self, pos: usize, msg: &'static str) -> ScanError {
        ScanError { pos, msg }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Result<u8, ScanError> {
        let c = self
            .peek()
            .ok_or(ScanError { pos: self.i, msg: "unexpected end of document" })?;
        self.i += 1;
        Ok(c)
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t') | Some(b'\n') | Some(b'\r')) {
            self.i += 1;
        }
    }

    /// Skip one string (cursor on the opening quote); returns the content
    /// byte range, quotes excluded. Escapes are shape-checked here so
    /// later unescaping cannot fail on structure.
    fn skip_string(&mut self) -> Result<(usize, usize), ScanError> {
        if self.bump()? != b'"' {
            return Err(self.err_at(self.i - 1, "expected a string"));
        }
        let start = self.i;
        loop {
            match self.bump()? {
                b'"' => return Ok((start, self.i - 1)),
                b'\\' => match self.bump()? {
                    b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {}
                    b'u' => {
                        for _ in 0..4 {
                            if !self.bump()?.is_ascii_hexdigit() {
                                return Err(self.err_at(self.i - 1, "invalid \\u escape"));
                            }
                        }
                    }
                    _ => return Err(self.err_at(self.i - 1, "invalid escape")),
                },
                c if c < 0x20 => {
                    return Err(self.err_at(self.i - 1, "unescaped control character in string"))
                }
                _ => {}
            }
        }
    }

    /// Skip one number token, validating the JSON grammar (`-?int frac?
    /// exp?`). Parsing to `f64` happens separately, on extraction.
    fn skip_number(&mut self) -> Result<(), ScanError> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        match self.peek() {
            Some(b'0') => self.i += 1,
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.i += 1;
                }
            }
            _ => return Err(self.err("expected a number")),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required after decimal point"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        Ok(())
    }

    fn skip_literal(&mut self, lit: &'static [u8]) -> Result<(), ScanError> {
        if self.b.len() < self.i + lit.len() || &self.b[self.i..self.i + lit.len()] != lit {
            return Err(self.err("invalid literal"));
        }
        self.i += lit.len();
        Ok(())
    }

    /// Skip one complete JSON value, validating as it goes. `depth` is the
    /// container-nesting level, bounded by [`MAX_DEPTH`] — the recursion
    /// cannot be driven deeper than ~64 frames by any input.
    fn skip_value(&mut self, depth: usize) -> Result<(), ScanError> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.ws();
        match self.peek() {
            Some(b'"') => self.skip_string().map(|_| ()),
            Some(b'{') => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    if self.peek() != Some(b'"') {
                        return Err(self.err("expected object key"));
                    }
                    self.skip_string()?;
                    self.ws();
                    if self.bump()? != b':' {
                        return Err(self.err_at(self.i - 1, "expected ':' after object key"));
                    }
                    self.skip_value(depth + 1)?;
                    self.ws();
                    match self.bump()? {
                        b',' => continue,
                        b'}' => return Ok(()),
                        _ => return Err(self.err_at(self.i - 1, "expected ',' or '}' in object")),
                    }
                }
            }
            Some(b'[') => {
                self.i += 1;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(());
                }
                loop {
                    self.skip_value(depth + 1)?;
                    self.ws();
                    match self.bump()? {
                        b',' => continue,
                        b']' => return Ok(()),
                        _ => return Err(self.err_at(self.i - 1, "expected ',' or ']' in array")),
                    }
                }
            }
            Some(b't') => self.skip_literal(b"true"),
            Some(b'f') => self.skip_literal(b"false"),
            Some(b'n') => self.skip_literal(b"null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.skip_number(),
            Some(_) => Err(self.err("unexpected byte")),
            None => Err(self.err("unexpected end of document")),
        }
    }
}

/// Streaming JSON object builder for responses: fields append directly to
/// one `String`, numbers in the same exact round-trip format as
/// [`crate::util::json`] (the wire half of the bit-parity contract).
pub struct JsonBuilder {
    buf: String,
    first: bool,
}

impl JsonBuilder {
    pub fn obj() -> JsonBuilder {
        JsonBuilder {
            buf: String::from("{"),
            first: true,
        }
    }

    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        write_escaped(&mut self.buf, k);
        self.buf.push(':');
    }

    pub fn num(mut self, k: &str, x: f64) -> Self {
        self.key(k);
        write_num(&mut self.buf, x);
        self
    }

    pub fn int(mut self, k: &str, x: i64) -> Self {
        self.key(k);
        let _ = std::fmt::Write::write_fmt(&mut self.buf, format_args!("{x}"));
        self
    }

    pub fn uint(mut self, k: &str, x: u64) -> Self {
        self.key(k);
        let _ = std::fmt::Write::write_fmt(&mut self.buf, format_args!("{x}"));
        self
    }

    pub fn text(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        write_escaped(&mut self.buf, v);
        self
    }

    pub fn boolean(mut self, k: &str, v: bool) -> Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// A pre-serialized JSON fragment (nested object/array). The caller
    /// guarantees validity.
    pub fn raw(mut self, k: &str, fragment: &str) -> Self {
        self.key(k);
        self.buf.push_str(fragment);
        self
    }

    /// A flat number array streamed from an iterator — the `z`/`w` vector
    /// fields, written without any intermediate tree.
    pub fn nums<I: IntoIterator<Item = f64>>(mut self, k: &str, it: I) -> Self {
        self.key(k);
        self.buf.push('[');
        for (i, x) in it.into_iter().enumerate() {
            if i > 0 {
                self.buf.push(',');
            }
            write_num(&mut self.buf, x);
        }
        self.buf.push(']');
        self
    }

    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn scans_paths_lazily() {
        let doc = br#"{"user":{"name":"ada","id":7},"z0":[1,2.5,-3e-1],"ok":true}"#;
        let d = LazyDoc::new(doc);
        assert_eq!(d.str_at(&["user", "name"]).unwrap().unwrap(), "ada");
        assert_eq!(d.u32_at(&["user", "id"]).unwrap().unwrap(), 7);
        assert_eq!(
            d.f64_vec_at(&["z0"], 8).unwrap().unwrap(),
            vec![1.0, 2.5, -0.3]
        );
        assert!(d.path(&["missing"]).unwrap().is_none());
        assert!(d.path(&["user", "missing"]).unwrap().is_none());
        // Path through a non-object is absent, not an error.
        assert!(d.path(&["ok", "x"]).unwrap().is_none());
    }

    #[test]
    fn duplicate_keys_resolve_first_match() {
        let d = LazyDoc::new(br#"{"a":1,"a":2}"#);
        assert_eq!(d.f64_at(&["a"]).unwrap().unwrap(), 1.0);
    }

    #[test]
    fn laziness_skips_garbage_after_the_hit() {
        // Bytes after the requested value are never scanned: the broken
        // tail is invisible to the path query (the lazy contract).
        let d = LazyDoc::new(br#"{"a":1,"b":<<<garbage"#);
        assert_eq!(d.f64_at(&["a"]).unwrap().unwrap(), 1.0);
        assert!(d.f64_at(&["b"]).is_err());
    }

    #[test]
    fn deep_nesting_is_a_typed_error_not_a_crash() {
        let mut doc = Vec::new();
        for _ in 0..100_000 {
            doc.push(b'[');
        }
        let d = LazyDoc::new(&doc);
        let e = d.validate().unwrap_err();
        assert_eq!(e.msg, "nesting too deep");
    }

    #[test]
    fn overflow_and_malformed_numbers_are_typed() {
        assert!(LazyDoc::new(br#"{"x":1e999}"#).f64_at(&["x"]).is_err());
        assert!(LazyDoc::new(br#"{"x":01}"#).validate().is_err());
        assert!(LazyDoc::new(br#"{"x":+1}"#).f64_at(&["x"]).is_err());
        assert!(LazyDoc::new(br#"{"x":1.}"#).f64_at(&["x"]).is_err());
        assert!(LazyDoc::new(br#"{"x":NaN}"#).f64_at(&["x"]).is_err());
    }

    #[test]
    fn unicode_escapes_round_trip() {
        let raw = "{\"s\":\"a\u{e9}\u{1F600}b\"}";
        let d = LazyDoc::new(raw.as_bytes());
        assert_eq!(d.str_at(&["s"]).unwrap().unwrap(), "a\u{e9}\u{1F600}b");
        // Surrogate-pair escape decodes; a lone surrogate is typed.
        let d = LazyDoc::new(br#"{"s":"\ud83d\ude00"}"#);
        assert_eq!(d.str_at(&["s"]).unwrap().unwrap(), "\u{1F600}");
        assert!(LazyDoc::new(br#"{"s":"\ud800"}"#).str_at(&["s"]).is_err());
        // Escaped keys still route.
        let d = LazyDoc::new(br#"{"m":5}"#);
        assert_eq!(d.f64_at(&["m"]).unwrap().unwrap(), 5.0);
    }

    #[test]
    fn builder_round_trips_through_the_tree_parser() {
        let s = JsonBuilder::obj()
            .uint("id", 7)
            .num("residual", 1.25e-9)
            .text("error", "queue \"full\"\n")
            .boolean("ok", false)
            .nums("z", [1.0, -0.5, 3e22])
            .raw("nested", "{\"a\":1}")
            .finish();
        let t = json::parse(&s).expect("builder output is valid JSON");
        assert_eq!(t.at(&["id"]).and_then(|j| j.as_f64()), Some(7.0));
        assert_eq!(
            t.at(&["error"]).and_then(|j| j.as_str()),
            Some("queue \"full\"\n")
        );
        assert_eq!(t.at(&["nested", "a"]).and_then(|j| j.as_f64()), Some(1.0));
        // And the lazy scanner agrees with itself on its own output.
        let d = LazyDoc::new(s.as_bytes());
        assert_eq!(
            d.f64_vec_at(&["z"], 4).unwrap().unwrap(),
            vec![1.0, -0.5, 3e22]
        );
        d.validate().unwrap();
    }

    #[test]
    fn f64_bits_survive_the_wire_format() {
        let vals = [
            1.0f64,
            -0.0,
            1.0 / 3.0,
            6.02214076e23,
            f64::MIN_POSITIVE,
            1e-300,
            -123456.789012345678,
        ];
        let s = JsonBuilder::obj().nums("v", vals).finish();
        let back = LazyDoc::new(s.as_bytes())
            .f64_vec_at(&["v"], 16)
            .unwrap()
            .unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} round trip");
        }
    }
}
