//! The bridge between HTTP and the sharded router: typed status mapping,
//! request/response JSON, and the completion-forwarding collector thread.
//!
//! [`ShardedRouter`] is a submit/collect machine — responses come back in
//! completion order on a shared queue, not per caller. The HTTP surface
//! needs per-request rendezvous, so [`Gateway`] runs **one collector
//! thread** that drains [`ShardedRouter::collect_timeout`] and delivers
//! each response to the slot its connection handler is parked on. Handlers
//! never touch the shared completion queue; the router's exactly-once
//! outcome contract becomes an exactly-once slot fill.
//!
//! The status mapping is canonical and lives in exactly one place
//! ([`serve_status`]): every [`ServeError`] variant maps to exactly one
//! HTTP status, pinned by an exhaustive-match unit test below. Admission
//! uses [`RetryPolicy::none`] by default — the server sheds with a fast
//! 429 + `Retry-After` and lets the *client* back off, instead of parking
//! connection handlers in server-side sleeps.

use crate::http::json::{JsonBuilder, LazyDoc};
use crate::http::proto::HttpError;
use crate::linalg::vecops::Elem;
use crate::serve::engine::BreakerState;
use crate::serve::scheduler::RetryPolicy;
use crate::serve::shard::{
    KeyMetrics, ServeError, ShardRequest, ShardResponse, ShardedRouter, SubmitError,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// The canonical [`ServeError`] → HTTP mapping: one status and one stable
/// machine-readable error token per variant. The exhaustive match (no
/// wildcard arm) means a new variant fails compilation here rather than
/// silently serving a default status; uniqueness is pinned by
/// `every_serve_error_has_exactly_one_status`.
pub fn serve_status(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::QueueFull { .. } => (429, "queue_full"),
        ServeError::DeadlineExceeded => (504, "deadline_exceeded"),
        ServeError::Unconverged => (422, "unconverged"),
        ServeError::ModelFault => (502, "model_fault"),
        ServeError::WorkerLost => (503, "worker_lost"),
    }
}

/// Numeric encoding of [`BreakerState`] for the `/metrics` exposition:
/// 0 = closed (healthy), 1 = open (degraded), 2 = half-open (probing).
pub fn breaker_code(b: BreakerState) -> u32 {
    match b {
        BreakerState::Closed => 0,
        BreakerState::Open { .. } => 1,
        BreakerState::HalfOpen => 2,
    }
}

/// One parsed `/v1/solve` call, precision-agnostic (`f64` is the wire
/// format; the backend narrows to its storage precision).
#[derive(Clone, Debug)]
pub struct SolveCall {
    pub model: u32,
    /// Initial iterate; `None` = zeros (the deterministic default every
    /// in-process driver uses).
    pub z0: Option<Vec<f64>>,
    pub cotangent: Vec<f64>,
    /// Relative deadline in seconds from admission; `None` never expires.
    pub deadline_s: Option<f64>,
}

/// What the backend answers: already rendered to status + JSON, plus the
/// header-borne retry hint and submit-attempt count.
#[derive(Clone, Debug)]
pub struct SolveReply {
    pub status: u16,
    /// JSON body (success document or `{"error", "message", ...}`).
    pub body: String,
    /// Backpressure hint, seconds (429 replies).
    pub retry_after: Option<f64>,
    /// Queue-full retries the submit path performed before resolving.
    pub attempts: usize,
}

impl SolveReply {
    fn error(status: u16, token: &str, message: &str, retry_after: Option<f64>) -> SolveReply {
        let mut b = JsonBuilder::obj().text("error", token).text("message", message);
        if let Some(ra) = retry_after {
            b = b.num("retry_after", ra);
        }
        SolveReply {
            status,
            body: b.finish(),
            retry_after,
            attempts: 0,
        }
    }
}

/// What the HTTP server needs from a solve tier. Object-safe so the
/// server is monomorphization-free: one `Arc<dyn SolveBackend>` serves
/// every panel-precision instantiation of [`Gateway`].
pub trait SolveBackend: Send + Sync {
    /// Fixed-point dimension d (the required `cotangent`/`z0` length).
    fn dim(&self) -> usize;
    /// Resolve one call to a rendered reply. **Blocks** until the router
    /// produces the request's typed outcome (bounded by the deadline).
    fn solve(&self, call: SolveCall) -> SolveReply;
    /// `/healthz` body: liveness + per-shard respawn counts.
    fn health(&self) -> String;
    /// `/metrics` body: text exposition of router + per-key telemetry.
    fn metrics(&self) -> String;
}

/// Parse a `/v1/solve` request body into a [`SolveCall`] with the lazy
/// path-scanner — only the four known paths are decoded, bytes after the
/// last hit are never validated (ADR-002 discipline). Errors are typed
/// 400s carrying the scanner's position/diagnosis.
pub fn parse_solve_call(
    body: &[u8],
    d: usize,
    header_deadline_ms: Option<f64>,
) -> Result<SolveCall, HttpError> {
    let doc = LazyDoc::new(body);
    let bad = |e: crate::http::json::ScanError| {
        HttpError::new(400, format!("invalid JSON body: {e}"))
    };
    let model = doc.u32_at(&["model"]).map_err(bad)?.unwrap_or(0);
    let cotangent = doc
        .f64_vec_at(&["cotangent"], d)
        .map_err(bad)?
        .ok_or_else(|| HttpError::new(400, "missing required field: cotangent"))?;
    if cotangent.len() != d {
        return Err(HttpError::new(
            400,
            format!("cotangent has {} elements, model dimension is {d}", cotangent.len()),
        ));
    }
    let z0 = doc.f64_vec_at(&["z0"], d).map_err(bad)?;
    if let Some(z) = &z0 {
        if z.len() != d {
            return Err(HttpError::new(
                400,
                format!("z0 has {} elements, model dimension is {d}", z.len()),
            ));
        }
    }
    // Body field wins over the x-deadline-ms header.
    let deadline_ms = match doc.f64_at(&["deadline_ms"]).map_err(bad)? {
        Some(ms) => Some(ms),
        None => header_deadline_ms,
    };
    let deadline_s = match deadline_ms {
        Some(ms) if !(ms.is_finite() && ms > 0.0) => {
            return Err(HttpError::new(400, "deadline_ms must be finite and positive"))
        }
        Some(ms) => Some(ms / 1e3),
        None => None,
    };
    Ok(SolveCall {
        model,
        z0,
        cotangent,
        deadline_s,
    })
}

/// Per-request rendezvous state, guarded by the slot mutex. The waiter
/// marks `Abandoned` under the lock when it gives up, and the collector
/// checks the state under the same lock at fill time — so a typed
/// outcome arriving at the deadline boundary is either delivered or
/// counted as an orphan, never silently written into a dead slot.
enum SlotState<E: Elem> {
    Empty,
    Filled(ShardResponse<E>),
    Abandoned,
}

/// Per-request rendezvous: the connection handler parks on the condvar,
/// the collector fills the slot and wakes it.
type Slot<E> = Arc<(Mutex<SlotState<E>>, Condvar)>;

struct PendingMap<E: Elem> {
    slots: Mutex<HashMap<usize, Slot<E>>>,
    /// Responses whose waiter had already given up (deadline-expired
    /// handlers deregister; the typed outcome still arrives here).
    orphans: AtomicUsize,
}

/// HTTP-facing front of one [`ShardedRouter`] instantiation. Cheap to
/// share (`Arc` it into the server); dropping the last handle stops the
/// collector thread and shuts the router down.
pub struct Gateway<E: Elem, EU: Elem = E, EV: Elem = EU> {
    router: Arc<ShardedRouter<E, EU, EV>>,
    /// Fixed-point dimension shared by every registered model (the
    /// sharded tier requires one; asserted by the drivers).
    d: usize,
    pending: Arc<PendingMap<E>>,
    next_id: AtomicUsize,
    retry: RetryPolicy,
    /// Bound on the post-submit wait when the request carries no deadline
    /// (a liveness backstop — the router's exactly-once contract means it
    /// fires only if the deployment is wedged).
    reply_timeout_s: f64,
    stop: Arc<AtomicBool>,
    collector: Option<JoinHandle<()>>,
}

/// Margin added to a request's deadline before the handler gives up
/// waiting: the drain loop types the outcome at the deadline, this covers
/// its trip through the completion queue.
const REPLY_MARGIN_S: f64 = 0.25;
/// Collector wake cadence; bounds shutdown latency, not delivery latency
/// (deliveries ride the completion condvar).
const COLLECT_TICK_S: f64 = 0.05;

impl<E: Elem, EU: Elem, EV: Elem> Gateway<E, EU, EV> {
    /// Wrap a router and start the collector thread. `d` is the shared
    /// fixed-point dimension of every model this router serves; `retry`
    /// governs the submit path ([`RetryPolicy::none`] for the HTTP
    /// default — shed fast, let the client back off on the echoed
    /// `Retry-After`).
    pub fn new(
        router: ShardedRouter<E, EU, EV>,
        d: usize,
        retry: RetryPolicy,
    ) -> Gateway<E, EU, EV> {
        let router = Arc::new(router);
        let pending = Arc::new(PendingMap {
            slots: Mutex::new(HashMap::new()),
            orphans: AtomicUsize::new(0),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let collector = {
            let router = Arc::clone(&router);
            let pending = Arc::clone(&pending);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    for resp in router.collect_timeout(1, COLLECT_TICK_S) {
                        let slot = {
                            let mut slots =
                                pending.slots.lock().unwrap_or_else(|p| p.into_inner());
                            slots.remove(&resp.id)
                        };
                        match slot {
                            Some(s) => {
                                let mut state =
                                    s.0.lock().unwrap_or_else(|p| p.into_inner());
                                if matches!(*state, SlotState::Abandoned) {
                                    // The waiter gave up at its deadline
                                    // between our map removal and this
                                    // fill; the outcome is an orphan, not
                                    // a delivery.
                                    pending.orphans.fetch_add(1, Ordering::Relaxed);
                                } else {
                                    *state = SlotState::Filled(resp);
                                    s.1.notify_one();
                                }
                            }
                            None => {
                                pending.orphans.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
            })
        };
        Gateway {
            router,
            d,
            pending,
            next_id: AtomicUsize::new(0),
            retry,
            reply_timeout_s: 60.0,
            stop,
            collector: Some(collector),
        }
    }

    /// The wrapped router (registration, swaps, telemetry snapshots).
    pub fn router(&self) -> &ShardedRouter<E, EU, EV> {
        &self.router
    }

    /// Typed outcomes delivered after their waiter gave up.
    pub fn orphans(&self) -> usize {
        self.pending.orphans.load(Ordering::Relaxed)
    }

    fn wait_for(&self, id: usize, slot: &Slot<E>, give_up_at: f64) -> Option<ShardResponse<E>> {
        let mut guard = slot.0.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if matches!(*guard, SlotState::Filled(_)) {
                match std::mem::replace(&mut *guard, SlotState::Empty) {
                    SlotState::Filled(resp) => return Some(resp),
                    _ => unreachable!("matched Filled above"),
                }
            }
            let left = give_up_at - self.router.now();
            if left <= 0.0 {
                // Abandon under the slot lock: the collector checks this
                // state under the same lock before filling, so a late
                // outcome is counted as an orphan — whether the collector
                // has already pulled the slot out of the map or not.
                *guard = SlotState::Abandoned;
                drop(guard);
                let mut slots = self.pending.slots.lock().unwrap_or_else(|p| p.into_inner());
                slots.remove(&id);
                return None;
            }
            let (g, _) = slot
                .1
                .wait_timeout(guard, std::time::Duration::from_secs_f64(left))
                .unwrap_or_else(|p| p.into_inner());
            guard = g;
        }
    }

    fn render_ok(&self, resp: &ShardResponse<E>, attempts: usize) -> SolveReply {
        let body = JsonBuilder::obj()
            .uint("id", resp.id as u64)
            .uint("model", resp.key.model as u64)
            .uint("version", resp.key.version as u64)
            .uint("shard", resp.shard as u64)
            .uint("seq", resp.seq)
            .uint("iters", resp.stats.iters as u64)
            .num("residual", resp.stats.residual)
            .boolean("converged", resp.stats.converged)
            .num("latency_s", resp.completed - resp.enqueued)
            .nums("z", resp.z.iter().map(|x| x.to_f64()))
            .nums("w", resp.w.iter().map(|x| x.to_f64()))
            .uint("attempts", attempts as u64)
            .finish();
        SolveReply {
            status: 200,
            body,
            retry_after: None,
            attempts,
        }
    }

    fn render_err(&self, e: &ServeError, attempts: usize) -> SolveReply {
        let (status, token) = serve_status(e);
        let retry_after = match e {
            ServeError::QueueFull { retry_after } => Some(*retry_after),
            _ => None,
        };
        let mut reply = SolveReply::error(status, token, &e.to_string(), retry_after);
        reply.attempts = attempts;
        reply
    }
}

impl<E: Elem, EU: Elem, EV: Elem> SolveBackend for Gateway<E, EU, EV> {
    fn dim(&self) -> usize {
        self.d
    }

    fn solve(&self, call: SolveCall) -> SolveReply {
        let d = self.dim();
        if call.cotangent.len() != d {
            return SolveReply::error(
                400,
                "bad_dimension",
                &format!("cotangent has {} elements, expected {d}", call.cotangent.len()),
                None,
            );
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let z0: Vec<E> = match &call.z0 {
            Some(z) => z.iter().map(|&x| E::from_f64(x)).collect(),
            None => vec![E::ZERO; d],
        };
        let cot: Vec<E> = call.cotangent.iter().map(|&x| E::from_f64(x)).collect();
        let mut req = ShardRequest::new(id, z0, cot);
        let now = self.router.now();
        req.deadline = call.deadline_s.map(|s| now + s);
        let give_up_at = match call.deadline_s {
            Some(s) => now + s + REPLY_MARGIN_S,
            None => now + self.reply_timeout_s,
        };

        // Slot registered BEFORE submit: the collector may deliver the
        // response before submit_with_retry even returns.
        let slot: Slot<E> = Arc::new((Mutex::new(SlotState::Empty), Condvar::new()));
        {
            let mut slots = self.pending.slots.lock().unwrap_or_else(|p| p.into_inner());
            slots.insert(id, Arc::clone(&slot));
        }

        let (res, attempts) = self.router.submit_with_retry(call.model, req, &self.retry);
        if let Err(e) = res {
            // Bounced at admission: nothing will ever fill the slot.
            let mut slots = self.pending.slots.lock().unwrap_or_else(|p| p.into_inner());
            slots.remove(&id);
            drop(slots);
            if let SubmitError::UnknownModel(_) = e {
                let mut reply = SolveReply::error(
                    404,
                    "unknown_model",
                    &format!("no live version registered for model {}", call.model),
                    None,
                );
                reply.attempts = attempts;
                return reply;
            }
            return self.render_err(&e.as_serve_error(), attempts);
        }

        match self.wait_for(id, &slot, give_up_at) {
            Some(resp) => match resp.error {
                None => self.render_ok(&resp, attempts),
                Some(e) => self.render_err(&e, attempts),
            },
            None => self.render_err(&ServeError::DeadlineExceeded, attempts),
        }
    }

    fn health(&self) -> String {
        let stats = self.router.shard_stats();
        let depths = self.router.queue_depths();
        let quarantined = self.router.quarantined_keys();
        let mut shards = String::from("[");
        for (i, (s, q)) in stats.iter().zip(&depths).enumerate() {
            if i > 0 {
                shards.push(',');
            }
            shards.push_str(
                &JsonBuilder::obj()
                    .uint("shard", i as u64)
                    .uint("respawns", s.respawns as u64)
                    .uint("worker_lost", s.worker_lost as u64)
                    .uint("queue_depth", *q as u64)
                    .finish(),
            );
        }
        shards.push(']');
        let mut quars = String::from("[");
        for (i, (k, strikes)) in quarantined.iter().enumerate() {
            if i > 0 {
                quars.push(',');
            }
            quars.push_str(
                &JsonBuilder::obj()
                    .text("key", &k.to_string())
                    .uint("strikes", *strikes as u64)
                    .finish(),
            );
        }
        quars.push(']');
        JsonBuilder::obj()
            .text("status", "ok")
            .uint("pending", self.router.pending() as u64)
            .raw("shards", &shards)
            .raw("quarantined", &quars)
            .finish()
    }

    fn metrics(&self) -> String {
        let mut out = String::with_capacity(4096);
        let stats = self.router.shard_stats();
        let depths = self.router.queue_depths();
        let hints = self.router.retry_hints();
        for (i, s) in stats.iter().enumerate() {
            let l = format!("{{shard=\"{i}\"}}");
            out.push_str(&format!("shine_shard_served_total{l} {}\n", s.served));
            out.push_str(&format!("shine_shard_batches_total{l} {}\n", s.batches));
            out.push_str(&format!("shine_shard_steals_total{l} {}\n", s.steals));
            out.push_str(&format!("shine_shard_respawns_total{l} {}\n", s.respawns));
            out.push_str(&format!("shine_shard_worker_lost_total{l} {}\n", s.worker_lost));
            out.push_str(&format!(
                "shine_shard_deadline_expired_total{l} {}\n",
                s.deadline_expired
            ));
            out.push_str(&format!("shine_shard_quarantined_total{l} {}\n", s.quarantined));
            out.push_str(&format!("shine_shard_queue_depth{l} {}\n", depths[i]));
            let mut hint = String::new();
            crate::util::json::write_num(&mut hint, hints[i]);
            out.push_str(&format!("shine_shard_retry_after_seconds{l} {hint}\n"));
        }
        for m in self.router.key_metrics() {
            push_key_metrics(&mut out, &m);
        }
        out.push_str(&format!(
            "shine_gateway_orphaned_responses_total {}\n",
            self.orphans()
        ));
        out
    }
}

/// Text-exposition block for one key's merged telemetry (shared with the
/// server's test hooks).
pub fn push_key_metrics(out: &mut String, m: &KeyMetrics) {
    let l = format!("{{key=\"{}\"}}", m.key);
    out.push_str(&format!("shine_key_served_total{l} {}\n", m.served));
    out.push_str(&format!("shine_key_batches_total{l} {}\n", m.batches));
    out.push_str(&format!("shine_key_fwd_iters_total{l} {}\n", m.fwd_iters));
    out.push_str(&format!("shine_key_fallback_cols_total{l} {}\n", m.fallback_cols));
    out.push_str(&format!("shine_key_nonfinite_cols_total{l} {}\n", m.nonfinite_cols));
    out.push_str(&format!("shine_key_unconverged_total{l} {}\n", m.unconverged));
    out.push_str(&format!("shine_key_model_faults_total{l} {}\n", m.model_faults));
    out.push_str(&format!("shine_key_calibrations_total{l} {}\n", m.calibrations));
    out.push_str(&format!("shine_key_recalibrations_total{l} {}\n", m.recalibrations));
    let mut rate = String::new();
    crate::util::json::write_num(&mut rate, m.fallback_rate);
    out.push_str(&format!("shine_key_fallback_rate{l} {rate}\n"));
    out.push_str(&format!(
        "shine_key_estimate_stale{l} {}\n",
        m.estimate_stale as u32
    ));
    out.push_str(&format!("shine_key_breaker_state{l} {}\n", breaker_code(m.breaker)));
    out.push_str(&format!("shine_key_strikes{l} {}\n", m.strikes));
    out.push_str(&format!("shine_key_quarantined{l} {}\n", m.quarantined as u32));
}

impl<E: Elem, EU: Elem, EV: Elem> Drop for Gateway<E, EU, EV> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.collector.take() {
            let _ = h.join();
        }
        // The router (last Arc here once the collector has exited) joins
        // its workers in its own Drop.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_serve_error_has_exactly_one_status() {
        // One mapping per variant; the match in serve_status has no
        // wildcard so this list is necessarily exhaustive.
        let cases = [
            (ServeError::QueueFull { retry_after: 0.1 }, 429, "queue_full"),
            (ServeError::DeadlineExceeded, 504, "deadline_exceeded"),
            (ServeError::Unconverged, 422, "unconverged"),
            (ServeError::ModelFault, 502, "model_fault"),
            (ServeError::WorkerLost, 503, "worker_lost"),
        ];
        let mut statuses: Vec<u16> = Vec::new();
        let mut tokens: Vec<&str> = Vec::new();
        for (e, status, token) in cases {
            let (s, t) = serve_status(&e);
            assert_eq!((s, t), (status, token), "{e:?}");
            assert!(!statuses.contains(&s), "status {s} mapped twice");
            assert!(!tokens.contains(&t), "token {t} mapped twice");
            statuses.push(s);
            tokens.push(t);
        }
    }

    #[test]
    fn breaker_codes_are_stable() {
        assert_eq!(breaker_code(BreakerState::Closed), 0);
        assert_eq!(breaker_code(BreakerState::Open { remaining: 5 }), 1);
        assert_eq!(breaker_code(BreakerState::HalfOpen), 2);
    }

    #[test]
    fn parse_solve_call_defaults_and_validation() {
        let d = 3;
        let ok = parse_solve_call(br#"{"cotangent":[1,2,3]}"#, d, None).unwrap();
        assert_eq!(ok.model, 0);
        assert!(ok.z0.is_none());
        assert_eq!(ok.cotangent, vec![1.0, 2.0, 3.0]);
        assert!(ok.deadline_s.is_none());

        let full = parse_solve_call(
            br#"{"model":2,"z0":[0,0,0],"cotangent":[1,2,3],"deadline_ms":250}"#,
            d,
            Some(1000.0),
        )
        .unwrap();
        assert_eq!(full.model, 2);
        assert_eq!(full.z0.as_deref(), Some(&[0.0, 0.0, 0.0][..]));
        // Body field wins over the header.
        assert!((full.deadline_s.unwrap() - 0.25).abs() < 1e-12);

        let hdr = parse_solve_call(br#"{"cotangent":[1,2,3]}"#, d, Some(500.0)).unwrap();
        assert!((hdr.deadline_s.unwrap() - 0.5).abs() < 1e-12);

        for (body, needle) in [
            (&br#"{}"#[..], "cotangent"),
            (&br#"{"cotangent":[1,2]}"#[..], "3"),
            (&br#"{"cotangent":[1,2,3],"z0":[1]}"#[..], "3"),
            (&br#"{"cotangent":[1,2,3,4]}"#[..], "dimension"),
            (&br#"{"cotangent":[1,2,3],"deadline_ms":-5}"#[..], "deadline"),
            (&br#"{"cotangent":"#[..], "JSON"),
        ] {
            let e = parse_solve_call(body, d, None).unwrap_err();
            assert_eq!(e.status, 400, "{body:?}");
            assert!(e.msg.contains(needle), "{body:?} -> {}", e.msg);
        }
    }
}
