//! The TCP front: accept thread, worker pool, admission control, and
//! endpoint dispatch over a [`SolveBackend`].
//!
//! Std-only by design (the crate has no async runtime and adds no
//! dependencies): one accept thread hands connections to a fixed worker
//! pool through the same `Mutex<VecDeque> + Condvar` idiom the shard
//! workers use. Each worker owns its connection end-to-end — HTTP/1.1
//! keep-alive, one request in flight per connection — so the concurrency
//! model stays the crate's: threads and condvars, no reactors.
//!
//! **Admission control** is end-to-end and sheds at the cheapest point
//! first: a connection beyond [`HttpConfig::max_connections`] gets a
//! `429 + Retry-After` from a dedicated shed thread (never the accept
//! thread — a slow shed client must not stall the front door) and is
//! closed before a worker or a parse ever touches it; past admission, the
//! router's own `queue_cap` bounds queued work and bounces with the same
//! typed 429.
//! Overload therefore degrades to fast, honest backpressure — never to
//! unbounded queues or silent drops.
//!
//! Endpoints:
//!
//! | method | path        | reply                                          |
//! |--------|-------------|------------------------------------------------|
//! | POST   | `/v1/solve` | typed solve result (see [`SolveBackend`])      |
//! | GET    | `/healthz`  | liveness JSON + per-shard respawn counts       |
//! | GET    | `/metrics`  | text exposition: router, per-key, server counters |

use crate::http::gateway::{parse_solve_call, SolveBackend};
use crate::http::json::JsonBuilder;
use crate::http::proto::{read_request, HttpError, RecvError, Request, Response};
use std::collections::{BTreeMap, VecDeque};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Network-layer knobs (the solve tier's knobs live in `ShardConfig`).
#[derive(Clone, Copy, Debug)]
pub struct HttpConfig {
    /// Connection-handler threads. Each parks on its connection's
    /// in-flight solve, so this also caps concurrent solves in the HTTP
    /// path.
    pub workers: usize,
    /// Admission budget: connections beyond this are shed with an inline
    /// 429 before any worker touches them.
    pub max_connections: usize,
    /// Request-body cap, bytes (413 beyond it).
    pub max_body: usize,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            workers: 4,
            max_connections: 64,
            max_body: crate::http::proto::DEFAULT_MAX_BODY,
        }
    }
}

/// Server-side response ledger: every byte-stream answer is counted by
/// status exactly once, so the CI gate can reconcile client-observed
/// statuses against the router's typed-outcome ledger.
#[derive(Default)]
pub struct HttpCounters {
    by_status: Mutex<BTreeMap<u16, u64>>,
    requests: AtomicUsize,
    /// Connections shed by admission control. Those that got a 429 answer
    /// are also in `by_status`; ones dropped past [`SHED_QUEUE_CAP`] are
    /// counted here only (no answer was attempted).
    shed: AtomicUsize,
    accepted: AtomicUsize,
}

impl HttpCounters {
    fn count(&self, status: u16) {
        let mut m = self.by_status.lock().unwrap_or_else(|p| p.into_inner());
        *m.entry(status).or_insert(0) += 1;
    }

    /// `(status, responses)` pairs, ascending by status.
    pub fn by_status(&self) -> Vec<(u16, u64)> {
        let m = self.by_status.lock().unwrap_or_else(|p| p.into_inner());
        m.iter().map(|(&k, &v)| (k, v)).collect()
    }

    pub fn requests(&self) -> usize {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn shed(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn accepted(&self) -> usize {
        self.accepted.load(Ordering::Relaxed)
    }
}

struct ServerShared {
    backend: Arc<dyn SolveBackend>,
    cfg: HttpConfig,
    conns: Mutex<VecDeque<TcpStream>>,
    conns_cv: Condvar,
    /// Shed connections waiting for their 429 write + drain. Handled by a
    /// dedicated thread so a slow (or hostile) shed client never stalls
    /// the accept loop; bounded by [`SHED_QUEUE_CAP`].
    shed_q: Mutex<VecDeque<TcpStream>>,
    shed_cv: Condvar,
    active: AtomicUsize,
    counters: HttpCounters,
    stop: AtomicBool,
}

/// Bound on shed connections parked for their 429: beyond this the
/// connection is dropped un-answered (reset) — under a flood that deep,
/// spending memory and drain time on politeness is itself a DoS vector.
/// Dropped-unanswered connections count in `shed` but not `by_status`
/// (no byte-stream answer was attempted).
const SHED_QUEUE_CAP: usize = 128;

/// A running HTTP front. [`HttpServer::shutdown`] (or drop) stops the
/// accept thread, drains the workers, and joins everything.
pub struct HttpServer {
    addr: SocketAddr,
    sh: Arc<ServerShared>,
    accept: Option<JoinHandle<()>>,
    shed: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (use `127.0.0.1:0` for an ephemeral test port — read
    /// it back via [`HttpServer::local_addr`]) and start serving.
    pub fn bind(
        backend: Arc<dyn SolveBackend>,
        addr: &str,
        cfg: HttpConfig,
    ) -> std::io::Result<HttpServer> {
        assert!(cfg.workers >= 1, "need at least one worker");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let sh = Arc::new(ServerShared {
            backend,
            cfg,
            conns: Mutex::new(VecDeque::new()),
            conns_cv: Condvar::new(),
            shed_q: Mutex::new(VecDeque::new()),
            shed_cv: Condvar::new(),
            active: AtomicUsize::new(0),
            counters: HttpCounters::default(),
            stop: AtomicBool::new(false),
        });
        let accept = {
            let sh = Arc::clone(&sh);
            std::thread::spawn(move || accept_loop(&sh, listener))
        };
        let shed = {
            let sh = Arc::clone(&sh);
            std::thread::spawn(move || shed_loop(&sh))
        };
        let workers = (0..cfg.workers)
            .map(|_| {
                let sh = Arc::clone(&sh);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Ok(HttpServer {
            addr: local,
            sh,
            accept: Some(accept),
            shed: Some(shed),
            workers,
        })
    }

    /// The bound address (the real port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The response ledger (live; snapshot methods copy out).
    pub fn counters(&self) -> &HttpCounters {
        &self.sh.counters
    }

    /// Stop accepting, finish queued connections' in-flight requests, and
    /// join every thread. Idempotent.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() && self.workers.is_empty() {
            return;
        }
        self.sh.stop.store(true, Ordering::SeqCst);
        // Unblock the accept() call with a throwaway connection; the flag
        // is already set, so the loop exits on wake.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.sh.shed_cv.notify_all();
        if let Some(h) = self.shed.take() {
            let _ = h.join();
        }
        self.sh.conns_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(sh: &ServerShared, listener: TcpListener) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
                // Back off instead of hot-spinning: under fd exhaustion
                // (EMFILE/ENFILE) accept() fails repeatedly, and a tight
                // retry loop at 100% CPU worsens the overload that caused
                // it. A brief sleep lets in-flight connections close and
                // return fds.
                std::thread::sleep(std::time::Duration::from_millis(50));
                continue;
            }
        };
        if sh.stop.load(Ordering::SeqCst) {
            return;
        }
        sh.counters.accepted.fetch_add(1, Ordering::Relaxed);
        // Admission control: connections beyond the budget are handed to
        // the shed thread for their 429 + drain. The accept thread never
        // writes to (or drains) a client socket itself — a hostile shed
        // connection must not be able to stall the front door.
        let admitted = sh.active.load(Ordering::SeqCst) < sh.cfg.max_connections;
        if !admitted {
            sh.counters.shed.fetch_add(1, Ordering::Relaxed);
            let mut q = sh.shed_q.lock().unwrap_or_else(|p| p.into_inner());
            if q.len() < SHED_QUEUE_CAP {
                q.push_back(stream);
                drop(q);
                sh.shed_cv.notify_one();
            }
            // Over the cap: drop un-answered (the stream closes here).
            continue;
        }
        sh.active.fetch_add(1, Ordering::SeqCst);
        let mut q = sh.conns.lock().unwrap_or_else(|p| p.into_inner());
        q.push_back(stream);
        drop(q);
        sh.conns_cv.notify_one();
    }
}

/// Dedicated thread for shed connections: write the 429 and drain
/// (bounded) off the accept path, one connection at a time.
fn shed_loop(sh: &ServerShared) {
    loop {
        let stream = {
            let mut q = sh.shed_q.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                // Stop wins over the backlog: connections still queued at
                // shutdown are dropped un-answered rather than holding the
                // join for up to a linger bound each.
                if sh.stop.load(Ordering::SeqCst) {
                    break None;
                }
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                q = sh.shed_cv.wait(q).unwrap_or_else(|p| p.into_inner());
            }
        };
        let Some(mut stream) = stream else { return };
        sh.counters.count(429);
        let body = JsonBuilder::obj()
            .text("error", "overloaded")
            .text("message", "connection budget exhausted; retry with backoff")
            .finish();
        let _ = Response::json(429, body)
            .with_header("retry-after", "1")
            .write_to(&mut stream, false);
        linger_close(&mut stream);
    }
}

fn worker_loop(sh: &ServerShared) {
    loop {
        let stream = {
            let mut q = sh.conns.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if sh.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = sh.conns_cv.wait(q).unwrap_or_else(|p| p.into_inner());
            }
        };
        let Some(stream) = stream else { return };
        handle_connection(sh, stream);
        sh.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Serve one connection: keep-alive request loop, close on protocol
/// error, client close, `Connection: close`, or server stop.
fn handle_connection(sh: &ServerShared, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    loop {
        let req = match read_request(&mut reader, sh.cfg.max_body) {
            Ok(r) => r,
            Err(RecvError::Closed) | Err(RecvError::Io(_)) => return,
            Err(RecvError::Proto(e)) => {
                // Malformed framing: answer typed, then close (the
                // connection's byte position is no longer trustworthy).
                let resp = error_response(&e);
                sh.counters.count(resp.status);
                let _ = resp.write_to(&mut stream, false);
                linger_close(&mut stream);
                return;
            }
        };
        sh.counters.requests.fetch_add(1, Ordering::Relaxed);
        // A handler panic answers 500 and closes, instead of tearing down
        // the worker (defense in depth — the solve tier already converts
        // model panics into typed WorkerLost outcomes).
        let resp = catch_unwind(AssertUnwindSafe(|| dispatch(sh, &req))).unwrap_or_else(|_| {
            Response::json(
                500,
                JsonBuilder::obj()
                    .text("error", "internal")
                    .text("message", "handler panicked")
                    .finish(),
            )
        });
        let closing = resp.status == 500;
        let keep_alive = req.keep_alive && !closing && !sh.stop.load(Ordering::SeqCst);
        sh.counters.count(resp.status);
        let wrote = resp.write_to(&mut stream, keep_alive);
        if wrote.is_err() {
            return;
        }
        if !keep_alive {
            linger_close(&mut stream);
            return;
        }
    }
}

/// Hard bounds on the close-time drain: a cooperative client finishes
/// well inside these; a hostile one that trickles bytes forever gets cut
/// off instead of pinning the thread.
const LINGER_TOTAL: std::time::Duration = std::time::Duration::from_millis(1000);
const LINGER_IDLE: std::time::Duration = std::time::Duration::from_millis(200);
const LINGER_MAX_BYTES: usize = 64 * 1024;

/// Half-close then read-drain before dropping a connection we just
/// answered on. Closing a socket with unread client bytes in its receive
/// buffer sends an immediate RST, which on most stacks discards the
/// response still sitting in the client's buffer — the typed 4xx would
/// vanish exactly when it matters (oversized request, shed connection).
/// Draining until the client's half closes makes the answer reliably
/// observable. The drain is bounded three ways (per-read idle timeout,
/// total deadline, byte budget) so a client that trickles data cannot
/// pin the thread indefinitely.
fn linger_close(stream: &mut TcpStream) {
    use std::io::Read;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(LINGER_IDLE));
    let start = std::time::Instant::now();
    let mut budget = LINGER_MAX_BYTES;
    let mut sink = [0u8; 4096];
    while budget > 0 && start.elapsed() < LINGER_TOTAL {
        match stream.read(&mut sink) {
            Ok(n) if n > 0 => budget = budget.saturating_sub(n),
            _ => return,
        }
    }
}

fn error_response(e: &HttpError) -> Response {
    Response::json(
        e.status,
        JsonBuilder::obj()
            .text("error", "bad_request")
            .text("message", &e.msg)
            .finish(),
    )
}

fn dispatch(sh: &ServerShared, req: &Request) -> Response {
    match (req.method.as_str(), req.target.as_str()) {
        ("POST", "/v1/solve") => solve_endpoint(sh, req),
        ("GET", "/healthz") => Response::json(200, sh.backend.health()),
        ("GET", "/metrics") => {
            let mut body = sh.backend.metrics();
            append_server_metrics(sh, &mut body);
            Response::text(200, &body)
        }
        ("POST", "/healthz") | ("POST", "/metrics") | ("GET", "/v1/solve") => {
            error_response(&HttpError::new(405, format!("{} not allowed here", req.method)))
        }
        _ => error_response(&HttpError::new(404, format!("no route for {}", req.target))),
    }
}

fn solve_endpoint(sh: &ServerShared, req: &Request) -> Response {
    let header_deadline = req
        .header("x-deadline-ms")
        .and_then(|v| v.parse::<f64>().ok());
    let call = match parse_solve_call(&req.body, sh.backend.dim(), header_deadline) {
        Ok(c) => c,
        Err(e) => return error_response(&e),
    };
    let reply = sh.backend.solve(call);
    let mut resp = Response::json(reply.status, reply.body)
        .with_header("x-shine-attempts", &reply.attempts.to_string());
    if let Some(ra) = reply.retry_after {
        // RFC header is whole seconds (rounded up, floor 1); the precise
        // hint rides the extension header.
        let secs = (ra.ceil() as u64).max(1);
        resp = resp
            .with_header("retry-after", &secs.to_string())
            .with_header("x-retry-after-ms", &format!("{:.3}", ra * 1e3));
    }
    resp
}

fn append_server_metrics(sh: &ServerShared, out: &mut String) {
    let c = &sh.counters;
    out.push_str(&format!("shine_http_requests_total {}\n", c.requests()));
    out.push_str(&format!("shine_http_connections_accepted_total {}\n", c.accepted()));
    out.push_str(&format!("shine_http_admission_shed_total {}\n", c.shed()));
    out.push_str(&format!(
        "shine_http_active_connections {}\n",
        sh.active.load(Ordering::SeqCst)
    ));
    for (status, n) in c.by_status() {
        out.push_str(&format!(
            "shine_http_responses_total{{code=\"{status}\"}} {n}\n"
        ));
    }
}
