//! Nonlinear power method — Table E.1's "nonlinear spectral radius" of the
//! fixed-point-defining sub-network.
//!
//! The paper probes the contractivity assumption of the Jacobian-Free method
//! (Fung et al. 2021) by applying the power method to f_θ around z*: if the
//! dominant singular value of ∂f/∂z exceeds 1, the network is not
//! contractive (the paper measures 194–234 — not contractive at all).
//!
//! Generic over the storage precision [`Elem`]: the DEQ path probes the
//! f32 `f_jvp` artifact directly (no f64↔f32 shuttle per iteration), dense
//! test oracles run at f64. Radius estimates are f64 norms either way.

use crate::linalg::vecops::{nrm2, scale, Elem};
use crate::solvers::session::Session;
use crate::util::rng::Rng;

/// Result of a power-method run.
#[derive(Clone, Debug)]
pub struct PowerResult {
    /// estimated spectral radius (dominant |eigenvalue| of the Jacobian map)
    pub radius: f64,
    pub iters: usize,
    /// per-iteration radius estimates (convergence diagnostics)
    pub history: Vec<f64>,
}

/// Power method on a linear map given as a write-into matvec closure
/// `apply(v, out)` (owns its session; probe loops that run many spectra
/// should hold a [`Session`] and use [`power_method_session`]).
pub fn power_method<E: Elem>(
    apply: impl FnMut(&[E], &mut [E]),
    dim: usize,
    iters: usize,
    rng: &mut Rng,
) -> PowerResult {
    let mut sess = Session::new();
    power_method_session(apply, dim, iters, rng, &mut sess)
}

/// [`power_method`] drawing its iterate buffers from a solve [`Session`] —
/// the session-API form the coordinator probes use. The two d-length
/// iterate buffers come from the session pools (recycled across probes);
/// the returned per-iteration `history` is still allocated per call, as is
/// whatever the operator itself does.
pub fn power_method_session<E: Elem>(
    mut apply: impl FnMut(&[E], &mut [E]),
    dim: usize,
    iters: usize,
    rng: &mut Rng,
    sess: &mut Session<E>,
) -> PowerResult {
    let mut v = sess.workspace().take(dim);
    for vi in v.iter_mut() {
        *vi = E::from_f64(rng.normal());
    }
    let n0 = nrm2(&v);
    scale(1.0 / n0.max(1e-300), &mut v);
    let mut av = sess.workspace().take(dim);
    let mut history = Vec::with_capacity(iters);
    let mut radius = 0.0;
    for _ in 0..iters {
        apply(&v, &mut av);
        radius = nrm2(&av);
        history.push(radius);
        if radius <= 1e-300 {
            break;
        }
        std::mem::swap(&mut v, &mut av);
        scale(1.0 / radius, &mut v);
    }
    sess.workspace().give(av);
    sess.workspace().give(v);
    PowerResult {
        radius,
        iters: history.len(),
        history,
    }
}

/// Nonlinear variant: the Jacobian map at z is approximated by finite
/// differences of `f` (the paper's "power-method applied to a nonlinear
/// function"). `f(z, out)` must be the fixed-point map (not the residual).
pub fn nonlinear_power_method<E: Elem>(
    mut f: impl FnMut(&[E], &mut [E]),
    z: &[E],
    iters: usize,
    eps: f64,
    rng: &mut Rng,
) -> PowerResult {
    let dim = z.len();
    let mut fz = vec![E::ZERO; dim];
    f(z, &mut fz);
    let mut zp = vec![E::ZERO; dim];
    let mut fp = vec![E::ZERO; dim];
    power_method(
        move |v: &[E], out: &mut [E]| {
            // (f(z + εv) − f(z)) / ε
            for i in 0..dim {
                zp[i] = E::from_f64(z[i].to_f64() + eps * v[i].to_f64());
            }
            f(&zp[..], &mut fp[..]);
            for i in 0..dim {
                out[i] = E::from_f64((fp[i].to_f64() - fz[i].to_f64()) / eps);
            }
        },
        dim,
        iters,
        rng,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    #[test]
    fn recovers_dominant_eigenvalue_of_diag() {
        let mut rng = Rng::new(2);
        let diag = [5.0, 2.0, 1.0, 0.5];
        let res = power_method(
            |v: &[f64], out: &mut [f64]| {
                for i in 0..4 {
                    out[i] = v[i] * diag[i];
                }
            },
            4,
            100,
            &mut rng,
        );
        assert!((res.radius - 5.0).abs() < 1e-6, "radius={}", res.radius);
    }

    #[test]
    fn spd_radius_matches_extreme_eigenvalue() {
        prop::check("power-spd", 8, |rng| {
            let n = 6;
            let a = DMat::random_spd(n, 0.1, 3.0, rng);
            let res = power_method(|v: &[f64], out: &mut [f64]| a.matvec(v, out), n, 500, rng);
            // Rayleigh check: radius must be ≥ |Av|/|v| for a random probe
            // and equal to the max singular value within tolerance: verify
            // via ‖A x‖ ≤ radius·‖x‖ (1 + tol) for random x.
            let x = rng.normal_vec(n);
            let mut ax = vec![0.0; n];
            a.matvec(&x, &mut ax);
            prop::ensure(
                nrm2(&ax) <= res.radius * nrm2(&x) * (1.0 + 1e-3),
                &format!("radius {} too small", res.radius),
            )
        });
    }

    #[test]
    fn nonlinear_matches_linear_on_linear_map() {
        let mut rng = Rng::new(7);
        let n = 5;
        // SPD: the power method converges cleanly (a random nonsymmetric
        // matrix may have complex dominant eigenvalues → oscillation).
        let a = DMat::random_spd(n, 0.2, 4.0, &mut rng);
        let z = rng.normal_vec(n);
        let res = nonlinear_power_method(
            |x: &[f64], out: &mut [f64]| a.matvec(x, out),
            &z,
            200,
            1e-6,
            &mut rng,
        );
        // Compare against direct power method on A.
        let mut rng2 = Rng::new(8);
        let lin = power_method(|v: &[f64], out: &mut [f64]| a.matvec(v, out), n, 200, &mut rng2);
        assert!(
            (res.radius - lin.radius).abs() / lin.radius < 1e-2,
            "{} vs {}",
            res.radius,
            lin.radius
        );
    }

    #[test]
    fn f32_power_method_runs_in_storage_precision() {
        // A diagonal f32 map: the radius must come out in f64 but the
        // iterate stays f32 end-to-end.
        let mut rng = Rng::new(9);
        let res = power_method(
            |v: &[f32], out: &mut [f32]| {
                for i in 0..3 {
                    out[i] = v[i] * 3.0;
                }
            },
            3,
            60,
            &mut rng,
        );
        assert!((res.radius - 3.0).abs() < 1e-4, "radius={}", res.radius);
    }

    #[test]
    fn history_converges() {
        let mut rng = Rng::new(3);
        let res = power_method(
            |v: &[f64], out: &mut [f64]| {
                for i in 0..3 {
                    out[i] = 2.0 * v[i];
                }
            },
            3,
            50,
            &mut rng,
        );
        assert_eq!(res.iters, 50);
        let last = res.history.last().unwrap();
        assert!((last - 2.0).abs() < 1e-9);
    }
}
