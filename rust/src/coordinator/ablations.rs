//! Ablations over the design choices DESIGN.md calls out:
//!
//! * **qN memory** — the paper uses 30 updates for accelerated methods and
//!   checks 30 does not help the original method (App. C). We sweep
//!   m ∈ {5, 10, 30, 60} and measure SHINE's hypergradient error and the
//!   final HPO loss.
//! * **tolerance schedule** — the accelerated methods use a faster
//!   exponential decrease (0.78 vs 0.99); sweep both for SHINE and HOAG.
//! * **refine budget** — the k in SHINE-refine (Fig. 3's trade-off knob) on
//!   the bi-level problem, where the exact hypergradient is computable.

use crate::bilevel::hoag::{hoag_run, HoagOptions};
use crate::coordinator::{ExpCtx, Experiment};
use crate::data::split::split_logreg;
use crate::data::synth_text::{synth_text, TextConfig};
use crate::hypergrad::{hypergrad, ForwardArtifacts, Strategy};
use crate::problems::logreg::{LogRegInner, LogRegOuter};
use crate::problems::quadratic::{QuadraticBilevel, QuadraticOuter};
use crate::problems::InnerProblem;
use crate::solvers::minimize::{lbfgs_minimize, MinimizeOptions};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;

pub struct Ablations;

impl Experiment for Ablations {
    fn id(&self) -> &'static str {
        "ablations"
    }
    fn description(&self) -> &'static str {
        "Ablations: qN memory size, tolerance-decrease schedule, refine budget \
         (the App. C design choices)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let mut out = Json::obj();
        out.set("memory", self.memory_sweep(ctx)?)
            .set("tol_schedule", self.tol_sweep(ctx)?)
            .set("refine_budget", self.refine_sweep(ctx)?);
        Ok(out)
    }
}

impl Ablations {
    /// Memory sweep: SHINE hypergradient error vs m on the quadratic oracle
    /// (exact answer known) + final HPO loss on the LR problem.
    fn memory_sweep(&self, ctx: &ExpCtx) -> Result<Json> {
        let mems = [5usize, 10, 30, 60];
        // (a) hypergradient error on the quadratic oracle
        let mut rng = Rng::new(ctx.seed ^ 0xAB1);
        let n = 40;
        let p = QuadraticBilevel::random(n, &mut rng);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        let theta = [0.2];
        let exact = p.exact_hypergrad(&theta);
        let mut rows = Vec::new();
        for &m in &mems {
            let obj = (n, |z: &[f64]| {
                (p.inner_value(&theta, z).unwrap(), p.g(&theta, z))
            });
            let res = lbfgs_minimize(
                &obj,
                &vec![0.0; n],
                &MinimizeOptions {
                    tol: 1e-10,
                    memory: m,
                    ..Default::default()
                },
                None,
                None,
            );
            let arts = ForwardArtifacts {
                z: &res.z,
                inv: Some(&res.qn),
                low_rank: None,
            };
            let sh = hypergrad(&p, &outer, &theta, &arts, Strategy::Shine, None);
            let rel_err = (sh.grad_theta[0] - exact).abs() / exact.abs().max(1e-12);
            eprintln!("  [ablation memory] m={m}: SHINE rel err {rel_err:.3e}");
            let mut j = Json::obj();
            j.set("memory", m).set("shine_rel_err", rel_err);
            rows.push(j);
        }
        let mut j = Json::obj();
        j.set("quadratic_oracle", Json::Arr(rows));
        Ok(j)
    }

    /// Tolerance-decrease sweep on the LR HPO problem.
    fn tol_sweep(&self, ctx: &ExpCtx) -> Result<Json> {
        let mut cfg = TextConfig::news20_like();
        cfg.n_docs /= if ctx.quick { 8 } else { 4 };
        cfg.n_features /= if ctx.quick { 8 } else { 4 };
        cfg.n_informative /= if ctx.quick { 8 } else { 4 };
        let data = synth_text(&cfg, ctx.seed);
        let mut rng = Rng::new(ctx.seed ^ 0xAB2);
        let (train, val, test) = split_logreg(&data, &mut rng);
        let prob = LogRegInner { train };
        let outer = LogRegOuter { val, test };
        let mut rows = Vec::new();
        for strategy_name in ["shine", "hoag"] {
            for decrease in [0.99f64, 0.9, 0.78, 0.6] {
                let strategy = if strategy_name == "shine" {
                    Strategy::Shine
                } else {
                    Strategy::Full {
                        tol: 1e-8,
                        max_iters: usize::MAX,
                    }
                };
                let opts = HoagOptions {
                    outer_iters: if ctx.quick { 6 } else { 25 },
                    strategy,
                    tol_decrease: decrease,
                    ..Default::default()
                };
                let res = hoag_run(&prob, &outer, &[-4.0], &opts);
                let last = res.trace.last().unwrap();
                eprintln!(
                    "  [ablation tol] {strategy_name} q={decrease}: test {:.4} in {:.2}s",
                    last.test_loss, res.total_time
                );
                let mut j = Json::obj();
                j.set("strategy", strategy_name)
                    .set("decrease", decrease)
                    .set("final_test_loss", last.test_loss)
                    .set("total_time", res.total_time);
                rows.push(j);
            }
        }
        Ok(Json::Arr(rows))
    }

    /// Refine-budget sweep on the quadratic oracle: error vs k.
    fn refine_sweep(&self, ctx: &ExpCtx) -> Result<Json> {
        let mut rng = Rng::new(ctx.seed ^ 0xAB3);
        let n = 40;
        let p = QuadraticBilevel::random(n, &mut rng);
        let outer = QuadraticOuter {
            target: p.target.clone(),
        };
        let theta = [0.0];
        let exact = p.exact_hypergrad(&theta);
        let obj = (n, |z: &[f64]| {
            (p.inner_value(&theta, z).unwrap(), p.g(&theta, z))
        });
        // Small memory so vanilla SHINE is visibly inexact.
        let res = lbfgs_minimize(
            &obj,
            &vec![0.0; n],
            &MinimizeOptions {
                tol: 1e-10,
                memory: 5,
                ..Default::default()
            },
            None,
            None,
        );
        let arts = ForwardArtifacts {
            z: &res.z,
            inv: Some(&res.qn),
            low_rank: None,
        };
        let mut rows = Vec::new();
        for k in [0usize, 1, 2, 5, 10, 20] {
            let strategy = if k == 0 {
                Strategy::Shine
            } else {
                Strategy::ShineRefine {
                    iters: k,
                    tol: 1e-12,
                }
            };
            let hg = hypergrad(&p, &outer, &theta, &arts, strategy, None);
            let rel_err = (hg.grad_theta[0] - exact).abs() / exact.abs().max(1e-12);
            eprintln!(
                "  [ablation refine] k={k}: rel err {rel_err:.3e} ({} matvecs)",
                hg.backward_matvecs
            );
            let mut j = Json::obj();
            j.set("k", k)
                .set("rel_err", rel_err)
                .set("matvecs", hg.backward_matvecs);
            rows.push(j);
        }
        Ok(Json::Arr(rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_quick_run() {
        let ctx = ExpCtx {
            quick: true,
            ..Default::default()
        };
        let out = Ablations.run(&ctx).unwrap();
        assert!(out.get("memory").is_some());
        assert!(out.get("tol_schedule").is_some());
        // refine error must be non-increasing in k.
        let rows = out.get("refine_budget").unwrap().as_arr().unwrap();
        let errs: Vec<f64> = rows
            .iter()
            .map(|r| r.get("rel_err").unwrap().as_f64().unwrap())
            .collect();
        assert!(errs.last().unwrap() <= &(errs[0] + 1e-12));
    }
}
