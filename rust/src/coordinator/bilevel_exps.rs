//! Bi-level / hyperparameter-optimization experiments (Fig. 1, Fig. 2,
//! Fig. E.1, Fig. E.2). All run on the native Rust inner problems (sparse
//! logistic regression / NLS) — the DEQ experiments are in `deq_exps`.

use crate::bilevel::hoag::{hoag_run, HoagOptions, HoagResult};
use crate::bilevel::search::{grid_search, random_search};
use crate::coordinator::{ExpCtx, Experiment};
use crate::data::split::{logreg_to_nls, split_logreg, split_nls};
use crate::data::synth_text::{synth_text, TextConfig};
use crate::hypergrad::Strategy;
use crate::linalg::lu::Lu;
use crate::problems::logreg::{LogRegInner, LogRegOuter};
use crate::problems::nls::{NlsInner, NlsOuter};
use crate::problems::InnerProblem;
use crate::qn::lbfgs::OpaConfig;
use crate::qn::InvOp;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::Result;

/// Appendix-C method configurations. The paper's figures compare methods at
/// equal wall-clock time, so the outer loop is time-budgeted: `outer_iters`
/// is a generous cap and `time_budget` (set by the caller) is the binding
/// constraint.
fn method_opts(strategy: Strategy, opa: bool, outer_iters: usize) -> HoagOptions {
    let accelerated = !matches!(strategy, Strategy::Full { .. });
    HoagOptions {
        outer_iters,
        step_size: 20.0, // θ is log-λ; hypergrads are O(1e-3) at θ₀, adaptive halving tames overshoot
        tol0: 1e-2,
        // HOAG: 0.99 exponential decrease; accelerated methods: 0.78 (App. C)
        tol_decrease: if accelerated { 0.78 } else { 0.99 },
        tol_min: 1e-10,
        // memory: 10 for HOAG, 30 for accelerated, 60 for OPA (App. C)
        inner_memory: if opa {
            60
        } else if accelerated {
            30
        } else {
            10
        },
        inner_max_iters: 1500,
        opa: if opa {
            Some(OpaConfig { freq: 5, t0: 1.0 })
        } else {
            None
        },
        strategy,
        adaptive_step: true,
        time_budget: f64::INFINITY,
    }
}

fn trace_json(res: &HoagResult) -> Json {
    let rows: Vec<Json> = res
        .trace
        .iter()
        .map(|p| {
            let mut j = Json::obj();
            j.set("k", p.k)
                .set("time", p.time)
                .set("theta", p.theta[0])
                .set("val_loss", p.val_loss)
                .set("test_loss", p.test_loss)
                .set("inner_iters", p.inner_iters)
                .set("backward_matvecs", p.backward_matvecs);
            j
        })
        .collect();
    Json::Arr(rows)
}

const FULL: Strategy = Strategy::Full {
    tol: 1e-8,
    max_iters: usize::MAX,
};

fn dataset_cfg(name: &str, quick: bool) -> TextConfig {
    let mut cfg = match name {
        "news20" => TextConfig::news20_like(),
        "realsim" => TextConfig::realsim_like(),
        _ => panic!("unknown dataset {name}"),
    };
    if quick {
        cfg.n_docs /= 8;
        cfg.n_features /= 8;
        cfg.n_informative /= 8;
    }
    cfg
}

/// Run one (dataset, methods) HPO comparison; shared by Fig. 1/2/E.1.
fn run_hpo_methods(
    dataset: &str,
    methods: &[(&str, Strategy, bool)],
    ctx: &ExpCtx,
    outer_iters: usize,
    with_search: bool,
) -> Result<Json> {
    let cfg = dataset_cfg(dataset, ctx.quick);
    let data = synth_text(&cfg, ctx.seed);
    let mut rng = Rng::new(ctx.seed ^ 0x5417);
    let (train, val, test) = split_logreg(&data, &mut rng);
    let prob = LogRegInner { train };
    let outer = LogRegOuter { val, test };
    let theta0 = [-4.0f64]; // λ₀ = e⁻⁴, HOAG-style starting point

    let mut out = Json::obj();
    out.set("dataset", dataset)
        .set("n_train", prob.train.n())
        .set("d", prob.dim());
    let mut methods_json = Json::obj();
    for (name, strategy, opa) in methods {
        let mut opts = method_opts(*strategy, *opa, outer_iters * 20);
        // Equal-time comparison (the paper's x-axis is wall time).
        opts.time_budget = outer_iters as f64 * 0.04;
        let res = hoag_run(&prob, &outer, &theta0, &opts);
        let final_test = res.trace.last().map(|p| p.test_loss).unwrap_or(f64::NAN);
        eprintln!(
            "  [{dataset}] {name}: {:.2}s, final test loss {:.4}, theta {:.3}",
            res.total_time, final_test, res.theta[0]
        );
        let mut m = Json::obj();
        m.set("trace", trace_json(&res))
            .set("total_time", res.total_time)
            .set("final_theta", res.theta[0])
            .set("final_test_loss", final_test);
        methods_json.set(name, m);
    }
    if with_search {
        let n_points = if ctx.quick { 4 } else { 12 };
        let budget = 120.0;
        let gs = grid_search(&prob, &outer, -8.0, 0.0, n_points, 1e-6, 1500, budget);
        let mut rng_s = Rng::new(ctx.seed ^ 0xABC);
        let rs = random_search(
            &prob, &outer, -8.0, 0.0, n_points, 1e-6, 1500, budget, &mut rng_s,
        );
        for (name, sr) in [("grid-search", gs), ("random-search", rs)] {
            let rows: Vec<Json> = sr
                .trace
                .iter()
                .map(|p| {
                    let mut j = Json::obj();
                    j.set("time", p.time)
                        .set("theta", p.theta)
                        .set("test_loss", p.test_loss)
                        .set("best_test_loss", p.best_test_loss);
                    j
                })
                .collect();
            let mut m = Json::obj();
            m.set("trace", Json::Arr(rows)).set("best_theta", sr.best_theta);
            methods_json.set(name, m);
            eprintln!("  [{dataset}] {name}: best θ {:.3}", sr.best_theta);
        }
    }
    out.set("methods", methods_json);
    Ok(out)
}

// ---------------------------------------------------------------------------
// Fig. 1 — HPO on ℓ2-LR, 2 datasets, SHINE vs competitors
// ---------------------------------------------------------------------------

pub struct Fig1;

impl Experiment for Fig1 {
    fn id(&self) -> &'static str {
        "fig1"
    }
    fn description(&self) -> &'static str {
        "Fig. 1: bi-level HPO on l2-logistic regression (20news-like & real-sim-like): \
         test-loss vs wall time for HOAG / SHINE / SHINE-refine / Jacobian-Free / grid"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let outer_iters = if ctx.quick { 8 } else { 60 };
        let methods: Vec<(&str, Strategy, bool)> = vec![
            ("hoag", FULL, false),
            ("shine", Strategy::Shine, false),
            (
                "shine-refine",
                Strategy::ShineRefine {
                    iters: 5,
                    tol: 1e-10,
                },
                false,
            ),
            ("jacobian-free", Strategy::JacobianFree, false),
        ];
        let mut out = Json::obj();
        for ds in ["news20", "realsim"] {
            out.set(ds, run_hpo_methods(ds, &methods, ctx, outer_iters, true)?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Fig. 2 (left) — OPA comparison on 20news
// ---------------------------------------------------------------------------

pub struct Fig2Left;

impl Experiment for Fig2Left {
    fn id(&self) -> &'static str {
        "fig2-left"
    }
    fn description(&self) -> &'static str {
        "Fig. 2 left: SHINE-OPA vs SHINE vs HOAG on the 20news-like problem \
         (all methods share the same Rust LBFGS, as the paper's pure-python comparison)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let outer_iters = if ctx.quick { 8 } else { 60 };
        let methods: Vec<(&str, Strategy, bool)> = vec![
            ("hoag", FULL, false),
            ("shine", Strategy::Shine, false),
            ("shine-opa", Strategy::Shine, true),
        ];
        run_hpo_methods("news20", &methods, ctx, outer_iters, false)
    }
}

// ---------------------------------------------------------------------------
// Fig. 2 (right) — inversion quality on the breast-cancer-like dataset
// ---------------------------------------------------------------------------

pub struct Fig2Right;

impl Experiment for Fig2Right {
    fn id(&self) -> &'static str {
        "fig2-right"
    }
    fn description(&self) -> &'static str {
        "Fig. 2 right: quality of B^-1 v vs exact J^-1 v in prescribed / Krylov / \
         random directions with OPA updates (d=30 dense, 100 seeds)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let n_runs = if ctx.quick { 10 } else { 100 };
        let mut scatter: Vec<(String, f64, f64)> = Vec::new();
        for run in 0..n_runs {
            let seed = ctx.seed.wrapping_add(run as u64);
            let mut rng = Rng::new(seed ^ 0xF16);
            let data = crate::data::synth_breast::synth_breast(400, seed);
            let (train, _val, _test) = split_logreg(&data, &mut rng);
            let prob = LogRegInner { train };
            let d = prob.dim();
            let theta = [-2.0f64];
            // Prescribed direction: random, but used for the OPA updates.
            let prescribed = rng.normal_vec(d);
            let presc_clone = prescribed.clone();
            let dg = move |_z: &[f64]| presc_clone.clone();
            let obj = (d, |z: &[f64]| {
                (prob.inner_value(&theta, z).unwrap(), prob.g(&theta, z))
            });
            let res = crate::solvers::minimize::lbfgs_minimize(
                &obj,
                &vec![0.0; d],
                &crate::solvers::minimize::MinimizeOptions {
                    tol: 1e-6,
                    max_iters: 400,
                    memory: 60,
                    scale_gamma: false,
                    ..Default::default()
                },
                Some(crate::solvers::minimize::OpaHooks {
                    dg_dtheta: &dg,
                    config: OpaConfig { freq: 5, t0: 1.0 },
                }),
                None,
            );
            // Exact Hessian at z* (dense, d = 30).
            let mut hess = crate::linalg::dmat::DMat::zeros(d, d);
            for j in 0..d {
                let mut e = vec![0.0; d];
                e[j] = 1.0;
                let col = prob.jvp(&theta, &res.z, &e);
                for i in 0..d {
                    hess[(i, j)] = col[i];
                }
            }
            let lu = Lu::factor(&hess)?;
            // Krylov direction: J_{g}(z*)·s_last ≈ the last secant y.
            let krylov = prob.jvp(&theta, &res.z, &{
                let mut s = rng.normal_vec(d);
                // use a step-like direction: H∇ at z*
                s = res.qn.apply_vec(&s);
                s
            });
            let random_dir = rng.normal_vec(d);
            for (kind, v) in [
                ("prescribed", &prescribed),
                ("krylov", &krylov),
                ("random", &random_dir),
            ] {
                let exact = lu.solve(v);
                let approx = res.qn.apply_vec(v);
                let cos = stats::cosine_similarity(&approx, &exact);
                let ratio = stats::norm2(&approx) / stats::norm2(&exact).max(1e-300);
                scatter.push((kind.to_string(), cos, ratio));
            }
        }
        let mut out = Json::obj();
        for kind in ["prescribed", "krylov", "random"] {
            let pts: Vec<Json> = scatter
                .iter()
                .filter(|(k, _, _)| k == kind)
                .map(|(_, c, r)| {
                    let mut j = Json::obj();
                    j.set("cos_sim", *c).set("norm_ratio", *r);
                    j
                })
                .collect();
            let cos_med = stats::median(
                &scatter
                    .iter()
                    .filter(|(k, _, _)| k == kind)
                    .map(|(_, c, _)| *c)
                    .collect::<Vec<_>>(),
            );
            eprintln!("  fig2-right {kind}: median cos-sim {cos_med:.3}");
            let mut kj = Json::obj();
            kj.set("points", Json::Arr(pts)).set("median_cos", cos_med);
            out.set(kind, kj);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Fig. E.1 — extended comparison (HOAG-limited + random search)
// ---------------------------------------------------------------------------

pub struct FigE1;

impl Experiment for FigE1 {
    fn id(&self) -> &'static str {
        "fig-e1"
    }
    fn description(&self) -> &'static str {
        "Fig. E.1: extended HPO baselines — HOAG with truncated backward solves \
         and random search on both datasets"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let outer_iters = if ctx.quick { 8 } else { 60 };
        let methods: Vec<(&str, Strategy, bool)> = vec![
            ("hoag", FULL, false),
            (
                "hoag-limited-5",
                Strategy::Full {
                    tol: 1e-8,
                    max_iters: 5,
                },
                false,
            ),
            (
                "hoag-limited-20",
                Strategy::Full {
                    tol: 1e-8,
                    max_iters: 20,
                },
                false,
            ),
            ("shine", Strategy::Shine, false),
            ("jacobian-free", Strategy::JacobianFree, false),
        ];
        let mut out = Json::obj();
        for ds in ["news20", "realsim"] {
            out.set(ds, run_hpo_methods(ds, &methods, ctx, outer_iters, true)?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Fig. E.2 — regularized nonlinear least squares
// ---------------------------------------------------------------------------

pub struct FigE2;

impl Experiment for FigE2 {
    fn id(&self) -> &'static str {
        "fig-e2"
    }
    fn description(&self) -> &'static str {
        "Fig. E.2: HPO on regularized nonlinear least squares (eq. 12) — \
         the non-convex inner problem where OPA helps most"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let outer_iters = if ctx.quick { 8 } else { 60 };
        let cfg = dataset_cfg("news20", ctx.quick);
        let data = synth_text(&cfg, ctx.seed);
        let nls_data = logreg_to_nls(&data);
        let mut rng = Rng::new(ctx.seed ^ 0x9E2);
        let (train, val, test) = split_nls(&nls_data, &mut rng);
        let prob = NlsInner { train };
        let outer = NlsOuter { val, test };
        let theta0 = [-4.0f64];
        let methods: Vec<(&str, Strategy, bool)> = vec![
            ("hoag", FULL, false),
            ("shine", Strategy::Shine, false),
            ("shine-opa", Strategy::Shine, true),
            ("jacobian-free", Strategy::JacobianFree, false),
        ];
        let mut out = Json::obj();
        out.set("n_train", prob.train.n()).set("d", prob.dim());
        let mut methods_json = Json::obj();
        for (name, strategy, opa) in methods {
            let mut opts = method_opts(strategy, opa, outer_iters * 20);
            opts.time_budget = outer_iters as f64 * 0.04;
            let res = hoag_run(&prob, &outer, &theta0, &opts);
            let final_test = res.trace.last().map(|p| p.test_loss).unwrap_or(f64::NAN);
            eprintln!(
                "  [nls] {name}: {:.2}s, final test loss {:.5}",
                res.total_time, final_test
            );
            let mut m = Json::obj();
            m.set("trace", trace_json(&res))
                .set("total_time", res.total_time)
                .set("final_test_loss", final_test);
            methods_json.set(name, m);
        }
        out.set("methods", methods_json);
        Ok(out)
    }
}
