//! DEQ experiments (Fig. 3, Tables E.1–E.3, Fig. E.3, end-to-end driver).
//! All run on the PJRT artifact path — `make artifacts` first.

use crate::coordinator::{ExpCtx, Experiment};
use crate::data::synth_images::{synth_images, ImageDataset};
use crate::deq::trainer::{BackwardKind, Trainer, TrainerConfig};
use crate::power::power_method_session;
use crate::solvers::session::Session;

use crate::runtime::engine::Engine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;
use anyhow::Result;

/// Scale knobs for one DEQ run.
#[derive(Clone, Debug)]
struct DeqScale {
    variant: String,
    pretrain_steps: usize,
    train_steps: usize,
    n_train: usize,
    n_test: usize,
    eval_batches: usize,
    noise: f64,
    lr: f64,
}

impl DeqScale {
    fn new(ctx: &ExpCtx, imagenet: bool) -> DeqScale {
        if ctx.quick {
            DeqScale {
                variant: "tiny".into(),
                pretrain_steps: 5,
                train_steps: 6,
                n_train: 32,
                n_test: 16,
                eval_batches: 2,
                noise: 0.3,
                lr: 8e-3,
            }
        } else if imagenet {
            DeqScale {
                variant: "imagenet".into(),
                pretrain_steps: 8,
                train_steps: 16,
                n_train: 256,
                n_test: 160,
                eval_batches: 5,
                noise: 0.4,
                lr: 8e-3,
            }
        } else {
            DeqScale {
                variant: "cifar".into(),
                pretrain_steps: 20,
                train_steps: 60,
                n_train: 512,
                n_test: 256,
                eval_batches: 8,
                noise: 0.4,
                lr: 8e-3,
            }
        }
    }

    fn datasets(&self, eng: &Engine, seed: u64) -> Result<(ImageDataset, ImageDataset)> {
        let v = eng.manifest.variant(&self.variant)?;
        // One generator call so train and test share the class templates
        // (they are i.i.d. samples of the same task), then split by index.
        let all = synth_images(
            self.n_train + self.n_test,
            v.h,
            v.w,
            v.c_in,
            v.n_classes,
            self.noise,
            seed ^ 0x7A1,
        );
        let d = all.sample_dim();
        let split = |lo: usize, hi: usize| ImageDataset {
            images: all.images[lo * d..hi * d].to_vec(),
            labels: all.labels[lo..hi].to_vec(),
            n: hi - lo,
            h: all.h,
            w: all.w,
            c_in: all.c_in,
            n_classes: all.n_classes,
        };
        Ok((split(0, self.n_train), split(self.n_train, self.n_train + self.n_test)))
    }
}

/// Pretrain a fresh model; returns the parameter snapshot so every method
/// shares the same unrolled pre-training ("models for a given seed share the
/// same unrolled-pretraining steps", §3.2).
fn pretrain_snapshot(
    eng: &Engine,
    scale: &DeqScale,
    train: &ImageDataset,
    seed: u64,
) -> Result<(crate::deq::model::Params, Vec<f64>)> {
    let cfg = TrainerConfig {
        variant: scale.variant.clone(),
        backward: BackwardKind::Shine, // irrelevant during pretraining
        lr: scale.lr,
        total_steps: scale.pretrain_steps + scale.train_steps,
        seed,
        ..Default::default()
    };
    let mut tr = Trainer::new(eng, cfg)?;
    let v = tr.model.v.clone();
    let mut rng = Rng::new(seed ^ 0x11);
    let mut losses = Vec::new();
    let mut step = 0;
    'outer: loop {
        for idx in train.epoch_batches(v.batch, &mut rng) {
            if step >= scale.pretrain_steps {
                break 'outer;
            }
            let (x, labels) = train.batch(&idx);
            losses.push(tr.pretrain_step(&x, &labels)?);
            step += 1;
        }
    }
    Ok((tr.params.clone(), losses))
}

/// Equilibrium-train from a snapshot with the given backward strategy.
/// Returns (trainer with stats, loss curve).
fn equilibrium_train<'e>(
    eng: &'e Engine,
    scale: &DeqScale,
    snapshot: &crate::deq::model::Params,
    backward: BackwardKind,
    train: &ImageDataset,
    seed: u64,
) -> Result<(Trainer<'e>, Vec<f64>)> {
    let cfg = TrainerConfig {
        variant: scale.variant.clone(),
        backward,
        lr: scale.lr, // cosine-annealed over the equilibrium phase
        total_steps: scale.train_steps.max(1),
        seed,
        ..Default::default()
    };
    let mut tr = Trainer::new(eng, cfg)?;
    tr.params = snapshot.clone();
    let v = tr.model.v.clone();
    let mut rng = Rng::new(seed ^ 0x22);
    let mut losses = Vec::new();
    let mut step = 0;
    'outer: loop {
        for idx in train.epoch_batches(v.batch, &mut rng) {
            if step >= scale.train_steps {
                break 'outer;
            }
            let (x, labels) = train.batch(&idx);
            let s = tr.train_step(&x, &labels)?;
            losses.push(s.loss);
            step += 1;
        }
    }
    Ok((tr, losses))
}

fn stats_row(tr: &Trainer, acc: f64, losses: &[f64]) -> Json {
    let fwd: Vec<f64> = tr.stats.iter().map(|s| s.fwd_seconds).collect();
    let bwd: Vec<f64> = tr.stats.iter().map(|s| s.bwd_seconds).collect();
    let fallbacks = tr.stats.iter().filter(|s| s.fallback_used).count();
    let mut j = Json::obj();
    j.set("top1_accuracy", acc)
        .set("median_fwd_ms", stats::median(&fwd) * 1e3)
        .set("median_bwd_ms", stats::median(&bwd) * 1e3)
        .set(
            "median_fwd_iters",
            stats::median(&tr.stats.iter().map(|s| s.fwd_iters as f64).collect::<Vec<_>>()),
        )
        .set(
            "mean_bwd_matvecs",
            stats::mean(&tr.stats.iter().map(|s| s.bwd_matvecs as f64).collect::<Vec<_>>()),
        )
        .set("fallback_steps", fallbacks)
        .set("final_loss", losses.last().copied().unwrap_or(f64::NAN))
        .set("loss_curve", &losses.to_vec()[..]);
    j
}

// ---------------------------------------------------------------------------
// Fig. 3 — accuracy vs backward time, CIFAR-proxy & ImageNet-proxy
// ---------------------------------------------------------------------------

pub struct Fig3 {
    pub imagenet: bool,
}

impl Experiment for Fig3 {
    fn id(&self) -> &'static str {
        if self.imagenet {
            "fig3-imagenet"
        } else {
            "fig3-cifar"
        }
    }
    fn description(&self) -> &'static str {
        "Fig. 3: DEQ top-1 accuracy vs backward-pass time for Original / \
         Jacobian-Free / SHINE (+refined variants)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let eng = Engine::load(&ctx.artifacts_dir)?;
        let scale = DeqScale::new(ctx, self.imagenet);
        eng.warmup_variant(&scale.variant)?;
        let (train, test) = scale.datasets(&eng, ctx.seed)?;
        let (snapshot, pre_losses) = pretrain_snapshot(&eng, &scale, &train, ctx.seed)?;

        let methods: Vec<(String, BackwardKind)> = vec![
            (
                "original".into(),
                BackwardKind::Original {
                    tol: 1e-6,
                    max_iters: 60,
                },
            ),
            (
                "original-limited".into(),
                BackwardKind::Original {
                    tol: 1e-6,
                    max_iters: 5,
                },
            ),
            ("jacobian-free".into(), BackwardKind::JacobianFree),
            (
                "shine".into(),
                if self.imagenet {
                    // ImageNet uses the fallback variant (§3.2).
                    BackwardKind::ShineFallback { ratio: 1.3 }
                } else {
                    BackwardKind::Shine
                },
            ),
            (
                "shine-refine-5".into(),
                BackwardKind::ShineRefine { iters: 5 },
            ),
            (
                "jf-refine-5".into(),
                BackwardKind::JacobianFreeRefine { iters: 5 },
            ),
        ];
        let mut out = Json::obj();
        out.set("variant", scale.variant.as_str())
            .set("pretrain_loss_curve", &pre_losses[..]);
        let mut mj = Json::obj();
        for (name, bk) in methods {
            let (tr, losses) =
                equilibrium_train(&eng, &scale, &snapshot, bk, &train, ctx.seed)?;
            let mut rng = Rng::new(ctx.seed ^ 0x33);
            let acc = tr.evaluate(&test, scale.eval_batches, &mut rng)?;
            let row = stats_row(&tr, acc, &losses);
            eprintln!(
                "  [{}] {name}: acc {:.3}, bwd {:.1}ms, fwd {:.1}ms",
                self.id(),
                acc,
                row.get("median_bwd_ms").unwrap().as_f64().unwrap(),
                row.get("median_fwd_ms").unwrap().as_f64().unwrap()
            );
            mj.set(&name, row);
        }
        out.set("methods", mj);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Table E.1 — nonlinear spectral radius via the power method
// ---------------------------------------------------------------------------

pub struct TableE1;

impl Experiment for TableE1 {
    fn id(&self) -> &'static str {
        "table-e1"
    }
    fn description(&self) -> &'static str {
        "Table E.1: nonlinear spectral radius of f_theta at z* for models \
         trained with Original / Jacobian-Free / SHINE (contractivity probe)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let eng = Engine::load(&ctx.artifacts_dir)?;
        let mut scale = DeqScale::new(ctx, false);
        scale.train_steps /= 2; // 3 trained models: halve each budget
        eng.warmup_variant(&scale.variant)?;
        let (train, _test) = scale.datasets(&eng, ctx.seed)?;
        let (snapshot, _) = pretrain_snapshot(&eng, &scale, &train, ctx.seed)?;
        let methods: Vec<(String, BackwardKind)> = vec![
            (
                "original".into(),
                BackwardKind::Original {
                    tol: 1e-6,
                    max_iters: 60,
                },
            ),
            ("jacobian-free".into(), BackwardKind::JacobianFree),
            ("shine".into(), BackwardKind::Shine),
        ];
        let mut out = Json::obj();
        let power_iters = if ctx.quick { 10 } else { 40 };
        // One probe session across all three trained models (the probes are
        // the same size, so the pooled iterate buffers are reused).
        let mut probe_sess: Session<f32> = Session::new();
        for (name, bk) in methods {
            let (tr, _) = equilibrium_train(&eng, &scale, &snapshot, bk, &train, ctx.seed)?;
            // Solve one batch to its fixed point, then power-method the
            // Jacobian of f there via the f_jvp artifact.
            let v = tr.model.v.clone();
            let mut rng = Rng::new(ctx.seed ^ 0x44);
            let idx = train.epoch_batches(v.batch, &mut rng).remove(0);
            let (x, _labels) = train.batch(&idx);
            let u = tr.model.inject(&tr.params, &x)?;
            let fwd = tr.forward_solve(&u)?;
            let zf = fwd.z.clone();
            let model = &tr.model;
            let params = &tr.params;
            // f32 end-to-end: the probe vector feeds the f_jvp artifact
            // directly (the power method is precision-generic and draws its
            // iterate buffers from the shared probe session).
            let res = power_method_session(
                |vv: &[f32], out: &mut [f32]| match model.f_jvp(params, &zf, &u, vv) {
                    Ok(t) => out.copy_from_slice(&t),
                    Err(_) => out.copy_from_slice(vv),
                },
                zf.len(),
                power_iters,
                &mut rng,
                &mut probe_sess,
            );
            eprintln!("  [table-e1] {name}: spectral radius {:.2}", res.radius);
            let mut j = Json::obj();
            j.set("spectral_radius", res.radius)
                .set("history", &res.history[..]);
            out.set(&name, j);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Table E.2 — forward/backward/epoch timings per method
// ---------------------------------------------------------------------------

pub struct TableE2;

impl Experiment for TableE2 {
    fn id(&self) -> &'static str {
        "table-e2"
    }
    fn description(&self) -> &'static str {
        "Table E.2: median forward/backward pass time per method (single batch) \
         and estimated epoch time"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let eng = Engine::load(&ctx.artifacts_dir)?;
        let mut out = Json::obj();
        let variants: Vec<bool> = if ctx.quick {
            vec![false]
        } else {
            vec![false, true]
        };
        for imagenet in variants {
            let scale = DeqScale::new(ctx, imagenet);
            eng.warmup_variant(&scale.variant)?;
            let (train, _) = scale.datasets(&eng, ctx.seed)?;
            let (snapshot, _) = pretrain_snapshot(&eng, &scale, &train, ctx.seed)?;
            let n_timing = if ctx.quick { 3 } else { 6 };
            let methods: Vec<(String, BackwardKind)> = vec![
                (
                    "original".into(),
                    BackwardKind::Original {
                        tol: 1e-6,
                        max_iters: 60,
                    },
                ),
                ("jacobian-free".into(), BackwardKind::JacobianFree),
                (
                    "shine-fallback".into(),
                    BackwardKind::ShineFallback { ratio: 1.3 },
                ),
                (
                    "shine-fallback-refine-5".into(),
                    BackwardKind::ShineRefine { iters: 5 },
                ),
                (
                    "jacobian-free-refine-5".into(),
                    BackwardKind::JacobianFreeRefine { iters: 5 },
                ),
                (
                    "original-limited".into(),
                    BackwardKind::Original {
                        tol: 1e-6,
                        max_iters: 5,
                    },
                ),
            ];
            let mut vj = Json::obj();
            for (name, bk) in methods {
                let cfg = TrainerConfig {
                    variant: scale.variant.clone(),
                    backward: bk,
                    lr: 0.0, // timing only: no parameter drift between methods
                    total_steps: 1,
                    seed: ctx.seed,
                    ..Default::default()
                };
                let mut tr = Trainer::new(&eng, cfg)?;
                tr.params = snapshot.clone();
                let v = tr.model.v.clone();
                let mut rng = Rng::new(ctx.seed ^ 0x55);
                let batches = train.epoch_batches(v.batch, &mut rng);
                for idx in batches.iter().take(n_timing) {
                    let (x, labels) = train.batch(idx);
                    tr.train_step(&x, &labels)?;
                }
                let fwd: Vec<f64> = tr.stats.iter().map(|s| s.fwd_seconds).collect();
                let bwd: Vec<f64> = tr.stats.iter().map(|s| s.bwd_seconds).collect();
                let fwd_ms = stats::median(&fwd) * 1e3;
                let bwd_ms = stats::median(&bwd) * 1e3;
                // Epoch estimate: our train set has n_train/batch batches.
                let epoch_s = (fwd_ms + bwd_ms) / 1e3 * (scale.n_train / v.batch) as f64;
                eprintln!(
                    "  [table-e2 {}] {name}: fwd {fwd_ms:.1}ms bwd {bwd_ms:.1}ms epoch {epoch_s:.1}s",
                    scale.variant
                );
                let mut j = Json::obj();
                j.set("fwd_ms", fwd_ms)
                    .set("bwd_ms", bwd_ms)
                    .set("epoch_seconds", epoch_s);
                vj.set(&name, j);
            }
            out.set(&scale.variant, vj);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Table E.3 — OPA / Adjoint Broyden accuracy on CIFAR-proxy
// ---------------------------------------------------------------------------

pub struct TableE3;

impl Experiment for TableE3 {
    fn id(&self) -> &'static str {
        "table-e3"
    }
    fn description(&self) -> &'static str {
        "Table E.3: top-1 accuracy and epoch time for Original / Jacobian-Free / \
         SHINE(Broyden) / SHINE(Adjoint Broyden) / SHINE(Adjoint Broyden + OPA)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let eng = Engine::load(&ctx.artifacts_dir)?;
        let mut scale = DeqScale::new(ctx, false);
        scale.train_steps /= 2; // 5 trained models: halve each budget
        eng.warmup_variant(&scale.variant)?;
        let (train, test) = scale.datasets(&eng, ctx.seed)?;
        let (snapshot, _) = pretrain_snapshot(&eng, &scale, &train, ctx.seed)?;
        let methods: Vec<(String, BackwardKind)> = vec![
            (
                "original".into(),
                BackwardKind::Original {
                    tol: 1e-6,
                    max_iters: 60,
                },
            ),
            ("jacobian-free".into(), BackwardKind::JacobianFree),
            ("shine-broyden".into(), BackwardKind::Shine),
            (
                "shine-adj-broyden".into(),
                BackwardKind::AdjointBroyden { opa_freq: None },
            ),
            (
                "shine-adj-broyden-opa".into(),
                BackwardKind::AdjointBroyden { opa_freq: Some(5) },
            ),
        ];
        let mut out = Json::obj();
        for (name, bk) in methods {
            let (tr, losses) = equilibrium_train(&eng, &scale, &snapshot, bk, &train, ctx.seed)?;
            let mut rng = Rng::new(ctx.seed ^ 0x66);
            let acc = tr.evaluate(&test, scale.eval_batches, &mut rng)?;
            let fwd: Vec<f64> = tr.stats.iter().map(|s| s.fwd_seconds).collect();
            let bwd: Vec<f64> = tr.stats.iter().map(|s| s.bwd_seconds).collect();
            let v = tr.model.v.clone();
            let epoch_s = (stats::median(&fwd) + stats::median(&bwd))
                * (scale.n_train / v.batch) as f64;
            eprintln!("  [table-e3] {name}: acc {acc:.3}, epoch {epoch_s:.1}s");
            let mut j = Json::obj();
            j.set("top1_accuracy", acc)
                .set("epoch_seconds", epoch_s)
                .set("final_loss", losses.last().copied().unwrap_or(f64::NAN));
            out.set(&name, j);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Fig. E.3 — inversion quality in DEQs
// ---------------------------------------------------------------------------

pub struct FigE3;

impl Experiment for FigE3 {
    fn id(&self) -> &'static str {
        "fig-e3"
    }
    fn description(&self) -> &'static str {
        "Fig. E.3: ratio/cosine of the approximate left-inverse direction \
         vs exact (tightly solved) for JF / SHINE / Adjoint-Broyden(+OPA)"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let eng = Engine::load(&ctx.artifacts_dir)?;
        let scale = DeqScale::new(ctx, false);
        eng.warmup_variant(&scale.variant)?;
        let (train, _) = scale.datasets(&eng, ctx.seed)?;
        let (snapshot, _) = pretrain_snapshot(&eng, &scale, &train, ctx.seed)?;
        let n_batches = if ctx.quick { 2 } else { 10 };

        // The paper compares each approximate left-inverse direction against
        // the *exact* J^-T grad. At d = 65k with a non-contractive f the
        // exact direction is not computable to tolerance in reasonable time,
        // so we report the exactly-computable *adjoint residual*
        //     ||w^T J_g - dL/dz|| / ||dL/dz||
        // (one VJP per measurement): 0 = perfect inversion, 1 = the error of
        // doing nothing. The paper's ordering (OPA best, then SHINE variants,
        // then Jacobian-Free) is preserved under this metric.
        let strategies: Vec<(String, BackwardKind)> = vec![
            ("jacobian-free".into(), BackwardKind::JacobianFree),
            ("shine-broyden".into(), BackwardKind::Shine),
            (
                "shine-adj-broyden".into(),
                BackwardKind::AdjointBroyden { opa_freq: None },
            ),
            (
                "shine-adj-broyden-opa".into(),
                BackwardKind::AdjointBroyden { opa_freq: Some(5) },
            ),
            (
                "shine-refine-5".into(),
                BackwardKind::ShineRefine { iters: 5 },
            ),
            (
                "original-60".into(),
                BackwardKind::Original {
                    tol: 1e-6,
                    max_iters: 60,
                },
            ),
        ];
        let mut out = Json::obj();
        for (name, bk) in strategies {
            let cfg = TrainerConfig {
                variant: scale.variant.clone(),
                backward: bk,
                lr: 0.0,
                total_steps: 1,
                seed: ctx.seed,
                ..Default::default()
            };
            let mut tr = Trainer::new(&eng, cfg)?;
            tr.params = snapshot.clone();
            let v = tr.model.v.clone();
            let mut rng = Rng::new(ctx.seed ^ 0x77);
            let batches = train.epoch_batches(v.batch, &mut rng);
            let mut residuals = Vec::new();
            for idx in batches.iter().take(n_batches) {
                let (x, labels) = train.batch(idx);
                let y = crate::deq::native::one_hot(&labels, v.n_classes);
                let u = tr.model.inject(&tr.params, &x)?;
                let fwd = tr.forward_solve(&u)?;
                let (_, dz, _, _) = tr.model.head_loss_grad(&tr.params, &fwd.z, &y)?;
                let (w, _, _) = tr.backward_direction(&fwd, &u, &dz);
                // residual r = w^T J_g - dz = w - w^T J_f - dz  (one VJP;
                // w is f32 now, so it feeds the VJP artifact directly —
                // the diagnostic norms below still widen to f64)
                let jw = tr.model.f_vjp_z(&tr.params, &fwd.z, &u, &w)?;
                let dz_norm: f64 = dz.iter().map(|&a| (a as f64) * (a as f64)).sum::<f64>().sqrt();
                let res_norm: f64 = (0..w.len())
                    .map(|i| {
                        let r = w[i] as f64 - jw[i] as f64 - dz[i] as f64;
                        r * r
                    })
                    .sum::<f64>()
                    .sqrt();
                residuals.push(res_norm / dz_norm.max(1e-300));
            }
            let med = stats::median(&residuals);
            eprintln!("  [fig-e3] {name}: median adjoint residual {med:.3}");
            let mut j = Json::obj();
            j.set("residuals", &residuals[..])
                .set("median_residual", med);
            out.set(&name, j);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// End-to-end driver (DESIGN.md §5 `e2e`)
// ---------------------------------------------------------------------------

pub struct EndToEnd;

impl Experiment for EndToEnd {
    fn id(&self) -> &'static str {
        "e2e"
    }
    fn description(&self) -> &'static str {
        "End-to-end driver: pretrain + SHINE equilibrium training of the DEQ \
         classifier on the synthetic image task, with loss curve and eval"
    }
    fn run(&self, ctx: &ExpCtx) -> Result<Json> {
        let eng = Engine::load(&ctx.artifacts_dir)?;
        let scale = DeqScale::new(ctx, false);
        eng.warmup_variant(&scale.variant)?;
        let (train, test) = scale.datasets(&eng, ctx.seed)?;
        let (snapshot, pre_losses) = pretrain_snapshot(&eng, &scale, &train, ctx.seed)?;
        let (tr, losses) = equilibrium_train(
            &eng,
            &scale,
            &snapshot,
            BackwardKind::Shine,
            &train,
            ctx.seed,
        )?;
        let mut rng = Rng::new(ctx.seed ^ 0x88);
        let acc = tr.evaluate(&test, scale.eval_batches, &mut rng)?;
        let train_acc = tr.evaluate(&train, scale.eval_batches, &mut rng)?;
        eprintln!(
            "  [e2e] {} params, test acc {acc:.3}, train acc {train_acc:.3}",
            tr.params.n_params()
        );
        let mut out = stats_row(&tr, acc, &losses);
        out.set("train_accuracy", train_acc)
            .set("pretrain_loss_curve", &pre_losses[..])
            .set("n_params", tr.params.n_params())
            .set("fixed_point_dim", tr.model.v.fixed_point_dim);
        Ok(out)
    }
}
