//! Experiment coordinator: every table and figure of the paper is a
//! registered [`Experiment`]; `shine run <id>` executes it and writes
//! `results/<id>.json` (DESIGN.md §5 maps ids to paper artifacts).

pub mod ablations;
pub mod report;
pub mod bilevel_exps;
pub mod deq_exps;

use crate::util::json::Json;
use anyhow::Result;

/// Shared experiment context.
#[derive(Clone, Debug)]
pub struct ExpCtx {
    pub seed: u64,
    /// reduced problem sizes / step counts for smoke runs (CI, --quick)
    pub quick: bool,
    pub out_dir: String,
    /// artifact directory for DEQ experiments
    pub artifacts_dir: String,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            seed: 0,
            quick: false,
            out_dir: "results".into(),
            artifacts_dir: crate::runtime::engine::Engine::default_dir(),
        }
    }
}

pub trait Experiment {
    fn id(&self) -> &'static str;
    fn description(&self) -> &'static str;
    fn run(&self, ctx: &ExpCtx) -> Result<Json>;
}

/// All registered experiments, in DESIGN.md §5 order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(bilevel_exps::Fig1),
        Box::new(bilevel_exps::Fig2Left),
        Box::new(bilevel_exps::Fig2Right),
        Box::new(bilevel_exps::FigE1),
        Box::new(bilevel_exps::FigE2),
        Box::new(deq_exps::Fig3 { imagenet: false }),
        Box::new(deq_exps::Fig3 { imagenet: true }),
        Box::new(deq_exps::TableE1),
        Box::new(deq_exps::TableE2),
        Box::new(deq_exps::TableE3),
        Box::new(deq_exps::FigE3),
        Box::new(deq_exps::EndToEnd),
        Box::new(ablations::Ablations),
    ]
}

/// Run one experiment by id; persists the JSON result and returns it.
pub fn run_experiment(id: &str, ctx: &ExpCtx) -> Result<Json> {
    let exps = registry();
    let exp = exps
        .iter()
        .find(|e| e.id() == id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}'; try `shine list`"))?;
    let sw = crate::util::timer::Stopwatch::start();
    let mut out = exp.run(ctx)?;
    out.set("experiment", id)
        .set("seed", ctx.seed)
        .set("quick", ctx.quick)
        .set("wall_seconds", sw.elapsed());
    let path = format!("{}/{}.json", ctx.out_dir, id);
    crate::util::json::write_file(&path, &out)?;
    eprintln!("wrote {path} ({:.1}s)", sw.elapsed());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_are_unique_and_nonempty() {
        let reg = registry();
        assert!(reg.len() >= 12);
        let mut ids: Vec<&str> = reg.iter().map(|e| e.id()).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate experiment ids");
        for e in &reg {
            assert!(!e.description().is_empty());
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        let ctx = ExpCtx {
            quick: true,
            ..Default::default()
        };
        assert!(run_experiment("nope", &ctx).is_err());
    }
}
