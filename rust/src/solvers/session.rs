//! The unified solve-session API: **`SolverSpec` → `FixedPointSolver` →
//! `SolveOutcome` → `Backward`** — one surface for "solve the fixed point,
//! capture the inverse estimate, share it with the backward pass".
//!
//! SHINE's core move (Ramzi et al., ICLR 2022, §3) is that the forward
//! solver's quasi-Newton state *is* the backward operator. Before this
//! module, forward and backward were disconnected free functions
//! (`broyden_solve_ws`, `anderson_solve_ws`, the `*_batch` family, plus a
//! separate `hypergrad::Strategy` dispatch) that every caller re-wired by
//! hand. Here the solver family and the gradient strategy are swappable
//! *values* behind two trait APIs, in the spirit of the solver registries in
//! torchdeq / the original `mdeq` codebase:
//!
//! * [`SolverSpec`] — a plain config value (Picard | Anderson{m, β} |
//!   Broyden{m, line-search} plus `tol`/`max_iters`, the **single source of
//!   truth** for tolerances — consumers no longer restate them);
//! * [`FixedPointSolver`] — the trait object [`SolverSpec::build`] produces:
//!   `solve(&mut Session, g, z0) -> SolveOutcome` for one problem and
//!   [`FixedPointSolver::solve_batch`] for a contiguous d × B column block
//!   (the serving path);
//! * [`SolveOutcome`] — the converged iterate, convergence telemetry and,
//!   when the method builds one, the **captured inverse-estimate handle**
//!   ([`EstimateHandle`]);
//! * [`Backward`] — the companion trait (Shine | JacobianFree | Fallback |
//!   Refine | Full) that consumes the handle, making "share the inverse
//!   estimate" a type-level contract instead of a calling convention.
//!
//! A [`Session`] owns the [`Workspace`] scratch arena shared by forward and
//! backward passes; the solver loops stay allocation-free once it is warm
//! (see `rust/tests/qn_alloc.rs`).
//!
//! The legacy free functions in [`crate::solvers::fixed_point`] survive as
//! thin deprecated shims that delegate here — bit-identical trajectories,
//! pinned by `rust/tests/session_parity.rs` — so external snippets keep
//! compiling while every in-tree consumer (DEQ trainer, HOAG, power probes,
//! coordinator experiments, the serving tier, the CLI) goes through this
//! API.

use crate::linalg::vecops::Elem;
use crate::qn::low_rank::LowRank;
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};
use crate::solvers::fixed_point::{
    anderson_core, broyden_core, picard_batch_core, picard_core, AndersonBatch, ColStats,
    FpOptions, FpResult,
};
use crate::solvers::linear::{broyden_solve_left_ws, cg_solve};
use crate::solvers::Trace;

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// A solve session: the scratch arena shared by every forward solve and
/// backward pass of one consumer (a trainer, an outer loop, a serving
/// engine). Buffers pooled here are recycled across solves, so the hot
/// loops perform zero heap allocations once the session is warm.
#[derive(Debug, Default)]
pub struct Session<E: Elem = f64> {
    ws: Workspace<E>,
}

impl<E: Elem> Session<E> {
    pub fn new() -> Session<E> {
        Session {
            ws: Workspace::new(),
        }
    }

    /// Wrap an existing workspace (the legacy-shim path: the free functions
    /// take `&mut Workspace`, so they lift it into a session for the call).
    pub fn from_workspace(ws: Workspace<E>) -> Session<E> {
        Session { ws }
    }

    /// Hand the workspace back (inverse of [`Session::from_workspace`]).
    pub fn into_workspace(self) -> Workspace<E> {
        self.ws
    }

    /// The underlying scratch arena (for code still written against raw
    /// `Workspace` plumbing, e.g. the adjoint-Broyden forward).
    pub fn workspace(&mut self) -> &mut Workspace<E> {
        &mut self.ws
    }
}

// ---------------------------------------------------------------------------
// SolverSpec
// ---------------------------------------------------------------------------

/// Which fixed-point iteration a [`SolverSpec`] builds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SolverMethod {
    /// Damped Picard iteration z ← z − τ g(z).
    Picard { tau: f64 },
    /// Anderson(m) acceleration with mixing parameter β.
    Anderson { m: usize, beta: f64 },
    /// Broyden's method with limited memory and optional backtracking
    /// line search — the only method that captures an inverse estimate.
    Broyden {
        memory: usize,
        policy: MemoryPolicy,
        line_search: bool,
    },
}

impl SolverMethod {
    pub fn name(&self) -> &'static str {
        match self {
            SolverMethod::Picard { .. } => "picard",
            SolverMethod::Anderson { .. } => "anderson",
            SolverMethod::Broyden { .. } => "broyden",
        }
    }
}

/// Config value describing one fixed-point solver: method plus the
/// tolerance/budget that used to be restated at every call site. This is
/// the single source of truth — `serve::EngineConfig`, the trainer and the
/// CLI all carry a `SolverSpec` instead of loose `tol`/`max_iters` copies.
///
/// # Examples
///
/// Parse (or construct) a spec, tighten the tolerance, build the solver and
/// run it — the whole forward surface in four lines:
///
/// ```
/// use shine::solvers::session::{Session, SolverSpec};
///
/// let spec = SolverSpec::parse("anderson:5").unwrap().with_tol(1e-10);
/// let mut solver = spec.build::<f64>();
/// let mut sess: Session<f64> = Session::new();
/// let mut g = |z: &[f64], out: &mut [f64]| {
///     for i in 0..z.len() {
///         out[i] = z[i] - 0.5 * z[(i + 1) % z.len()] - 1.0;
///     }
/// };
/// let out = solver.solve(&mut sess, &mut g, &[0.0; 8]);
/// assert!(out.converged && out.residual <= 1e-10);
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SolverSpec {
    pub method: SolverMethod,
    /// Absolute tolerance on ‖g(z)‖.
    pub tol: f64,
    /// Per-solve iteration budget.
    pub max_iters: usize,
}

impl SolverSpec {
    pub fn picard(tau: f64) -> SolverSpec {
        SolverSpec {
            method: SolverMethod::Picard { tau },
            tol: 1e-8,
            max_iters: 200,
        }
    }

    pub fn anderson(m: usize, beta: f64) -> SolverSpec {
        SolverSpec {
            method: SolverMethod::Anderson { m, beta },
            tol: 1e-8,
            max_iters: 200,
        }
    }

    /// Broyden with the paper's defaults (Freeze policy, no line search).
    pub fn broyden(memory: usize) -> SolverSpec {
        SolverSpec {
            method: SolverMethod::Broyden {
                memory,
                policy: MemoryPolicy::Freeze,
                line_search: false,
            },
            tol: 1e-8,
            max_iters: 200,
        }
    }

    pub fn with_tol(mut self, tol: f64) -> SolverSpec {
        self.tol = tol;
        self
    }

    pub fn with_max_iters(mut self, max_iters: usize) -> SolverSpec {
        self.max_iters = max_iters;
        self
    }

    pub fn with_line_search(mut self, ls: bool) -> SolverSpec {
        if let SolverMethod::Broyden { line_search, .. } = &mut self.method {
            *line_search = ls;
        }
        self
    }

    /// Lift the legacy Broyden option struct (shim path).
    pub fn from_fp_options(opts: &FpOptions) -> SolverSpec {
        SolverSpec {
            method: SolverMethod::Broyden {
                memory: opts.memory,
                policy: opts.policy,
                line_search: opts.line_search,
            },
            tol: opts.tol,
            max_iters: opts.max_iters,
        }
    }

    /// Lower to the legacy option struct (Broyden only; other methods get
    /// the defaults with this spec's tol/budget).
    pub fn fp_options(&self) -> FpOptions {
        match self.method {
            SolverMethod::Broyden {
                memory,
                policy,
                line_search,
            } => FpOptions {
                tol: self.tol,
                max_iters: self.max_iters,
                memory,
                policy,
                line_search,
            },
            _ => FpOptions {
                tol: self.tol,
                max_iters: self.max_iters,
                ..Default::default()
            },
        }
    }

    /// Parse a CLI-style spec: `picard[:tau]`, `anderson[:m[,beta]]`,
    /// `broyden[:memory]` (tolerance/budget come from separate flags).
    pub fn parse(s: &str) -> Result<SolverSpec, String> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "picard" => {
                let tau = match args {
                    Some(a) => a.parse::<f64>().map_err(|_| format!("bad tau '{a}'"))?,
                    None => 1.0,
                };
                Ok(SolverSpec::picard(tau))
            }
            "anderson" => {
                let (m, beta) = match args {
                    Some(a) => match a.split_once(',') {
                        Some((ms, bs)) => (
                            ms.parse::<usize>().map_err(|_| format!("bad m '{ms}'"))?,
                            bs.parse::<f64>().map_err(|_| format!("bad beta '{bs}'"))?,
                        ),
                        None => (a.parse::<usize>().map_err(|_| format!("bad m '{a}'"))?, 1.0),
                    },
                    None => (5, 1.0),
                };
                Ok(SolverSpec::anderson(m, beta))
            }
            "broyden" => {
                let memory = match args {
                    Some(a) => a.parse::<usize>().map_err(|_| format!("bad memory '{a}'"))?,
                    None => 30,
                };
                Ok(SolverSpec::broyden(memory))
            }
            other => Err(format!(
                "unknown solver '{other}' (picard[:tau] | anderson[:m[,beta]] | broyden[:memory])"
            )),
        }
    }

    /// Build the solver this spec describes.
    pub fn build<E: Elem>(&self) -> Box<dyn FixedPointSolver<E>> {
        match self.method {
            SolverMethod::Picard { .. } => Box::new(PicardSolver { spec: *self }),
            SolverMethod::Anderson { .. } => Box::new(AndersonSolver {
                spec: *self,
                batch: None,
                batch_d: 0,
            }),
            SolverMethod::Broyden { .. } => Box::new(BroydenSolver { spec: *self }),
        }
    }
}

impl Default for SolverSpec {
    /// The DEQ-paper default: Broyden(30), tol 1e-8, 200 iterations.
    fn default() -> Self {
        SolverSpec::broyden(30)
    }
}

// ---------------------------------------------------------------------------
// PanelPrecision
// ---------------------------------------------------------------------------

/// Panel-storage precision of a serving-tier inverse estimate — the value
/// of the CLI `--panel-precision` flag, naming one instantiation of
/// `ServeEngine<E, EU, EV>` / `Router<E, EU, EV>` /
/// `ShardedRouter<E, EU, EV>`.
///
/// Monomorphized generics cannot be selected by a runtime value directly,
/// so this enum is the dispatch point: callers match on it and call their
/// generic driver with the corresponding storage types. State (iterates,
/// cotangents, residuals) stays `f32` in every reduced variant — only the
/// cached estimate's factor panels are demoted, and all accumulation is
/// f64 regardless (the `Elem` contract). See
/// `docs/adr/003-reduced-precision-panels.md` for why `Mixed` is the
/// recommended reduced layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PanelPrecision {
    /// `<f64, f64, f64>` — the bi-level/HOAG reference precision.
    F64,
    /// `<f32, f32, f32>` — the DEQ serving default.
    F32,
    /// `<f32, Bf16, Bf16>` — both panels bf16 (maximum traffic win).
    Bf16,
    /// `<f32, F16, F16>` — both panels IEEE binary16.
    F16,
    /// `<f32, Bf16, f32>` — bf16 U factors, f32 V factors: the
    /// accuracy-critical mixed layout (U carries the memory traffic of the
    /// backward sweep; V feeds the coefficient reductions where error is
    /// cheapest to avoid).
    Mixed,
}

impl PanelPrecision {
    /// CLI / JSON name of the variant.
    pub fn name(&self) -> &'static str {
        match self {
            PanelPrecision::F64 => "f64",
            PanelPrecision::F32 => "f32",
            PanelPrecision::Bf16 => "bf16",
            PanelPrecision::F16 => "f16",
            PanelPrecision::Mixed => "mixed",
        }
    }

    /// Parse a CLI-style name (`f64 | f32 | bf16 | f16 | mixed`).
    pub fn parse(s: &str) -> Result<PanelPrecision, String> {
        match s {
            "f64" => Ok(PanelPrecision::F64),
            "f32" => Ok(PanelPrecision::F32),
            "bf16" => Ok(PanelPrecision::Bf16),
            "f16" => Ok(PanelPrecision::F16),
            "mixed" => Ok(PanelPrecision::Mixed),
            other => Err(format!(
                "unknown panel precision '{other}' (f64 | f32 | bf16 | f16 | mixed)"
            )),
        }
    }

    /// Every variant, in documentation order (drives sweep harnesses).
    pub fn all() -> [PanelPrecision; 5] {
        [
            PanelPrecision::F64,
            PanelPrecision::F32,
            PanelPrecision::Bf16,
            PanelPrecision::F16,
            PanelPrecision::Mixed,
        ]
    }
}

// ---------------------------------------------------------------------------
// SolveOutcome + EstimateHandle
// ---------------------------------------------------------------------------

/// The captured forward inverse estimate `H ≈ J_g⁻¹` — the object SHINE
/// shares with the backward pass. Holding one is proof a forward solve
/// produced it; [`Backward`] strategies consume it through
/// [`EstimateHandle::forward`], and the serving tier caches one per
/// [`crate::serve::ModelKey`].
#[derive(Clone, Debug)]
pub struct EstimateHandle<E: Elem = f64> {
    lr: LowRank<E>,
}

impl<E: Elem> EstimateHandle<E> {
    pub fn new(lr: LowRank<E>) -> EstimateHandle<E> {
        EstimateHandle { lr }
    }

    pub fn rank(&self) -> usize {
        self.lr.rank()
    }

    pub fn low_rank(&self) -> &LowRank<E> {
        &self.lr
    }

    pub fn into_low_rank(self) -> LowRank<E> {
        self.lr
    }

    /// Borrow as the artifact bundle a [`Backward`] strategy consumes.
    pub fn forward(&self) -> ForwardHandle<'_, E> {
        ForwardHandle {
            inv: Some(&self.lr),
            low_rank: Some(&self.lr),
        }
    }
}

impl<E: Elem> InvOp<E> for EstimateHandle<E> {
    fn dim(&self) -> usize {
        self.lr.dim()
    }
    fn apply(&self, x: &[E], out: &mut [E]) {
        self.lr.apply(x, out)
    }
    fn apply_t(&self, x: &[E], out: &mut [E]) {
        self.lr.apply_t(x, out)
    }
    fn apply_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.lr.apply_into(x, out, ws)
    }
    fn apply_t_into(&self, x: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.lr.apply_t_into(x, out, ws)
    }
    fn apply_multi(&self, xs: &[E], out: &mut [E]) {
        self.lr.apply_multi(xs, out)
    }
    fn apply_t_multi(&self, xs: &[E], out: &mut [E]) {
        self.lr.apply_t_multi(xs, out)
    }
    fn apply_multi_into(&self, xs: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.lr.apply_multi_into(xs, out, ws)
    }
    fn apply_t_multi_into(&self, xs: &[E], out: &mut [E], ws: &mut Workspace<E>) {
        self.lr.apply_t_multi_into(xs, out, ws)
    }
}

/// What one [`FixedPointSolver::solve`] produced.
#[derive(Debug)]
pub struct SolveOutcome<E: Elem = f64> {
    /// The final iterate.
    pub z: Vec<E>,
    /// Final residual norm ‖g(z)‖.
    pub residual: f64,
    pub iters: usize,
    pub converged: bool,
    /// Residual evaluations spent (≠ iters when line search is active).
    pub n_g_evals: usize,
    /// Per-iteration residual/time telemetry (empty for methods that do not
    /// record one).
    pub trace: Trace,
    /// The captured inverse-estimate handle — `Some` only for quasi-Newton
    /// methods (Broyden). This is the SHINE hand-off.
    pub estimate: Option<EstimateHandle<E>>,
}

impl<E: Elem> SolveOutcome<E> {
    /// Whether the final residual is a finite number. A NaN/Inf residual
    /// means the model emitted non-finite values mid-solve: the captured
    /// estimate panel is then garbage and must not be installed for
    /// serving — the serve tier counts such a solve as a failed
    /// calibration and a circuit-breaker strike
    /// (see [`crate::serve::CircuitBreaker`]).
    pub fn residual_finite(&self) -> bool {
        self.residual.is_finite()
    }

    /// Lower to the legacy Broyden result struct (shim path). Panics if the
    /// solve captured no estimate — only Broyden outcomes convert.
    pub fn into_fp_result(self) -> FpResult<E> {
        let est = self
            .estimate
            .expect("only quasi-Newton outcomes carry an estimate");
        FpResult {
            z: self.z,
            g_norm: self.residual,
            iters: self.iters,
            converged: self.converged,
            qn: crate::qn::broyden::BroydenInverse::from_low_rank(est.into_low_rank()),
            trace: self.trace,
            n_g_evals: self.n_g_evals,
        }
    }
}

// ---------------------------------------------------------------------------
// FixedPointSolver trait + implementations
// ---------------------------------------------------------------------------

/// A built fixed-point solver. Stateful: Anderson keeps its per-column
/// batch states across calls (the serving engine relies on this for its
/// zero-allocation steady state), so methods take `&mut self`.
pub trait FixedPointSolver<E: Elem> {
    /// The spec this solver was built from.
    fn spec(&self) -> &SolverSpec;

    /// Solve g(z) = 0 from `z0`, drawing scratch from the session.
    fn solve(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &mut [E]),
        z0: &[E],
    ) -> SolveOutcome<E>;

    /// Solve B independent problems packed as a contiguous d × B
    /// column-major block (`zs`, in: initial iterates, out: solutions in
    /// submission order). The batched residual `g(block, ids, out)`
    /// evaluates `ids.len()` active columns in one call; `ids[p]` names the
    /// caller-side column at physical position `p` (compaction permutes).
    /// Per-column outcomes land in `stats` (length ≥ B); each column's
    /// trajectory is bit-identical to a sequential [`FixedPointSolver::solve`]
    /// with the same spec.
    fn solve_batch(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        d: usize,
        stats: &mut [ColStats],
    );

    /// Pre-size internal per-column state for batches up to `max_cols`
    /// columns of dimension `d` (so the first real batch allocates
    /// nothing). Stateless methods ignore this.
    fn prepare_batch(&mut self, _d: usize, _max_cols: usize, _sess: &mut Session<E>) {}

    // ---- solve_streaming surface (continuous batching) --------------------
    //
    // The serving engine's continuous-batching loop
    // ([`crate::serve::ServeEngine::process_streaming`]) owns the block,
    // the per-column iteration counters and the retirement/compaction
    // bookkeeping; the solver contributes exactly three things: reset a
    // column's state when a request is injected mid-solve, move per-column
    // state along with a compaction swap, and advance the active prefix one
    // iteration. Picard and Anderson support this (their per-column updates
    // are independent, so injection never perturbs a neighbour's
    // trajectory); Broyden does not (its qN state spans the whole solve).

    /// Whether this solver implements the streaming hooks below. Engines
    /// must check before driving [`FixedPointSolver::stream_advance`].
    fn supports_streaming(&self) -> bool {
        false
    }

    /// A new request was admitted into block column `slot` mid-solve:
    /// forget that column's solver state without touching any neighbour.
    /// Default no-op (stateless methods have nothing to forget).
    fn stream_admit(&mut self, _slot: usize) {}

    /// Block columns `a` and `b` were swapped by retirement compaction —
    /// swap any per-column solver state along with them. Default no-op.
    fn stream_swap(&mut self, _a: usize, _b: usize) {}

    /// Advance the active prefix (`zs`/`r` are `active × d`, column-major)
    /// one iteration given the freshly evaluated residual block — the same
    /// per-column update [`FixedPointSolver::solve_batch`] applies, so each
    /// column's trajectory stays bit-identical to a solo solve.
    fn stream_advance(&mut self, _sess: &mut Session<E>, _zs: &mut [E], _r: &[E], _d: usize) {
        panic!(
            "{} does not support streaming solves (check supports_streaming)",
            self.spec().method.name()
        );
    }

    /// Return internal buffers to the session pools (one-shot users; a
    /// long-lived solver just keeps them).
    fn release(&mut self, _sess: &mut Session<E>) {}
}

/// Damped Picard iteration (stateless).
pub struct PicardSolver {
    spec: SolverSpec,
}

impl PicardSolver {
    fn tau(&self) -> f64 {
        match self.spec.method {
            SolverMethod::Picard { tau } => tau,
            _ => unreachable!("PicardSolver built from a Picard spec"),
        }
    }
}

impl<E: Elem> FixedPointSolver<E> for PicardSolver {
    fn spec(&self) -> &SolverSpec {
        &self.spec
    }

    fn solve(
        &mut self,
        _sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &mut [E]),
        z0: &[E],
    ) -> SolveOutcome<E> {
        let (z, residual, iters) =
            picard_core(g, z0, self.tau(), self.spec.tol, self.spec.max_iters);
        SolveOutcome {
            converged: residual <= self.spec.tol,
            z,
            residual,
            iters,
            n_g_evals: iters + 1,
            trace: Trace::default(),
            estimate: None,
        }
    }

    fn solve_batch(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        d: usize,
        stats: &mut [ColStats],
    ) {
        picard_batch_core(
            g,
            zs,
            d,
            self.tau(),
            self.spec.tol,
            self.spec.max_iters,
            &mut sess.ws,
            stats,
        );
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    /// One fused damped-Picard step over the active prefix — columnwise
    /// independent, so mid-solve injection needs no state reset.
    fn stream_advance(&mut self, _sess: &mut Session<E>, zs: &mut [E], r: &[E], _d: usize) {
        crate::linalg::vecops::axpy(-self.tau(), r, zs);
    }
}

/// Anderson(m) acceleration. Holds the per-column batch state machine
/// across calls so repeated batch solves through one solver are
/// allocation-free (the serving steady state).
pub struct AndersonSolver<E: Elem> {
    spec: SolverSpec,
    batch: Option<AndersonBatch<E>>,
    batch_d: usize,
}

impl<E: Elem> AndersonSolver<E> {
    fn params(&self) -> (usize, f64) {
        match self.spec.method {
            SolverMethod::Anderson { m, beta } => (m, beta),
            _ => unreachable!("AndersonSolver built from an Anderson spec"),
        }
    }

    fn ensure_batch(&mut self, d: usize, cols: usize, ws: &mut Workspace<E>) {
        let rebuild = match &self.batch {
            Some(b) => self.batch_d != d || b.max_cols() < cols,
            None => true,
        };
        if rebuild {
            if let Some(old) = self.batch.take() {
                old.release(ws);
            }
            let (m, beta) = self.params();
            self.batch = Some(AndersonBatch::new(d, m, beta, cols, ws));
            self.batch_d = d;
        }
    }
}

impl<E: Elem> FixedPointSolver<E> for AndersonSolver<E> {
    fn spec(&self) -> &SolverSpec {
        &self.spec
    }

    fn solve(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &mut [E]),
        z0: &[E],
    ) -> SolveOutcome<E> {
        let (m, beta) = self.params();
        let (z, residual, iters) = anderson_core(
            g,
            z0,
            m,
            self.spec.tol,
            self.spec.max_iters,
            beta,
            &mut sess.ws,
        );
        SolveOutcome {
            converged: residual <= self.spec.tol,
            z,
            residual,
            iters,
            n_g_evals: iters + 1,
            trace: Trace::default(),
            estimate: None,
        }
    }

    fn solve_batch(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        d: usize,
        stats: &mut [ColStats],
    ) {
        if zs.is_empty() || d == 0 {
            return;
        }
        let b = zs.len() / d;
        self.ensure_batch(d, b, &mut sess.ws);
        let batch = self.batch.as_mut().expect("batch state just ensured");
        batch.solve(g, zs, self.spec.tol, self.spec.max_iters, &mut sess.ws, stats);
    }

    fn prepare_batch(&mut self, d: usize, max_cols: usize, sess: &mut Session<E>) {
        self.ensure_batch(d, max_cols, &mut sess.ws);
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn stream_admit(&mut self, slot: usize) {
        self.batch
            .as_mut()
            .expect("prepare_batch before streaming")
            .reset_col(slot);
    }

    fn stream_swap(&mut self, a: usize, b: usize) {
        self.batch
            .as_mut()
            .expect("prepare_batch before streaming")
            .swap_state(a, b);
    }

    fn stream_advance(&mut self, sess: &mut Session<E>, zs: &mut [E], r: &[E], _d: usize) {
        self.batch
            .as_mut()
            .expect("prepare_batch before streaming")
            .advance_cols(zs, r, &mut sess.ws);
    }

    fn release(&mut self, sess: &mut Session<E>) {
        if let Some(b) = self.batch.take() {
            b.release(&mut sess.ws);
        }
        self.batch_d = 0;
    }
}

/// Broyden's method — the quasi-Newton forward whose outcome carries the
/// SHINE estimate handle.
pub struct BroydenSolver {
    spec: SolverSpec,
}

impl<E: Elem> FixedPointSolver<E> for BroydenSolver {
    fn spec(&self) -> &SolverSpec {
        &self.spec
    }

    fn solve(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &mut [E]),
        z0: &[E],
    ) -> SolveOutcome<E> {
        let opts = self.spec.fp_options();
        let res = broyden_core(g, z0, &opts, &mut sess.ws);
        SolveOutcome {
            converged: res.converged,
            z: res.z,
            residual: res.g_norm,
            iters: res.iters,
            n_g_evals: res.n_g_evals,
            trace: res.trace,
            estimate: Some(EstimateHandle::new(res.qn.into_low_rank())),
        }
    }

    /// Column-by-column solve: Broyden's per-column qN state does not batch
    /// into shared sweeps, so the block is solved sequentially (each column
    /// still bit-identical to a standalone solve). Prefer Picard/Anderson
    /// specs for wide serving batches.
    fn solve_batch(
        &mut self,
        sess: &mut Session<E>,
        g: &mut dyn FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        d: usize,
        stats: &mut [ColStats],
    ) {
        if zs.is_empty() || d == 0 {
            return;
        }
        debug_assert_eq!(zs.len() % d, 0);
        let b = zs.len() / d;
        debug_assert!(stats.len() >= b);
        let opts = self.spec.fp_options();
        for j in 0..b {
            let ids = [j];
            let mut g1 = |z: &[E], out: &mut [E]| g(z, &ids, out);
            let res = broyden_core(&mut g1, &zs[j * d..(j + 1) * d], &opts, &mut sess.ws);
            zs[j * d..(j + 1) * d].copy_from_slice(&res.z);
            stats[j] = ColStats {
                iters: res.iters,
                residual: res.g_norm,
                converged: res.converged,
            };
        }
    }
}

// ---------------------------------------------------------------------------
// Backward trait + implementations
// ---------------------------------------------------------------------------

/// Borrowed view of what a forward solve hands the backward pass: the
/// inverse-estimate operator and (when available) its low-rank factors for
/// warm-starting the refine solver. Obtained from
/// [`EstimateHandle::forward`], or assembled by hand for non-session
/// forwards (the L-BFGS bi-level path).
#[derive(Clone, Copy)]
pub struct ForwardHandle<'a, E: Elem = f64> {
    pub inv: Option<&'a dyn InvOp<E>>,
    pub low_rank: Option<&'a LowRank<E>>,
}

impl<'a, E: Elem> ForwardHandle<'a, E> {
    /// A handle with no estimate (Jacobian-free serving / testing).
    pub fn none() -> ForwardHandle<'a, E> {
        ForwardHandle {
            inv: None,
            low_rank: None,
        }
    }
}

/// What one backward strategy produced.
#[derive(Debug)]
pub struct BackwardOutcome<E: Elem = f64> {
    /// The left-solve direction w ≈ J_g⁻ᵀ dz.
    pub w: Vec<E>,
    /// Matrix–vector / VJP products spent (the paper's backward-cost unit).
    pub matvecs: usize,
    /// Whether the §3 fallback guard fired.
    pub fallback_used: bool,
}

/// A backward strategy: given the forward handle, the cotangent `dz` and a
/// VJP oracle (for the iterative strategies), produce the left-solve
/// direction `w ≈ J_g⁻ᵀ dz`. The SHINE strategies never call `vjp`; the
/// Full/Refine strategies spend one VJP per iteration.
///
/// `warm` is the caller's warm start (HOAG restarts the inversion from the
/// previous outer iteration's w, Appendix C); only [`FullBackward`] uses it.
///
/// # Examples
///
/// The SHINE hand-off end to end: a Broyden forward captures the inverse
/// estimate, and the SHINE backward turns it into the left-solve direction
/// with zero VJP calls:
///
/// ```
/// use shine::qn::InvOp;
/// use shine::solvers::session::{Backward, Session, ShineBackward, SolverSpec};
///
/// let mut sess: Session<f64> = Session::new();
/// let mut g = |z: &[f64], out: &mut [f64]| {
///     for i in 0..z.len() {
///         out[i] = z[i] - 0.3 * z[(i + 1) % z.len()] - 1.0;
///     }
/// };
/// let mut solver = SolverSpec::broyden(10).with_tol(1e-11).build::<f64>();
/// let out = solver.solve(&mut sess, &mut g, &[0.0; 6]);
/// let est = out.estimate.expect("quasi-Newton forwards capture H");
///
/// let dz = vec![1.0; 6];
/// let mut no_vjp = |_: &[f64], _: &mut [f64]| unreachable!("SHINE spends no VJPs");
/// let bw = ShineBackward.direction(&mut sess, est.forward(), &dz, &mut no_vjp, None);
/// assert_eq!(bw.matvecs, 0);
/// let mut w_ref = vec![0.0f64; 6];
/// est.low_rank().apply_t(&dz, &mut w_ref); // w = Hᵀ dz, shared from the forward
/// assert_eq!(bw.w, w_ref);
/// ```
pub trait Backward<E: Elem> {
    fn name(&self) -> &'static str;

    fn direction(
        &mut self,
        sess: &mut Session<E>,
        fwd: ForwardHandle<'_, E>,
        dz: &[E],
        vjp: &mut dyn FnMut(&[E], &mut [E]),
        warm: Option<&[E]>,
    ) -> BackwardOutcome<E>;
}

/// Config value naming a backward strategy (the CLI `--backward` /
/// `--strategy` surface). Consumers lower it to trait objects with their
/// own tolerance/memory conventions (`hypergrad::Strategy::from_spec`,
/// `deq::trainer::BackwardKind::from_spec`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BackwardSpec {
    JacobianFree,
    Shine,
    ShineFallback { ratio: f64 },
    ShineRefine { iters: usize },
    Full { tol: f64, max_iters: usize },
}

impl BackwardSpec {
    pub fn name(&self) -> &'static str {
        match self {
            BackwardSpec::JacobianFree => "jacobian-free",
            BackwardSpec::Shine => "shine",
            BackwardSpec::ShineFallback { .. } => "shine-fallback",
            BackwardSpec::ShineRefine { .. } => "shine-refine",
            BackwardSpec::Full { .. } => "full",
        }
    }

    /// Parse a CLI-style spec: `jacobian-free`, `shine`,
    /// `shine-fallback[:ratio]`, `shine-refine[:iters]`,
    /// `full[:max_iters]`.
    pub fn parse(s: &str) -> Result<BackwardSpec, String> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        match head {
            "jacobian-free" | "jf" => Ok(BackwardSpec::JacobianFree),
            "shine" => Ok(BackwardSpec::Shine),
            "shine-fallback" => {
                let ratio = match args {
                    Some(a) => a.parse::<f64>().map_err(|_| format!("bad ratio '{a}'"))?,
                    None => 1.3, // the paper's ImageNet setting (§3.2)
                };
                Ok(BackwardSpec::ShineFallback { ratio })
            }
            "shine-refine" => {
                let iters = match args {
                    Some(a) => a.parse::<usize>().map_err(|_| format!("bad iters '{a}'"))?,
                    None => 5,
                };
                Ok(BackwardSpec::ShineRefine { iters })
            }
            "full" => {
                let max_iters = match args {
                    Some(a) => a
                        .parse::<usize>()
                        .map_err(|_| format!("bad max_iters '{a}'"))?,
                    None => usize::MAX,
                };
                Ok(BackwardSpec::Full {
                    tol: 1e-8,
                    max_iters,
                })
            }
            other => Err(format!(
                "unknown backward strategy '{other}' (jacobian-free | shine | \
                 shine-fallback[:ratio] | shine-refine[:iters] | full[:max_iters])"
            )),
        }
    }
}

/// Jacobian-Free (Fung et al. 2021): w = dz. Needs no estimate and no VJPs.
pub struct JacobianFreeBackward;

impl<E: Elem> Backward<E> for JacobianFreeBackward {
    fn name(&self) -> &'static str {
        "jacobian-free"
    }
    fn direction(
        &mut self,
        _sess: &mut Session<E>,
        _fwd: ForwardHandle<'_, E>,
        dz: &[E],
        _vjp: &mut dyn FnMut(&[E], &mut [E]),
        _warm: Option<&[E]>,
    ) -> BackwardOutcome<E> {
        BackwardOutcome {
            w: dz.to_vec(),
            matvecs: 0,
            fallback_used: false,
        }
    }
}

/// SHINE: w = Hᵀ dz against the captured forward estimate — zero VJPs.
pub struct ShineBackward;

impl<E: Elem> Backward<E> for ShineBackward {
    fn name(&self) -> &'static str {
        "shine"
    }
    fn direction(
        &mut self,
        sess: &mut Session<E>,
        fwd: ForwardHandle<'_, E>,
        dz: &[E],
        _vjp: &mut dyn FnMut(&[E], &mut [E]),
        _warm: Option<&[E]>,
    ) -> BackwardOutcome<E> {
        let inv = fwd.inv.expect("SHINE requires a forward qN estimate");
        let mut w = vec![E::ZERO; dz.len()];
        inv.apply_t_into(dz, &mut w, &mut sess.ws);
        BackwardOutcome {
            w,
            matvecs: 0,
            fallback_used: false,
        }
    }
}

/// SHINE with the §3 fallback guard: revert to the Jacobian-Free direction
/// when ‖Hᵀdz‖ > ratio·‖dz‖ — a blown-up panel answer is the telltale sign
/// of a bad inversion.
pub struct FallbackBackward {
    pub ratio: f64,
}

impl<E: Elem> Backward<E> for FallbackBackward {
    fn name(&self) -> &'static str {
        "shine-fallback"
    }
    fn direction(
        &mut self,
        sess: &mut Session<E>,
        fwd: ForwardHandle<'_, E>,
        dz: &[E],
        _vjp: &mut dyn FnMut(&[E], &mut [E]),
        _warm: Option<&[E]>,
    ) -> BackwardOutcome<E> {
        let inv = fwd.inv.expect("SHINE requires a forward qN estimate");
        let mut w = vec![E::ZERO; dz.len()];
        inv.apply_t_into(dz, &mut w, &mut sess.ws);
        let fallback_used = crate::linalg::vecops::nrm2(&w)
            > self.ratio * crate::linalg::vecops::nrm2(dz);
        if fallback_used {
            w.clear();
            w.extend_from_slice(dz);
        }
        BackwardOutcome {
            w,
            matvecs: 0,
            fallback_used,
        }
    }
}

/// Where the refine solver starts from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RefineSeed {
    /// Warm start at the SHINE direction (and, when the low-rank factors
    /// are in the handle, seed the backward qN matrix with Hᵀ).
    Estimate,
    /// Warm start at the Jacobian-Free direction (Fig. 3's "JF refine").
    Identity,
}

/// k extra iterative-inversion steps warm-started per [`RefineSeed`].
/// `symmetric` problems run CG on the oracle (J = Jᵀ), others Broyden on
/// VJPs; `max_mem` is the backward qN memory cap (consumers keep their
/// historical conventions).
pub struct RefineBackward {
    pub iters: usize,
    pub tol: f64,
    pub max_mem: usize,
    pub seed: RefineSeed,
    pub symmetric: bool,
}

impl<E: Elem> Backward<E> for RefineBackward {
    fn name(&self) -> &'static str {
        match self.seed {
            RefineSeed::Estimate => "shine-refine",
            RefineSeed::Identity => "jf-refine",
        }
    }
    fn direction(
        &mut self,
        sess: &mut Session<E>,
        fwd: ForwardHandle<'_, E>,
        dz: &[E],
        vjp: &mut dyn FnMut(&[E], &mut [E]),
        _warm: Option<&[E]>,
    ) -> BackwardOutcome<E> {
        let (w0, h_init): (Vec<E>, Option<LowRank<E>>) = match self.seed {
            RefineSeed::Estimate => {
                let inv = fwd.inv.expect("refine requires a forward qN estimate");
                // O(1) panel swap on a clone: the forward estimate stays
                // intact while the backward solver grows its transposed
                // copy. The symmetric (CG) branch never seeds a qN matrix,
                // so skip the panel copy there.
                let h = if self.symmetric {
                    None
                } else {
                    fwd.low_rank.map(|lr| {
                        lr.clone()
                            .into_transposed()
                            .with_max_mem(self.max_mem, MemoryPolicy::Freeze)
                    })
                };
                (inv.apply_t_vec(dz), h)
            }
            RefineSeed::Identity => (dz.to_vec(), None),
        };
        if self.symmetric {
            let res = cg_solve(vjp, dz, Some(&w0), self.tol, self.iters);
            BackwardOutcome {
                w: res.x,
                matvecs: res.n_matvecs,
                fallback_used: false,
            }
        } else {
            let res = broyden_solve_left_ws(
                vjp,
                dz,
                Some(&w0),
                h_init,
                self.tol,
                self.iters,
                self.max_mem,
                &mut sess.ws,
            );
            BackwardOutcome {
                w: res.x,
                matvecs: res.n_matvecs,
                fallback_used: false,
            }
        }
    }
}

/// The Original / HOAG baseline: iterative inversion of `Jᵀ w = dz` to
/// tolerance (truncated by `max_iters` — the "limited backward" baseline of
/// Fig. E.1). The only strategy that honors the caller's warm start.
pub struct FullBackward {
    pub tol: f64,
    pub max_iters: usize,
    pub max_mem: usize,
    pub symmetric: bool,
}

impl<E: Elem> Backward<E> for FullBackward {
    fn name(&self) -> &'static str {
        "full"
    }
    fn direction(
        &mut self,
        sess: &mut Session<E>,
        _fwd: ForwardHandle<'_, E>,
        dz: &[E],
        vjp: &mut dyn FnMut(&[E], &mut [E]),
        warm: Option<&[E]>,
    ) -> BackwardOutcome<E> {
        if self.symmetric {
            let res = cg_solve(vjp, dz, warm, self.tol, self.max_iters);
            BackwardOutcome {
                w: res.x,
                matvecs: res.n_matvecs,
                fallback_used: false,
            }
        } else {
            let res = broyden_solve_left_ws(
                vjp,
                dz,
                warm,
                None,
                self.tol,
                self.max_iters,
                self.max_mem,
                &mut sess.ws,
            );
            BackwardOutcome {
                w: res.x,
                matvecs: res.n_matvecs,
                fallback_used: false,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::nrm2;
    use crate::util::rng::Rng;

    fn contractive(d: usize, seed: u64) -> (impl Fn(&[f64], &mut [f64]), Vec<f64>) {
        let mut rng = Rng::new(seed);
        let b = rng.normal_vec(d);
        let g = move |z: &[f64], out: &mut [f64]| {
            for i in 0..d {
                out[i] = z[i] - 0.3 * z[(i + 1) % d] - b[i];
            }
        };
        let b2 = {
            let mut rng = Rng::new(seed);
            rng.normal_vec(d)
        };
        (g, b2)
    }

    #[test]
    fn spec_parse_roundtrips() {
        assert_eq!(
            SolverSpec::parse("picard").unwrap().method,
            SolverMethod::Picard { tau: 1.0 }
        );
        assert_eq!(
            SolverSpec::parse("picard:0.5").unwrap().method,
            SolverMethod::Picard { tau: 0.5 }
        );
        assert_eq!(
            SolverSpec::parse("anderson:4,0.9").unwrap().method,
            SolverMethod::Anderson { m: 4, beta: 0.9 }
        );
        assert!(matches!(
            SolverSpec::parse("broyden:12").unwrap().method,
            SolverMethod::Broyden { memory: 12, .. }
        ));
        assert!(SolverSpec::parse("nope").is_err());
        assert_eq!(
            BackwardSpec::parse("shine-fallback:2.0").unwrap(),
            BackwardSpec::ShineFallback { ratio: 2.0 }
        );
        assert_eq!(
            BackwardSpec::parse("shine-refine").unwrap(),
            BackwardSpec::ShineRefine { iters: 5 }
        );
        assert!(BackwardSpec::parse("wat").is_err());
    }

    #[test]
    fn panel_precision_parse_round_trips() {
        for p in PanelPrecision::all() {
            assert_eq!(PanelPrecision::parse(p.name()).unwrap(), p);
        }
        assert_eq!(PanelPrecision::parse("mixed").unwrap(), PanelPrecision::Mixed);
        assert!(PanelPrecision::parse("fp8").is_err());
    }

    #[test]
    fn built_solvers_converge_and_only_broyden_captures_estimate() {
        let d = 12;
        let (g, _) = contractive(d, 3);
        let mut sess: Session<f64> = Session::new();
        for (name, spec) in [
            ("picard", SolverSpec::picard(1.0).with_tol(1e-10)),
            ("anderson", SolverSpec::anderson(4, 1.0).with_tol(1e-10)),
            ("broyden", SolverSpec::broyden(10).with_tol(1e-10)),
        ] {
            let mut solver = spec.build::<f64>();
            let mut gm = |z: &[f64], out: &mut [f64]| g(z, out);
            let out = solver.solve(&mut sess, &mut gm, &vec![0.0; d]);
            assert!(out.converged, "{name} converged, residual {}", out.residual);
            assert_eq!(
                out.estimate.is_some(),
                name == "broyden",
                "{name} estimate presence"
            );
        }
    }

    #[test]
    fn broyden_batch_is_columnwise_sequential() {
        let d = 8;
        let nb = 3;
        let mut rng = Rng::new(11);
        let bs: Vec<Vec<f64>> = (0..nb).map(|_| rng.normal_vec(d)).collect();
        let spec = SolverSpec::broyden(8).with_tol(1e-10).with_max_iters(100);
        let mut solver = spec.build::<f64>();
        let mut sess: Session<f64> = Session::new();
        let mut zs = vec![0.0; nb * d];
        let mut stats = vec![ColStats::default(); nb];
        let mut g = |block: &[f64], ids: &[usize], out: &mut [f64]| {
            for (p, &id) in ids.iter().enumerate() {
                for i in 0..d {
                    out[p * d + i] =
                        block[p * d + i] - 0.25 * block[p * d + (i + 1) % d] - bs[id][i];
                }
            }
        };
        solver.solve_batch(&mut sess, &mut g, &mut zs, d, &mut stats);
        for j in 0..nb {
            assert!(stats[j].converged, "col {j}");
            let mut g1 = |z: &[f64], out: &mut [f64]| {
                for i in 0..d {
                    out[i] = z[i] - 0.25 * z[(i + 1) % d] - bs[j][i];
                }
            };
            let mut s2 = spec.build::<f64>();
            let single = s2.solve(&mut sess, &mut g1, &vec![0.0; d]);
            assert_eq!(&zs[j * d..(j + 1) * d], &single.z[..], "col {j} bits");
            assert_eq!(stats[j].iters, single.iters, "col {j} iters");
        }
    }

    #[test]
    fn shine_backward_applies_transposed_estimate() {
        let d = 10;
        let (g, _) = contractive(d, 7);
        let mut sess: Session<f64> = Session::new();
        let mut solver = SolverSpec::broyden(10).with_tol(1e-11).build::<f64>();
        let mut gm = |z: &[f64], out: &mut [f64]| g(z, out);
        let out = solver.solve(&mut sess, &mut gm, &vec![0.0; d]);
        let est = out.estimate.expect("broyden estimate");
        let mut rng = Rng::new(5);
        let dz = rng.normal_vec(d);
        let mut novjp = |_: &[f64], _: &mut [f64]| panic!("SHINE must not call vjp");
        let bw = ShineBackward
            .direction(&mut sess, est.forward(), &dz, &mut novjp, None);
        assert_eq!(bw.matvecs, 0);
        assert_eq!(bw.w, est.low_rank().apply_t_vec(&dz));
        // Jacobian-free ignores the estimate entirely.
        let jf =
            JacobianFreeBackward.direction(&mut sess, ForwardHandle::none(), &dz, &mut novjp, None);
        assert_eq!(jf.w, dz);
    }

    #[test]
    fn fallback_guard_trips_on_blown_estimate() {
        let d = 6;
        let mut sess: Session<f64> = Session::new();
        // H = I + 100·e0 e0ᵀ blows up any cotangent with mass on coord 0.
        let mut lr = LowRank::identity(d, 2, MemoryPolicy::Evict);
        let mut e0 = vec![0.0; d];
        e0[0] = 1.0;
        let u: Vec<f64> = e0.iter().map(|x| 100.0 * x).collect();
        lr.push(&u, &e0);
        let mut dz = vec![0.0; d];
        dz[0] = 1.0;
        let fwd = ForwardHandle {
            inv: Some(&lr),
            low_rank: Some(&lr),
        };
        let mut novjp = |_: &[f64], _: &mut [f64]| {};
        let mut guard = FallbackBackward { ratio: 1.5 };
        let out = guard.direction(&mut sess, fwd, &dz, &mut novjp, None);
        assert!(out.fallback_used);
        assert_eq!(out.w, dz);
        // An orthogonal cotangent passes through untouched.
        let mut dz2 = vec![0.0; d];
        dz2[1] = 1.0;
        let out2 = guard.direction(&mut sess, fwd, &dz2, &mut novjp, None);
        assert!(!out2.fallback_used);
        assert!(nrm2(&out2.w) > 0.0);
    }

    #[test]
    fn anderson_solver_batch_state_persists_and_releases() {
        let d = 9;
        let nb = 3;
        let spec = SolverSpec::anderson(3, 1.0).with_tol(1e-9).with_max_iters(150);
        let mut solver = spec.build::<f64>();
        let mut sess: Session<f64> = Session::new();
        solver.prepare_batch(d, nb, &mut sess);
        let mut rng = Rng::new(77);
        let bs: Vec<Vec<f64>> = (0..nb).map(|_| rng.normal_vec(d)).collect();
        let mut g = |block: &[f64], ids: &[usize], out: &mut [f64]| {
            for (p, &id) in ids.iter().enumerate() {
                for i in 0..d {
                    out[p * d + i] =
                        block[p * d + i] - 0.3 * block[p * d + (i + 1) % d] - bs[id][i];
                }
            }
        };
        let mut stats = vec![ColStats::default(); nb];
        let mut zs1 = vec![0.0; nb * d];
        solver.solve_batch(&mut sess, &mut g, &mut zs1, d, &mut stats);
        let iters1: Vec<usize> = stats.iter().map(|s| s.iters).collect();
        // Second batch through the SAME solver reproduces the first.
        let mut zs2 = vec![0.0; nb * d];
        solver.solve_batch(&mut sess, &mut g, &mut zs2, d, &mut stats);
        assert_eq!(zs1, zs2);
        assert_eq!(iters1, stats.iter().map(|s| s.iters).collect::<Vec<_>>());
        solver.release(&mut sess);
    }
}
