//! LBFGS minimizer with strong-Wolfe line search and OPA extra updates —
//! the forward solver of the bi-level / hyperparameter-optimization
//! experiments (Fig. 1, Fig. 2, Fig. E.1, Fig. E.2).
//!
//! With `opa: Some(..)`, this is Algorithm LBFGS from Appendix A: every `M`
//! regular updates the qN matrix receives an additional update in the
//! direction `e_n = t_n · H ∂g_θ/∂θ|_{z_n}` (eq. 5). Theorem 3 then gives
//! q-superlinear convergence of the iterates *and* convergence of the SHINE
//! direction to the true hypergradient.

use crate::linalg::vecops::{axpy, dot, nrm2, scale, sub};
use crate::qn::lbfgs::{LbfgsInverse, OpaConfig};
use crate::qn::workspace::Workspace;
use crate::qn::InvOp;
use crate::solvers::line_search::wolfe;
use crate::solvers::Trace;
use crate::util::timer::Stopwatch;

/// Objective with value and gradient (the inner problem r_θ).
pub trait Objective {
    fn dim(&self) -> usize;
    fn value_grad(&self, z: &[f64]) -> (f64, Vec<f64>);
}

/// Blanket impl for closures.
impl<F> Objective for (usize, F)
where
    F: Fn(&[f64]) -> (f64, Vec<f64>),
{
    fn dim(&self) -> usize {
        self.0
    }
    fn value_grad(&self, z: &[f64]) -> (f64, Vec<f64>) {
        (self.1)(z)
    }
}

#[derive(Clone, Debug)]
pub struct MinimizeOptions {
    /// Stop when ‖∇r(z)‖ ≤ tol.
    pub tol: f64,
    pub max_iters: usize,
    /// L-BFGS memory (paper: 10 for HOAG, 30 for SHINE/JF, 60 for OPA).
    pub memory: usize,
    /// H₀ scaling: true = Barzilai–Borwein γ (classical L-BFGS); false = I
    /// (the paper's theoretical setting).
    pub scale_gamma: bool,
    pub wolfe_c1: f64,
    pub wolfe_c2: f64,
}

impl Default for MinimizeOptions {
    fn default() -> Self {
        MinimizeOptions {
            tol: 1e-8,
            max_iters: 500,
            memory: 30,
            scale_gamma: true,
            wolfe_c1: 1e-4,
            wolfe_c2: 0.9,
        }
    }
}

/// OPA hooks: the direction field ∂g_θ/∂θ|_z (a d-vector for the scalar-θ
/// problems of §2.3) and the schedule (M, t₀).
pub struct OpaHooks<'a> {
    pub dg_dtheta: &'a dyn Fn(&[f64]) -> Vec<f64>,
    pub config: OpaConfig,
}

#[derive(Debug)]
pub struct MinimizeResult {
    pub z: Vec<f64>,
    pub value: f64,
    pub grad_norm: f64,
    pub iters: usize,
    pub converged: bool,
    /// The inverse-Hessian estimate — shared with the backward pass by SHINE.
    pub qn: LbfgsInverse,
    pub trace: Trace,
    pub n_evals: usize,
}

/// Minimize `obj` from `z0`.
pub fn lbfgs_minimize(
    obj: &dyn Objective,
    z0: &[f64],
    opts: &MinimizeOptions,
    mut opa: Option<OpaHooks>,
    // Optional warm-started qN state (outer-loop warm restarts reuse it).
    qn_init: Option<LbfgsInverse>,
) -> MinimizeResult {
    let d = obj.dim();
    let sw = Stopwatch::start();
    let mut ws = Workspace::new();
    let mut qn = qn_init.unwrap_or_else(|| LbfgsInverse::new(d, opts.memory));
    let mut z = z0.to_vec();
    let (mut f, mut grad) = obj.value_grad(&z);
    let mut n_evals = 1usize;
    let mut trace = Trace::with_capacity(opts.max_iters.saturating_add(1).min(1 << 16));
    let mut g_norm = nrm2(&grad);
    trace.push(g_norm, sw.elapsed());
    // Preallocated loop state: the two-loop recursion and the OPA extra
    // updates draw any remaining scratch from the workspace, so the solver
    // itself adds no per-iteration allocations on top of the Objective's.
    let mut p = vec![0.0; d];
    let mut e = vec![0.0; d];
    let mut z_pert = vec![0.0; d];
    let mut y_hat = vec![0.0; d];
    let mut s = vec![0.0; d];
    let mut y = vec![0.0; d];
    let mut iters = 0;
    let mut prev_step_norm = opa.as_ref().map(|o| o.config.t0).unwrap_or(1.0);
    let mut regular_updates = 0usize;

    while g_norm > opts.tol && iters < opts.max_iters {
        // --- OPA extra update (before computing the step, as in Alg. LBFGS)
        if let Some(hooks) = opa.as_mut() {
            if regular_updates % hooks.config.freq.max(1) == 0 {
                let dgdt = (hooks.dg_dtheta)(&z);
                qn.apply_into(&dgdt, &mut e, &mut ws);
                let t_n = prev_step_norm.min(1.0).max(1e-12);
                scale(t_n / nrm2(&e).max(1e-300), &mut e);
                // ŷ = ∇r(z+e) − ∇r(z)
                for i in 0..d {
                    z_pert[i] = z[i] + e[i];
                }
                let (_, g_pert) = obj.value_grad(&z_pert);
                n_evals += 1;
                sub(&g_pert, &grad, &mut y_hat);
                qn.update_extra(&e, &y_hat);
            }
        }

        // --- LBFGS direction
        if opts.scale_gamma && qn.rank() == 0 {
            qn.gamma = 1.0;
        }
        qn.apply_into(&grad, &mut p, &mut ws);
        for v in p.iter_mut() {
            *v = -*v;
        }
        let mut dphi0 = dot(&grad, &p);
        if dphi0 >= 0.0 {
            // Defensive restart: direction is not a descent direction.
            for (pi, gi) in p.iter_mut().zip(&grad) {
                *pi = -*gi;
            }
            dphi0 = -dot(&grad, &grad);
        }

        // --- Strong Wolfe line search
        let z_snapshot = z.clone();
        let mut cache: Option<(f64, f64, Vec<f64>, Vec<f64>)> = None;
        let alpha = {
            let obj_ref = &*obj;
            let p_ref = &p;
            let cache_ref = &mut cache;
            let n_evals_ref = &mut n_evals;
            wolfe(
                f,
                dphi0,
                move |a| {
                    let mut zt = z_snapshot.clone();
                    axpy(a, p_ref, &mut zt);
                    let (ft, gt) = obj_ref.value_grad(&zt);
                    *n_evals_ref += 1;
                    let dphi = dot(&gt, p_ref);
                    *cache_ref = Some((ft, a, zt, gt));
                    (ft, dphi)
                },
                opts.wolfe_c1,
                opts.wolfe_c2,
                40,
            )
        };
        let alpha = match alpha {
            Some(a) => a,
            None => break, // line search failed: stationary to precision
        };
        // Recompute at the accepted α unless the cache already holds it.
        let (f_new, z_new, g_new) = match cache {
            Some((fc, ac, zc, gc)) if (ac - alpha).abs() < 1e-15 => (fc, zc, gc),
            _ => {
                let mut zt = z.clone();
                axpy(alpha, &p, &mut zt);
                let (ft, gt) = obj.value_grad(&zt);
                n_evals += 1;
                (ft, zt, gt)
            }
        };
        sub(&z_new, &z, &mut s);
        sub(&g_new, &grad, &mut y);
        prev_step_norm = nrm2(&s);
        if prev_step_norm == 0.0 || (f_new == f && nrm2(&y) == 0.0) {
            // Floating-point stall: no representable progress remains.
            break;
        }
        if qn.update(&s, &y) {
            regular_updates += 1;
        }
        if opts.scale_gamma {
            let yy = dot(&y, &y);
            if yy > 0.0 {
                qn.gamma = dot(&s, &y) / yy;
            }
        }
        z = z_new;
        f = f_new;
        grad = g_new;
        g_norm = nrm2(&grad);
        iters += 1;
        trace.push(g_norm, sw.elapsed());
    }
    MinimizeResult {
        converged: g_norm <= opts.tol,
        z,
        value: f,
        grad_norm: g_norm,
        iters,
        qn,
        trace,
        n_evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    fn quadratic_obj(a: DMat, b: Vec<f64>) -> impl Fn(&[f64]) -> (f64, Vec<f64>) {
        move |z: &[f64]| {
            let n = z.len();
            let mut az = vec![0.0; n];
            a.matvec(z, &mut az);
            let f = 0.5 * dot(z, &az) - dot(&b, z);
            let grad: Vec<f64> = (0..n).map(|i| az[i] - b[i]).collect();
            (f, grad)
        }
    }

    #[test]
    fn minimizes_strongly_convex_quadratic() {
        prop::check("lbfgs-quadratic", 10, |rng| {
            let n = 4 + rng.below(16);
            let a = DMat::random_spd(n, 0.5, 20.0, rng);
            let z_star = rng.normal_vec(n);
            let mut b = vec![0.0; n];
            a.matvec(&z_star, &mut b);
            let obj = (n, quadratic_obj(a, b));
            let res = lbfgs_minimize(&obj, &vec![0.0; n], &MinimizeOptions::default(), None, None);
            prop::ensure(res.converged, &format!("converged |g|={}", res.grad_norm))?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-4, "argmin")
        });
    }

    #[test]
    fn monotone_decrease_on_convex() {
        // Wolfe guarantees monotone decrease of f; we check ‖∇f‖ roughly
        // decays over the run (trace is on grad norm).
        let mut rng = crate::util::rng::Rng::new(7);
        let n = 10;
        let a = DMat::random_spd(n, 1.0, 10.0, &mut rng);
        let z_star = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        a.matvec(&z_star, &mut b);
        let obj = (n, quadratic_obj(a, b));
        let res = lbfgs_minimize(&obj, &vec![0.0; n], &MinimizeOptions::default(), None, None);
        let first = res.trace.residuals[0];
        let last = *res.trace.residuals.last().unwrap();
        assert!(last < first * 1e-4, "first={first} last={last}");
    }

    #[test]
    fn opa_extra_updates_applied() {
        let mut rng = crate::util::rng::Rng::new(3);
        let n = 12;
        let a = DMat::random_spd(n, 0.5, 8.0, &mut rng);
        let z_star = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        a.matvec(&z_star, &mut b);
        let obj = (n, quadratic_obj(a, b));
        // Arbitrary smooth direction field for ∂g/∂θ.
        let dg = |z: &[f64]| z.iter().map(|&x| x + 1.0).collect::<Vec<f64>>();
        let opa = OpaHooks {
            dg_dtheta: &dg,
            config: OpaConfig { freq: 2, t0: 1.0 },
        };
        let res = lbfgs_minimize(
            &obj,
            &vec![0.0; n],
            &MinimizeOptions::default(),
            Some(opa),
            None,
        );
        assert!(res.converged);
        assert!(res.qn.n_extra > 0, "extra updates must fire");
    }

    #[test]
    fn rosenbrock_2d() {
        // Non-convex sanity check: LBFGS + Wolfe reaches the global minimum.
        let obj = (2usize, |z: &[f64]| {
            let (x, y) = (z[0], z[1]);
            let f = (1.0 - x).powi(2) + 100.0 * (y - x * x).powi(2);
            let g = vec![
                -2.0 * (1.0 - x) - 400.0 * x * (y - x * x),
                200.0 * (y - x * x),
            ];
            (f, g)
        });
        let opts = MinimizeOptions {
            max_iters: 2000,
            tol: 1e-8,
            ..Default::default()
        };
        let res = lbfgs_minimize(&obj, &[-1.2, 1.0], &opts, None, None);
        assert!(res.converged, "grad_norm={}", res.grad_norm);
        assert!((res.z[0] - 1.0).abs() < 1e-5 && (res.z[1] - 1.0).abs() < 1e-5);
    }
}
