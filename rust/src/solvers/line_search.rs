//! Line searches.
//!
//! * [`wolfe`] — strong-Wolfe bracketing search (Nocedal & Wright Alg. 3.5/3.6,
//!   simplified zoom). Assumption 5.3/5.4 of the paper requires a line search
//!   that eventually accepts unit steps near the solution — strong Wolfe with
//!   α₀ = 1 has that property, enabling Theorem 3's q-superlinear rate.
//! * [`backtrack_residual`] — derivative-free residual-decrease backtracking
//!   (Li & Fukushima style) used by the Broyden root solver when enabled.

/// Objective interface for line search: φ(α) = f(z + α p) and φ'(α).
pub struct LsEval<'a> {
    /// Returns (value, directional derivative) at the given α.
    pub eval: &'a mut dyn FnMut(f64) -> (f64, f64),
}

/// Strong Wolfe line search. Returns accepted step α (> 0) or None.
///
/// c1, c2: Armijo / curvature constants (defaults 1e-4, 0.9 for quasi-Newton).
pub fn wolfe(
    phi0: f64,
    dphi0: f64,
    mut eval: impl FnMut(f64) -> (f64, f64),
    c1: f64,
    c2: f64,
    max_iters: usize,
) -> Option<f64> {
    debug_assert!(dphi0 < 0.0, "search direction must be a descent direction");
    let mut alpha_prev = 0.0;
    let mut phi_prev = phi0;
    let mut alpha = 1.0;
    let amax = 1e4;
    for i in 0..max_iters {
        let (phi, dphi) = eval(alpha);
        if phi > phi0 + c1 * alpha * dphi0 || (i > 0 && phi >= phi_prev) {
            return zoom(
                alpha_prev, alpha, phi_prev, phi0, dphi0, &mut eval, c1, c2, 25,
            );
        }
        if dphi.abs() <= -c2 * dphi0 {
            return Some(alpha);
        }
        if dphi >= 0.0 {
            return zoom(alpha, alpha_prev, phi, phi0, dphi0, &mut eval, c1, c2, 25);
        }
        alpha_prev = alpha;
        phi_prev = phi;
        alpha = (2.0 * alpha).min(amax);
        if alpha >= amax {
            return Some(amax);
        }
    }
    None
}

#[allow(clippy::too_many_arguments)]
fn zoom(
    mut lo: f64,
    mut hi: f64,
    mut phi_lo: f64,
    phi0: f64,
    dphi0: f64,
    eval: &mut impl FnMut(f64) -> (f64, f64),
    c1: f64,
    c2: f64,
    max_iters: usize,
) -> Option<f64> {
    for _ in 0..max_iters {
        let alpha = 0.5 * (lo + hi);
        let (phi, dphi) = eval(alpha);
        if phi > phi0 + c1 * alpha * dphi0 || phi >= phi_lo {
            hi = alpha;
        } else {
            if dphi.abs() <= -c2 * dphi0 {
                return Some(alpha);
            }
            if dphi * (hi - lo) >= 0.0 {
                hi = lo;
            }
            lo = alpha;
            phi_lo = phi;
        }
        if (hi - lo).abs() < 1e-14 {
            return Some(alpha.max(1e-14));
        }
    }
    // Bracketing stalled (flat landscape / numerical noise): return the best
    // Armijo-satisfying midpoint rather than failing the whole solve.
    let alpha = 0.5 * (lo + hi);
    if alpha > 0.0 {
        Some(alpha)
    } else {
        None
    }
}

/// Derivative-free backtracking on the residual norm for root solvers:
/// accept the first α in {1, β, β², ...} with ‖g(z+αp)‖ ≤ (1 − σα)‖g(z)‖,
/// falling back to the smallest trial α (non-monotone tolerance) if none
/// qualifies — Broyden iterations are not monotone in general and hard
/// failure would stall DEQ forward passes.
pub fn backtrack_residual(
    g_norm: f64,
    mut res_at: impl FnMut(f64) -> f64,
    beta: f64,
    sigma: f64,
    max_backtracks: usize,
) -> f64 {
    let mut alpha = 1.0;
    let mut best_alpha = 1.0;
    let mut best_res = f64::INFINITY;
    for _ in 0..max_backtracks {
        let r = res_at(alpha);
        if r <= (1.0 - sigma * alpha) * g_norm {
            return alpha;
        }
        if r < best_res {
            best_res = r;
            best_alpha = alpha;
        }
        alpha *= beta;
    }
    best_alpha
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wolfe_on_quadratic() {
        // φ(α) = (α−2)², φ0 = 4, dphi0 = −4. Exact minimizer α = 2.
        let alpha = wolfe(
            4.0,
            -4.0,
            |a| ((a - 2.0) * (a - 2.0), 2.0 * (a - 2.0)),
            1e-4,
            0.9,
            30,
        )
        .unwrap();
        // Strong Wolfe accepts near the minimizer.
        let (phi, dphi) = ((alpha - 2.0f64).powi(2), 2.0 * (alpha - 2.0));
        assert!(phi <= 4.0 + 1e-4 * alpha * -4.0);
        assert!(dphi.abs() <= 0.9 * 4.0);
    }

    #[test]
    fn wolfe_accepts_unit_step_when_good() {
        // φ(α) = α² − α: φ(1) = 0 < φ(0) = 0? No: pick φ = (α−1)²−1 → unit
        // step is the exact minimizer.
        let alpha = wolfe(
            0.0,
            -2.0,
            |a| ((a - 1.0) * (a - 1.0) - 1.0, 2.0 * (a - 1.0)),
            1e-4,
            0.9,
            30,
        )
        .unwrap();
        assert!((alpha - 1.0).abs() < 1e-9, "alpha={alpha}");
    }

    #[test]
    fn backtrack_reduces_residual() {
        // Residual model: r(α) = |1 − α|·10 + α²  (decreasing then rising).
        let alpha = backtrack_residual(10.0, |a| (1.0 - a).abs() * 10.0 + a * a, 0.5, 1e-4, 10);
        assert!(alpha > 0.0 && alpha <= 1.0);
        let r = (1.0 - alpha).abs() * 10.0 + alpha * alpha;
        assert!(r < 10.0);
    }

    #[test]
    fn backtrack_falls_back_to_best() {
        // Residual never satisfies the decrease test; should return the best
        // trial rather than 0.
        let alpha = backtrack_residual(1.0, |a| 1.0 + a, 0.5, 0.5, 5);
        assert!(alpha > 0.0);
    }
}
