//! Forward root solve driven by the **Adjoint Broyden** method with optional
//! OPA extra updates (§2.3, Theorem 4) — the variant evaluated in
//! Table E.3 / Fig. E.3.
//!
//! Each regular iteration performs one VJP (σᵀJ) in addition to the function
//! evaluation — the extra cost the paper points out for this method ("we
//! have to store the activations of g_θ(z) ... but also perform the
//! vector-Jacobian product in addition to the function evaluation").

use crate::linalg::vecops::{axpy, nrm2};
use crate::qn::adjoint_broyden::AdjointBroyden;
use crate::qn::{InvOp, MemoryPolicy};
use crate::solvers::Trace;
use crate::util::timer::Stopwatch;

/// Direction used for the regular adjoint-Broyden updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SigmaChoice {
    /// σ_n = s_n (the step) — the tangent flavour.
    Step,
    /// σ_n = g(z_{n+1}) (the new residual) — Schlenkrich's adjoint residual.
    Residual,
}

#[derive(Clone, Debug)]
pub struct AdjointFpOptions {
    pub tol: f64,
    pub max_iters: usize,
    pub memory: usize,
    pub sigma: SigmaChoice,
    /// OPA: apply an extra update in the direction v_n = B⁻ᵀ ∇L(z_n)
    /// (eq. 8) every `freq` iterations.
    pub opa_freq: Option<usize>,
}

impl Default for AdjointFpOptions {
    fn default() -> Self {
        AdjointFpOptions {
            tol: 1e-8,
            max_iters: 200,
            memory: 60,
            sigma: SigmaChoice::Step,
            opa_freq: None,
        }
    }
}

#[derive(Debug)]
pub struct AdjointFpResult {
    pub z: Vec<f64>,
    pub g_norm: f64,
    pub iters: usize,
    pub converged: bool,
    pub qn: AdjointBroyden,
    pub trace: Trace,
    pub n_vjps: usize,
}

/// Solve g(z) = 0 with Adjoint Broyden.
///
/// * `g` — residual evaluation.
/// * `vjp` — `(z, σ) ↦ σᵀ J_g(z)` (auto-diff VJP in the DEQ case).
/// * `outer_grad` — `z ↦ ∇_z L(z)` for the OPA direction; required when
///   `opts.opa_freq` is set.
pub fn adjoint_broyden_solve(
    mut g: impl FnMut(&[f64]) -> Vec<f64>,
    mut vjp: impl FnMut(&[f64], &[f64]) -> Vec<f64>,
    mut outer_grad: Option<&mut dyn FnMut(&[f64]) -> Vec<f64>>,
    z0: &[f64],
    opts: &AdjointFpOptions,
) -> AdjointFpResult {
    let d = z0.len();
    let sw = Stopwatch::start();
    let mut qn = AdjointBroyden::new(d, opts.memory, MemoryPolicy::Freeze);
    let mut z = z0.to_vec();
    let mut gz = g(&z);
    let mut g_norm = nrm2(&gz);
    let mut trace = Trace::default();
    trace.push(g_norm, sw.elapsed());
    let mut p = vec![0.0; d];
    let mut iters = 0;
    let mut n_vjps = 0;
    while g_norm > opts.tol && iters < opts.max_iters {
        qn.direction(&gz, &mut p);
        let mut z_new = z.clone();
        axpy(1.0, &p, &mut z_new);
        let g_new = g(&z_new);
        // Regular adjoint update at z_{n+1}.
        let sigma: Vec<f64> = match opts.sigma {
            SigmaChoice::Step => z_new.iter().zip(&z).map(|(a, b)| a - b).collect(),
            SigmaChoice::Residual => g_new.clone(),
        };
        if nrm2(&sigma) > 0.0 {
            let sigma_j = vjp(&z_new, &sigma);
            n_vjps += 1;
            qn.update(&sigma, &sigma_j);
        }
        // OPA extra update (eq. 7/8): σ = B⁻ᵀ ∇L(z_{n+1}).
        if let (Some(freq), Some(og)) = (opts.opa_freq, outer_grad.as_deref_mut()) {
            if freq > 0 && iters % freq == 0 {
                let grad_l = og(&z_new);
                let v = qn.apply_t_vec(&grad_l);
                if nrm2(&v) > 0.0 {
                    let v_j = vjp(&z_new, &v);
                    n_vjps += 1;
                    qn.update(&v, &v_j);
                }
            }
        }
        z = z_new;
        gz = g_new;
        g_norm = nrm2(&gz);
        iters += 1;
        trace.push(g_norm, sw.elapsed());
    }
    AdjointFpResult {
        converged: g_norm <= opts.tol,
        z,
        g_norm,
        iters,
        qn,
        trace,
        n_vjps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    /// g(z) = z − (Az + b): J = I − A constant, easy VJP.
    fn linear_case(
        rng: &mut crate::util::rng::Rng,
        n: usize,
    ) -> (DMat, Vec<f64>, Vec<f64>) {
        let a = DMat::randn(n, n, 0.3 / (n as f64).sqrt(), rng);
        let b = rng.normal_vec(n);
        let mut ia = DMat::eye(n);
        for i in 0..n {
            for j in 0..n {
                ia[(i, j)] -= a[(i, j)];
            }
        }
        let z_star = crate::linalg::lu::Lu::factor(&ia).unwrap().solve(&b);
        (a, b, z_star)
    }

    #[test]
    fn converges_without_opa() {
        prop::check("adjfp-plain", 8, |rng| {
            let n = 8 + rng.below(10);
            let (a, b, z_star) = linear_case(rng, n);
            let res = adjoint_broyden_solve(
                |z| {
                    let mut az = vec![0.0; n];
                    a.matvec(z, &mut az);
                    (0..n).map(|i| z[i] - az[i] - b[i]).collect()
                },
                |_z, sigma| {
                    // σᵀ(I − A) = σ − Aᵀσ
                    let mut at_s = vec![0.0; n];
                    a.matvec_t(sigma, &mut at_s);
                    (0..n).map(|i| sigma[i] - at_s[i]).collect()
                },
                None,
                &vec![0.0; n],
                &AdjointFpOptions {
                    max_iters: 30 * n,
                    memory: 40 * n,
                    ..Default::default()
                },
            );
            prop::ensure(res.converged, &format!("|g|={}", res.g_norm))?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn opa_improves_left_inversion() {
        // With OPA updates in the direction v = B⁻ᵀ∇L, the SHINE estimate
        // ∇Lᵀ B⁻¹ should be closer to ∇Lᵀ J⁻¹ than without OPA (Fig. E.3).
        prop::check("adjfp-opa-quality", 5, |rng| {
            let n = 12;
            let (a, b, _z_star) = linear_case(rng, n);
            let grad_l = rng.normal_vec(n);
            let mut ia = DMat::eye(n);
            for i in 0..n {
                for j in 0..n {
                    ia[(i, j)] -= a[(i, j)];
                }
            }
            let exact = crate::linalg::lu::Lu::factor(&ia).unwrap().solve_t(&grad_l);
            let run = |opa: Option<usize>| {
                let gl = grad_l.clone();
                let mut og = move |_z: &[f64]| gl.clone();
                let res = adjoint_broyden_solve(
                    |z| {
                        let mut az = vec![0.0; n];
                        a.matvec(z, &mut az);
                        (0..n).map(|i| z[i] - az[i] - b[i]).collect()
                    },
                    |_z, sigma| {
                        let mut at_s = vec![0.0; n];
                        a.matvec_t(sigma, &mut at_s);
                        (0..n).map(|i| sigma[i] - at_s[i]).collect()
                    },
                    Some(&mut og),
                    &vec![0.0; n],
                    &AdjointFpOptions {
                        max_iters: 25,
                        memory: 400,
                        opa_freq: opa,
                        ..Default::default()
                    },
                );
                let approx = res.qn.apply_t_vec(&grad_l);
                crate::linalg::vecops::dist2(&approx, &exact)
            };
            let err_opa = run(Some(1));
            let err_plain = run(None);
            prop::ensure(
                err_opa <= err_plain * 1.2 + 1e-9,
                &format!("opa {err_opa:.3e} vs plain {err_plain:.3e}"),
            )
        });
    }
}
