//! Forward root solve driven by the **Adjoint Broyden** method with optional
//! OPA extra updates (§2.3, Theorem 4) — the variant evaluated in
//! Table E.3 / Fig. E.3.
//!
//! Each regular iteration performs one VJP (σᵀJ) in addition to the function
//! evaluation — the extra cost the paper points out for this method ("we
//! have to store the activations of g_θ(z) ... but also perform the
//! vector-Jacobian product in addition to the function evaluation").
//!
//! Generic over the storage precision [`Elem`] like the rest of the solver
//! stack: the DEQ trainer instantiates it at `f32` so residuals, VJPs and
//! the qN panels all stay in artifact precision with no boundary casts.
//!
//! Residuals and VJPs use the write-into convention (`g(z, out)`,
//! `vjp(z, σ, out)`); the loop state is preallocated and the qN updates draw
//! scratch from a [`Workspace`], mirroring
//! [`crate::solvers::fixed_point::broyden_solve_ws`].

use crate::linalg::vecops::{add, nrm2, sub, Elem};
use crate::qn::adjoint_broyden::AdjointBroyden;
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, MemoryPolicy};
use crate::solvers::Trace;
use crate::util::timer::Stopwatch;

/// Direction used for the regular adjoint-Broyden updates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SigmaChoice {
    /// σ_n = s_n (the step) — the tangent flavour.
    Step,
    /// σ_n = g(z_{n+1}) (the new residual) — Schlenkrich's adjoint residual.
    Residual,
}

#[derive(Clone, Debug)]
pub struct AdjointFpOptions {
    pub tol: f64,
    pub max_iters: usize,
    pub memory: usize,
    pub sigma: SigmaChoice,
    /// OPA: apply an extra update in the direction v_n = B⁻ᵀ ∇L(z_n)
    /// (eq. 8) every `freq` iterations.
    pub opa_freq: Option<usize>,
}

impl Default for AdjointFpOptions {
    fn default() -> Self {
        AdjointFpOptions {
            tol: 1e-8,
            max_iters: 200,
            memory: 60,
            sigma: SigmaChoice::Step,
            opa_freq: None,
        }
    }
}

#[derive(Debug)]
pub struct AdjointFpResult<E: Elem = f64> {
    pub z: Vec<E>,
    pub g_norm: f64,
    pub iters: usize,
    pub converged: bool,
    pub qn: AdjointBroyden<E>,
    pub trace: Trace,
    pub n_vjps: usize,
}

/// Solve g(z) = 0 with Adjoint Broyden (owns its workspace).
///
/// * `g` — residual evaluation, `g(z, out)`.
/// * `vjp` — `(z, σ, out) ↦ out = σᵀ J_g(z)` (auto-diff VJP in the DEQ case).
/// * `outer_grad` — `(z, out) ↦ out = ∇_z L(z)` for the OPA direction;
///   required when `opts.opa_freq` is set.
pub fn adjoint_broyden_solve<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    vjp: impl FnMut(&[E], &[E], &mut [E]),
    outer_grad: Option<&mut dyn FnMut(&[E], &mut [E])>,
    z0: &[E],
    opts: &AdjointFpOptions,
) -> AdjointFpResult<E> {
    let mut ws = Workspace::new();
    adjoint_broyden_solve_ws(g, vjp, outer_grad, z0, opts, &mut ws)
}

/// [`adjoint_broyden_solve`] with a caller-provided scratch arena.
pub fn adjoint_broyden_solve_ws<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    mut vjp: impl FnMut(&[E], &[E], &mut [E]),
    mut outer_grad: Option<&mut dyn FnMut(&[E], &mut [E])>,
    z0: &[E],
    opts: &AdjointFpOptions,
    ws: &mut Workspace<E>,
) -> AdjointFpResult<E> {
    let d = z0.len();
    let sw = Stopwatch::start();
    let mut qn = AdjointBroyden::new(d, opts.memory, MemoryPolicy::Freeze);
    let mut z = z0.to_vec();
    let mut gz = vec![E::ZERO; d];
    g(&z, &mut gz);
    let mut g_norm = nrm2(&gz);
    let mut trace = Trace::with_capacity(opts.max_iters.saturating_add(1).min(1 << 16));
    trace.push(g_norm, sw.elapsed());
    let mut p = vec![E::ZERO; d];
    let mut z_new = vec![E::ZERO; d];
    let mut g_new = vec![E::ZERO; d];
    let mut sigma = vec![E::ZERO; d];
    let mut sigma_j = vec![E::ZERO; d];
    let mut grad_l = vec![E::ZERO; d];
    let mut v_dir = vec![E::ZERO; d];
    let mut iters = 0;
    let mut n_vjps = 0;
    while g_norm > opts.tol && iters < opts.max_iters {
        qn.direction_ws(&gz, &mut p, ws);
        add(&z, &p, &mut z_new);
        g(&z_new, &mut g_new);
        // Regular adjoint update at z_{n+1}.
        match opts.sigma {
            SigmaChoice::Step => sub(&z_new, &z, &mut sigma),
            SigmaChoice::Residual => sigma.copy_from_slice(&g_new),
        }
        if nrm2(&sigma) > 0.0 {
            vjp(&z_new, &sigma, &mut sigma_j);
            n_vjps += 1;
            qn.update_ws(&sigma, &sigma_j, ws);
        }
        // OPA extra update (eq. 7/8): σ = B⁻ᵀ ∇L(z_{n+1}).
        if let (Some(freq), Some(og)) = (opts.opa_freq, outer_grad.as_deref_mut()) {
            if freq > 0 && iters % freq == 0 {
                og(&z_new, &mut grad_l);
                qn.apply_t_into(&grad_l, &mut v_dir, ws);
                if nrm2(&v_dir) > 0.0 {
                    vjp(&z_new, &v_dir, &mut sigma_j);
                    n_vjps += 1;
                    qn.update_ws(&v_dir, &sigma_j, ws);
                }
            }
        }
        std::mem::swap(&mut z, &mut z_new);
        std::mem::swap(&mut gz, &mut g_new);
        g_norm = nrm2(&gz);
        iters += 1;
        trace.push(g_norm, sw.elapsed());
    }
    AdjointFpResult {
        converged: g_norm <= opts.tol,
        z,
        g_norm,
        iters,
        qn,
        trace,
        n_vjps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::util::prop;

    /// g(z) = z − (Az + b): J = I − A constant, easy VJP.
    fn linear_case(rng: &mut crate::util::rng::Rng, n: usize) -> (DMat, Vec<f64>, Vec<f64>) {
        let a = DMat::randn(n, n, 0.3 / (n as f64).sqrt(), rng);
        let b = rng.normal_vec(n);
        let mut ia = DMat::eye(n);
        for i in 0..n {
            for j in 0..n {
                ia[(i, j)] -= a[(i, j)];
            }
        }
        let z_star = crate::linalg::lu::Lu::factor(&ia).unwrap().solve(&b);
        (a, b, z_star)
    }

    #[test]
    fn converges_without_opa() {
        prop::check("adjfp-plain", 8, |rng| {
            let n = 8 + rng.below(10);
            let (a, b, z_star) = linear_case(rng, n);
            let res = adjoint_broyden_solve(
                |z: &[f64], out: &mut [f64]| {
                    a.matvec(z, out); // out = Az
                    for i in 0..n {
                        out[i] = z[i] - out[i] - b[i];
                    }
                },
                |_z: &[f64], sigma: &[f64], out: &mut [f64]| {
                    // σᵀ(I − A) = σ − Aᵀσ
                    a.matvec_t(sigma, out);
                    for i in 0..n {
                        out[i] = sigma[i] - out[i];
                    }
                },
                None,
                &vec![0.0; n],
                &AdjointFpOptions {
                    max_iters: 30 * n,
                    memory: 40 * n,
                    ..Default::default()
                },
            );
            prop::ensure(res.converged, &format!("|g|={}", res.g_norm))?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn opa_improves_left_inversion() {
        // With OPA updates in the direction v = B⁻ᵀ∇L, the SHINE estimate
        // ∇Lᵀ B⁻¹ should be closer to ∇Lᵀ J⁻¹ than without OPA (Fig. E.3).
        prop::check("adjfp-opa-quality", 5, |rng| {
            let n = 12;
            let (a, b, _z_star) = linear_case(rng, n);
            let grad_l = rng.normal_vec(n);
            let mut ia = DMat::eye(n);
            for i in 0..n {
                for j in 0..n {
                    ia[(i, j)] -= a[(i, j)];
                }
            }
            let exact = crate::linalg::lu::Lu::factor(&ia).unwrap().solve_t(&grad_l);
            let run = |opa: Option<usize>| {
                let gl = grad_l.clone();
                let mut og = move |_z: &[f64], out: &mut [f64]| out.copy_from_slice(&gl);
                let res = adjoint_broyden_solve(
                    |z: &[f64], out: &mut [f64]| {
                        a.matvec(z, out);
                        for i in 0..n {
                            out[i] = z[i] - out[i] - b[i];
                        }
                    },
                    |_z: &[f64], sigma: &[f64], out: &mut [f64]| {
                        a.matvec_t(sigma, out);
                        for i in 0..n {
                            out[i] = sigma[i] - out[i];
                        }
                    },
                    Some(&mut og),
                    &vec![0.0; n],
                    &AdjointFpOptions {
                        max_iters: 25,
                        memory: 400,
                        opa_freq: opa,
                        ..Default::default()
                    },
                );
                let approx = res.qn.apply_t_vec(&grad_l);
                crate::linalg::vecops::dist2(&approx, &exact)
            };
            let err_opa = run(Some(1));
            let err_plain = run(None);
            prop::ensure(
                err_opa <= err_plain * 1.2 + 1e-9,
                &format!("opa {err_opa:.3e} vs plain {err_plain:.3e}"),
            )
        });
    }
}
