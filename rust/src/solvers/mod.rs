//! Inner-problem solvers (the *forward pass* of the bi-level problem).
//!
//! * [`session`] — **the unified solve surface**: [`session::SolverSpec`]
//!   (Picard | Anderson | Broyden, plus the authoritative tol/budget)
//!   builds a [`session::FixedPointSolver`] trait object whose
//!   [`session::SolveOutcome`] carries the captured inverse-estimate
//!   handle; the companion [`session::Backward`] trait (Shine |
//!   JacobianFree | Fallback | Refine | Full) consumes it. Every in-tree
//!   consumer — DEQ trainer, HOAG, power probes, coordinator experiments,
//!   the serving tier, the CLI — goes through this API.
//! * [`fixed_point`] — the iteration bodies the session solvers drive, plus
//!   the legacy free-function shims (`broyden_solve_ws`,
//!   `anderson_solve_ws`, `picard_solve*`, `anderson_solve_batch`) that
//!   delegate to the session API for source compatibility.
//! * [`minimize`] — LBFGS minimizer with Wolfe line search and the paper's
//!   OPA extra updates (hyperparameter-optimization forward).
//! * [`adjoint`] — forward solve driven by the Adjoint Broyden method
//!   (needed for Theorem 4 / Table E.3 experiments).
//! * [`linear`] — the backward-pass linear solvers: CG (symmetric case) and
//!   Broyden-on-VJPs (general case), both warm-startable — the *refine*
//!   strategy is exactly "warm start these from the forward estimate", and
//!   the session [`session::Backward`] implementations are built on them.
//! * [`line_search`] — Wolfe and backtracking line searches.

pub mod adjoint;
pub mod fixed_point;
pub mod line_search;
pub mod linear;
pub mod minimize;
pub mod session;

pub use session::{
    Backward, BackwardOutcome, BackwardSpec, EstimateHandle, FixedPointSolver, ForwardHandle,
    PanelPrecision, Session, SolveOutcome, SolverMethod, SolverSpec,
};

/// Shared solver telemetry: per-iteration residual + wall time.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub residuals: Vec<f64>,
    pub times: Vec<f64>,
}

impl Trace {
    /// Preallocate for `n` samples so recording inside an allocation-free
    /// solver loop never grows the vectors.
    pub fn with_capacity(n: usize) -> Trace {
        Trace {
            residuals: Vec::with_capacity(n),
            times: Vec::with_capacity(n),
        }
    }

    pub fn push(&mut self, res: f64, t: f64) {
        self.residuals.push(res);
        self.times.push(t);
    }
    pub fn len(&self) -> usize {
        self.residuals.len()
    }
    pub fn is_empty(&self) -> bool {
        self.residuals.is_empty()
    }
}
