//! Root / fixed-point solvers for the DEQ forward pass.
//!
//! **Entry-point status**: since the session-API redesign
//! ([`crate::solvers::session`]), the public free functions here
//! (`broyden_solve_ws`, `anderson_solve_ws`, `picard_solve`, the `*_batch`
//! family) are thin deprecated shims that delegate to
//! `SolverSpec::build()` → `FixedPointSolver::solve`/`solve_batch` — the
//! iteration bodies live in `pub(crate)` cores the trait implementations
//! drive, so both surfaces are one code path (bit-identical, pinned by
//! `rust/tests/session_parity.rs`). In-tree consumers go through the
//! session API; the shims exist for external snippets and the parity tests.
//!
//! The primary solver is Broyden's method ([`broyden_solve`]) exactly as in
//! the DEQ line of work: limited memory, identity initialization, optional
//! derivative-free backtracking. It returns the final iterate *and* the qN
//! inverse estimate — the object SHINE shares with the backward pass.
//!
//! Every solver here is generic over the storage precision
//! [`Elem`] (`f32` for the DEQ path, `f64` default elsewhere); residual
//! norms, mixing weights and the Anderson Gram system stay in f64 per the
//! crate's precision contract ([`crate::linalg::vecops`]).
//!
//! Residual evaluations use the write-into convention `g(z, out)` so the
//! solver loops are allocation-free: every iterate/residual/step buffer is
//! preallocated and double-buffered with `mem::swap`, and the qN update draws
//! its scratch from a [`Workspace`] (see `rust/tests/qn_alloc.rs` for the
//! counting-allocator proof). Use [`broyden_solve_ws`] to share one workspace
//! across many solves (the DEQ trainer does this across training steps).
//!
//! [`anderson_solve`] and [`picard_solve`] are baselines used in tests and
//! ablations. Since the incremental-Gram rework, [`anderson_solve_ws`] is
//! allocation-free per iteration too: the k×k Gram matrix persists in the
//! workspace's accumulator pool and is updated by a row/column shift per
//! evicted history entry plus one fresh row of dots — O(k·d) per iteration
//! instead of the old O(k²·d) rebuild — and the small solve runs in place
//! (no `DMat`/LU allocation).
//!
//! The batched serving path ([`crate::serve`]) runs the same two methods
//! over a contiguous d × B column-major state block:
//! [`picard_solve_batch`] and [`anderson_solve_batch`] / [`AndersonBatch`]
//! evaluate the residual ONCE per iteration for every active column, retire
//! converged columns by swap-to-back compaction, and keep each column's
//! trajectory bit-identical to its sequential counterpart (Anderson shares
//! the literal iteration body through the private `AndersonState` machine).
//! For **continuous batching** the engine drives the same per-column state
//! through the streaming hooks ([`AndersonBatch::reset_col`] /
//! [`AndersonBatch::swap_state`] / [`AndersonBatch::advance_cols`]), so a
//! request injected into a freed column mid-solve follows the bit-identical
//! solo trajectory from its injection point.

use crate::linalg::vecops::{add_scaled, axpy, dot, nrm2, sub, zero, Elem};
use crate::qn::broyden::BroydenInverse;
use crate::qn::workspace::Workspace;
use crate::qn::MemoryPolicy;
use crate::solvers::session::{FixedPointSolver, Session, SolverSpec};
use crate::solvers::Trace;
use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct FpOptions {
    /// Absolute tolerance on ‖g(z)‖ (the DEQ code stops on absolute residual
    /// scaled by √d; we expose the raw threshold).
    pub tol: f64,
    pub max_iters: usize,
    /// qN memory (paper: 30 for accelerated methods, Appendix C).
    pub memory: usize,
    pub policy: MemoryPolicy,
    /// Enable derivative-free backtracking line search.
    pub line_search: bool,
}

impl Default for FpOptions {
    fn default() -> Self {
        FpOptions {
            tol: 1e-8,
            max_iters: 200,
            memory: 30,
            policy: MemoryPolicy::Freeze,
            line_search: false,
        }
    }
}

#[derive(Debug)]
pub struct FpResult<E: Elem = f64> {
    pub z: Vec<E>,
    pub g_norm: f64,
    pub iters: usize,
    pub converged: bool,
    /// Forward quasi-Newton estimate (H ≈ J_g⁻¹) — what SHINE reuses.
    pub qn: BroydenInverse<E>,
    pub trace: Trace,
    /// Number of g evaluations (≠ iters when line search is active).
    pub n_g_evals: usize,
}

/// Broyden root solve of g(z) = 0 starting from `z0` (owns its workspace).
pub fn broyden_solve<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    opts: &FpOptions,
) -> FpResult<E> {
    let mut ws = Workspace::new();
    broyden_solve_ws(g, z0, opts, &mut ws)
}

/// Broyden root solve with a caller-provided scratch arena.
///
/// **Deprecated shim**: new code should build a solver through the session
/// API ([`SolverSpec::build`](crate::solvers::session::SolverSpec) →
/// [`FixedPointSolver::solve`](crate::solvers::session::FixedPointSolver)),
/// which returns the captured inverse estimate as a typed
/// [`EstimateHandle`](crate::solvers::session::EstimateHandle). This entry
/// point lifts the caller's workspace into a [`Session`] and delegates —
/// bit-identical trajectories, pinned by `rust/tests/session_parity.rs`.
pub fn broyden_solve_ws<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    opts: &FpOptions,
    ws: &mut Workspace<E>,
) -> FpResult<E> {
    let spec = SolverSpec::from_fp_options(opts);
    let mut solver: Box<dyn FixedPointSolver<E>> = spec.build::<E>();
    let mut sess = Session::from_workspace(std::mem::take(ws));
    let mut g = g;
    let out = solver.solve(&mut sess, &mut g, z0);
    *ws = sess.into_workspace();
    out.into_fp_result()
}

/// The Broyden iteration body (the session API's `BroydenSolver` drives
/// this; the public shim above routes through the trait). After the first
/// one or two iterations warm the workspace, the loop performs zero heap
/// allocations.
pub(crate) fn broyden_core<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    opts: &FpOptions,
    ws: &mut Workspace<E>,
) -> FpResult<E> {
    let d = z0.len();
    let sw = Stopwatch::start();
    let mut qn = BroydenInverse::new(d, opts.memory, opts.policy);
    let mut z = z0.to_vec();
    let mut gz = vec![E::ZERO; d];
    g(&z, &mut gz);
    let mut n_g_evals = 1usize;
    let mut g_norm = nrm2(&gz);
    let mut trace = Trace::with_capacity(opts.max_iters.saturating_add(1).min(1 << 16));
    trace.push(g_norm, sw.elapsed());
    // All loop state is preallocated here; the iteration below only swaps.
    let mut p = vec![E::ZERO; d];
    let mut z_new = vec![E::ZERO; d];
    let mut g_new = vec![E::ZERO; d];
    let mut s = vec![E::ZERO; d];
    let mut y = vec![E::ZERO; d];
    let mut zt = vec![E::ZERO; d]; // line-search trial point
    let mut gt = vec![E::ZERO; d]; // line-search trial residual
    let mut iters = 0;
    while g_norm > opts.tol && iters < opts.max_iters {
        qn.direction_ws(&gz, &mut p, ws);
        let alpha = if opts.line_search {
            let mut evals = 0usize;
            let a = crate::solvers::line_search::backtrack_residual(
                g_norm,
                |a| {
                    evals += 1;
                    add_scaled(&z, a, &p, &mut zt);
                    g(&zt[..], &mut gt[..]);
                    nrm2(&gt)
                },
                0.5,
                1e-4,
                8,
            );
            n_g_evals += evals;
            a
        } else {
            1.0
        };
        add_scaled(&z, alpha, &p, &mut z_new);
        g(&z_new, &mut g_new);
        n_g_evals += 1;
        sub(&z_new, &z, &mut s);
        sub(&g_new, &gz, &mut y);
        qn.update_ws(&s, &y, ws);
        std::mem::swap(&mut z, &mut z_new);
        std::mem::swap(&mut gz, &mut g_new);
        g_norm = nrm2(&gz);
        iters += 1;
        trace.push(g_norm, sw.elapsed());
    }
    FpResult {
        converged: g_norm <= opts.tol,
        z,
        g_norm,
        iters,
        qn,
        trace,
        n_g_evals,
    }
}

/// Damped Picard iteration z ← z − τ g(z) (baseline / pre-training warmup).
///
/// **Deprecated shim** over the session API (`SolverSpec::picard(tau)` →
/// `build().solve(...)`); kept for callers that only want the iterate.
pub fn picard_solve<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    tau: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<E>, f64, usize) {
    let spec = SolverSpec::picard(tau).with_tol(tol).with_max_iters(max_iters);
    let mut solver: Box<dyn FixedPointSolver<E>> = spec.build::<E>();
    let mut sess: Session<E> = Session::new();
    let mut g = g;
    let out = solver.solve(&mut sess, &mut g, z0);
    (out.z, out.residual, out.iters)
}

/// The Picard iteration body (driven by the session API's `PicardSolver`).
pub(crate) fn picard_core<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    tau: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<E>, f64, usize) {
    let d = z0.len();
    let mut z = z0.to_vec();
    let mut gz = vec![E::ZERO; d];
    let mut iters = 0;
    loop {
        g(&z, &mut gz);
        let n = nrm2(&gz);
        if n <= tol || iters >= max_iters {
            return (z, n, iters);
        }
        axpy(-tau, &gz, &mut z);
        iters += 1;
    }
}

/// Anderson acceleration (type-II) on the fixed-point map  z ↦ z − g(z)
/// (owns its workspace).
pub fn anderson_solve<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
) -> (Vec<E>, f64, usize) {
    let mut ws = Workspace::new();
    anderson_solve_ws(g, z0, m, tol, max_iters, beta, &mut ws)
}

/// Anderson acceleration with a caller-provided workspace — allocation-free
/// per iteration once the workspace is warm:
///
/// * the iterate/residual histories live in recycled buffers (O(1) eviction
///   by rotating the oldest buffer to the back);
/// * the k×k Gram matrix of the ΔR difference rows **persists across
///   iterations** in the workspace's f64 accumulator pool — evicting the
///   oldest history entry shifts it one row+column up-left in place, and
///   each iteration appends a single fresh row/column of dots (O(k·d)
///   instead of rebuilding all k² entries);
/// * the damped normal-equation solve runs by in-place Gaussian elimination
///   on a workspace scratch copy — no `DMat`/LU allocation.
///
/// The iteration body lives in [`AndersonState`], the per-column state
/// machine the batched serving solver ([`anderson_solve_batch`]) drives for
/// B columns against one shared residual evaluation — one code path, so the
/// batched solve is bit-identical to B sequential runs.
///
/// **Deprecated shim** over the session API (`SolverSpec::anderson(m, beta)`
/// → `build().solve(...)`); lifts the caller's workspace into a [`Session`]
/// for the call.
pub fn anderson_solve_ws<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
    ws: &mut Workspace<E>,
) -> (Vec<E>, f64, usize) {
    let spec = SolverSpec::anderson(m, beta).with_tol(tol).with_max_iters(max_iters);
    let mut solver: Box<dyn FixedPointSolver<E>> = spec.build::<E>();
    let mut sess = Session::from_workspace(std::mem::take(ws));
    let mut g = g;
    let out = solver.solve(&mut sess, &mut g, z0);
    *ws = sess.into_workspace();
    (out.z, out.residual, out.iters)
}

/// The Anderson iteration body (driven by the session API's
/// `AndersonSolver`).
pub(crate) fn anderson_core<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
    ws: &mut Workspace<E>,
) -> (Vec<E>, f64, usize) {
    let d = z0.len();
    let mut z = z0.to_vec();
    let mut r = vec![E::ZERO; d];
    let mut st = AndersonState::new(d, m, ws);
    let mut iters = 0;
    let rn = loop {
        g(&z, &mut r);
        let rn = nrm2(&r);
        if rn <= tol || iters >= max_iters {
            break rn;
        }
        st.advance(&mut z, &r, beta, ws);
        iters += 1;
    };
    st.release(ws);
    (z, rn, iters)
}

/// Per-column Anderson(m) state machine: exactly the iteration body of
/// [`anderson_solve_ws`], factored out so the batched solver can drive B
/// independent columns against one shared residual evaluation while each
/// column follows the bit-identical sequential trajectory.
///
/// All d-length buffers come from the caller's [`Workspace`]; on
/// [`AndersonState::reset`] they are parked on an internal spare list, so a
/// state that lives across repeated solves (the serving engine keeps one
/// per batch slot) allocates nothing after its first full-depth solve.
struct AndersonState<E: Elem> {
    m: usize,
    d: usize,
    /// Gram stride (`m.max(1)`).
    gs: usize,
    /// Iterate / residual history, logical oldest → newest, at most m live.
    hist_z: Vec<Vec<E>>,
    hist_r: Vec<Vec<E>>,
    /// ΔR difference rows (logical oldest → newest), at most m−1 live.
    dr: Vec<Vec<E>>,
    ndr: usize,
    /// Persistent small-system scratch (f64 accumulator pool); the Gram
    /// block survives across iterations (incremental row/col updates).
    gram: Vec<f64>,
    lu: Vec<f64>,
    rhs: Vec<f64>,
    alphas: Vec<f64>,
    z_next: Vec<E>,
    /// Recycled d-buffers from a previous solve through this state.
    spare: Vec<Vec<E>>,
}

impl<E: Elem> AndersonState<E> {
    fn new(d: usize, m: usize, ws: &mut Workspace<E>) -> AndersonState<E> {
        let gs = m.max(1);
        // Take order gram → lu → rhs → alphas; release() gives back in
        // reverse so the acc pool hands the same capacities to the next
        // construction.
        AndersonState {
            m,
            d,
            gs,
            hist_z: Vec::with_capacity(m.max(1)),
            hist_r: Vec::with_capacity(m.max(1)),
            dr: Vec::with_capacity(m.max(1)),
            ndr: 0,
            gram: ws.take_acc(gs * gs),
            lu: ws.take_acc(gs * gs),
            rhs: ws.take_acc(gs),
            alphas: ws.take_acc(gs + 1),
            z_next: ws.take(d),
            spare: Vec::with_capacity(3 * m.max(1) + 2),
        }
    }

    /// One Anderson mixing step given the fresh residual `r` at iterate `z`
    /// (the post-tolerance-check body of the [`anderson_solve_ws`] loop);
    /// the mixed iterate is written back into `z`.
    fn advance(&mut self, z: &mut [E], r: &[E], beta: f64, ws: &mut Workspace<E>) {
        let m = self.m;
        let gs = self.gs;
        let d = self.d;
        debug_assert_eq!(z.len(), d);
        debug_assert_eq!(r.len(), d);
        // --- incremental ΔR / Gram maintenance (only defined for m ≥ 2).
        if m >= 2 && !self.hist_r.is_empty() {
            if self.ndr + 1 >= m {
                // The oldest history entry is about to be evicted: drop ΔR₀
                // by shifting the Gram block up-left and rotating the row
                // buffer to the back for reuse as the new newest row.
                let n = self.ndr;
                for i in 1..n {
                    for j in 1..n {
                        self.gram[(i - 1) * gs + (j - 1)] = self.gram[i * gs + j];
                    }
                }
                self.dr[..n].rotate_left(1);
                self.ndr -= 1;
            }
            if self.dr.len() == self.ndr {
                let buf = self.spare.pop().unwrap_or_else(|| ws.take(d));
                self.dr.push(buf);
            }
            let n = self.ndr;
            {
                // ΔR_new = r − r_prev (the history still ends at r_prev).
                let prev = self.hist_r.last().unwrap();
                sub(r, prev, &mut self.dr[n]);
            }
            for j in 0..n {
                let gij = dot(&self.dr[n], &self.dr[j]);
                self.gram[n * gs + j] = gij;
                self.gram[j * gs + n] = gij;
            }
            self.gram[n * gs + n] = dot(&self.dr[n], &self.dr[n]);
            self.ndr += 1;
        }
        // --- append (z, r) to the history, recycling the evicted buffers.
        let (mut zb, mut rb) = if self.hist_z.len() >= m && !self.hist_z.is_empty() {
            (self.hist_z.remove(0), self.hist_r.remove(0))
        } else {
            let zb = self.spare.pop().unwrap_or_else(|| ws.take(d));
            let rb = self.spare.pop().unwrap_or_else(|| ws.take(d));
            (zb, rb)
        };
        zb.copy_from_slice(z);
        rb.copy_from_slice(r);
        self.hist_z.push(zb);
        self.hist_r.push(rb);
        let k = self.hist_z.len();
        debug_assert!(m < 2 || self.ndr == k - 1);
        // --- solve min ‖Σ αᵢ rᵢ‖² s.t. Σ αᵢ = 1 via the damped normal
        // equations on the persistent Gram (solution γ lands in `rhs`).
        let kk = self.ndr;
        for a in self.alphas.iter_mut().take(k) {
            *a = 0.0;
        }
        self.alphas[k - 1] = 1.0;
        if kk > 0 {
            for i in 0..kk {
                for j in 0..kk {
                    self.lu[i * kk + j] = self.gram[i * gs + j];
                }
                self.lu[i * kk + i] += 1e-10;
                self.rhs[i] = dot(&self.dr[i], r);
            }
            if solve_in_place(&mut self.lu[..kk * kk], kk, &mut self.rhs[..kk]) {
                // α from γ: barycentric weights (singular systems keep the
                // plain-mixing fallback α = e_{k−1}).
                for i in 0..kk {
                    self.alphas[i + 1] -= self.rhs[i];
                    self.alphas[i] += self.rhs[i];
                }
            }
        }
        // --- mixing: z⁺ = Σ αᵢ (zᵢ − β rᵢ), accumulated in f64.
        zero(&mut self.z_next);
        for i in 0..k {
            let a = self.alphas[i];
            if a != 0.0 {
                for j in 0..d {
                    self.z_next[j] = E::from_f64(
                        self.z_next[j].to_f64()
                            + a * (self.hist_z[i][j].to_f64()
                                - beta * self.hist_r[i][j].to_f64()),
                    );
                }
            }
        }
        z.copy_from_slice(&self.z_next);
    }

    /// Forget the solve history, parking every d-buffer on the spare list so
    /// the next solve through this state allocates nothing.
    fn reset(&mut self) {
        self.spare.extend(self.hist_z.drain(..));
        self.spare.extend(self.hist_r.drain(..));
        self.spare.extend(self.dr.drain(..));
        self.ndr = 0;
    }

    /// Give every buffer back to the workspace (acc buffers in reverse take
    /// order, per the pool's LIFO discipline).
    fn release(mut self, ws: &mut Workspace<E>) {
        self.reset();
        for b in self.spare.drain(..) {
            ws.give(b);
        }
        ws.give(self.z_next);
        ws.give_acc(self.alphas);
        ws.give_acc(self.rhs);
        ws.give_acc(self.lu);
        ws.give_acc(self.gram);
    }
}

// ---- batched (serving) fixed-point solvers --------------------------------
//
// The serving engine treats B concurrent DEQ requests as one contiguous
// d × B column-major state block (column j = `zs[j*d..(j+1)*d]`) so the
// model residual is evaluated ONCE per iteration over the whole block — the
// batching that turns B vector solves into matrix-level work. Converged
// columns retire by swapping behind the active prefix (O(d) per
// retirement), so late iterations only touch the stragglers; the block is
// returned in submission order (the permutation is undone by a cycle walk).
// Every column follows exactly the trajectory of its sequential solver, so
// per-column results and iteration counts are bit-identical to B
// independent runs (pinned by `rust/tests/serve_batch.rs`).

/// Per-column outcome of a batched fixed-point solve, indexed by the
/// column's position in the caller's original block (the solvers compact
/// internally but report in submission order).
#[derive(Clone, Copy, Debug, Default)]
pub struct ColStats {
    /// Iterations this column ran before retiring.
    pub iters: usize,
    /// Final residual norm ‖g(z)‖ at retirement.
    pub residual: f64,
    pub converged: bool,
}

/// Swap columns `a` and `b` (`a < b`) of a contiguous block of d-columns.
/// `pub(crate)` because the serving engine's streaming-admission loop
/// ([`crate::serve::engine::ServeEngine::process_streaming`]) performs the
/// same swap-to-back compaction on its long-lived in-flight block.
pub(crate) fn swap_cols<E: Elem>(zs: &mut [E], d: usize, a: usize, b: usize) {
    debug_assert!(a < b);
    let (lo, hi) = zs.split_at_mut(b * d);
    lo[a * d..(a + 1) * d].swap_with_slice(&mut hi[..d]);
}

/// Undo the retirement permutation: physical column `p` currently holds the
/// caller's logical column `ids[p]`; cycle-walk until every column is home
/// (`ids` becomes the identity). O(B) column swaps, allocation-free.
fn unpermute_cols<E: Elem>(zs: &mut [E], d: usize, ids: &mut [usize]) {
    for p in 0..ids.len() {
        while ids[p] != p {
            let q = ids[p];
            // Positions < p are already home, so the displaced column's
            // destination is always to the right.
            debug_assert!(q > p);
            swap_cols(zs, d, p, q);
            ids.swap(p, q);
        }
    }
}

/// Per-solver hooks of the shared batched driver ([`batch_solve_driver`]):
/// how per-column solver state travels with a compaction swap, and how the
/// active block advances given its freshly evaluated residuals.
trait BatchCols<E: Elem> {
    /// Columns `j` and `k` swapped in the block — swap any per-column state.
    fn swap(&mut self, j: usize, k: usize);
    /// Advance the active prefix (`zs`/`r` are `active × d`) one iteration.
    fn update(&mut self, zs: &mut [E], r: &[E], d: usize, ws: &mut Workspace<E>);
}

/// The one retirement/compaction loop both batched solvers share — keeping
/// the bit-parity contract (per-column trajectories, residuals and
/// iteration counts identical to sequential runs) in a single place.
///
/// Per iteration: evaluate `g` once over the active prefix, retire every
/// column whose residual reaches `tol` (or whose budget is exhausted) by
/// swapping it behind the prefix — state, residual, ids and per-solver
/// state travel together — then let `ops.update` advance the survivors.
/// On return the block is un-permuted back to submission order.
fn batch_solve_driver<E: Elem>(
    mut g: impl FnMut(&[E], &[usize], &mut [E]),
    zs: &mut [E],
    d: usize,
    tol: f64,
    max_iters: usize,
    ws: &mut Workspace<E>,
    stats: &mut [ColStats],
    ops: &mut impl BatchCols<E>,
) {
    if zs.is_empty() || d == 0 {
        return;
    }
    debug_assert_eq!(zs.len() % d, 0);
    let b = zs.len() / d;
    debug_assert!(stats.len() >= b);
    let mut r = ws.take(b * d);
    let mut ids = ws.take_idx(b);
    for (j, id) in ids.iter_mut().enumerate() {
        *id = j;
    }
    let mut active = b;
    let mut iters = 0usize;
    while active > 0 {
        g(&zs[..active * d], &ids[..active], &mut r[..active * d]);
        let mut j = 0;
        while j < active {
            let n = nrm2(&r[j * d..(j + 1) * d]);
            if n <= tol || iters >= max_iters {
                stats[ids[j]] = ColStats {
                    iters,
                    residual: n,
                    converged: n <= tol,
                };
                active -= 1;
                if j != active {
                    swap_cols(zs, d, j, active);
                    swap_cols(&mut r, d, j, active);
                    ids.swap(j, active);
                    ops.swap(j, active);
                }
                // Re-examine position j: it now holds the swapped-in column
                // (whose residual from this sweep moved with it).
            } else {
                j += 1;
            }
        }
        if active == 0 {
            break;
        }
        ops.update(&mut zs[..active * d], &r[..active * d], d, ws);
        iters += 1;
    }
    unpermute_cols(zs, d, &mut ids);
    ws.give_idx(ids);
    ws.give(r);
}

/// Damped Picard iteration over a whole batch of fixed-point problems.
///
/// `zs` is the contiguous d × B column-major state block (in: initial
/// iterates, out: solutions in submission order). The batched residual
/// closure `g(block, ids, out)` evaluates `ids.len()` active columns in one
/// call; `ids[p]` is the caller-side column that physical column `p`
/// currently holds, so per-request context (e.g. the DEQ input injection)
/// can be looked up per column. Columns whose residual reaches `tol` retire
/// by swap-to-back compaction and stop being touched; each column's
/// trajectory, final residual and iteration count are exactly those of an
/// independent [`picard_solve`] run with the same `tau`/`tol`/`max_iters`.
/// Per-column outcomes land in `stats` (length ≥ B). Allocation-free once
/// `ws` is warm.
///
/// **Deprecated shim** over the session API
/// ([`FixedPointSolver::solve_batch`](crate::solvers::session::FixedPointSolver::solve_batch)).
pub fn picard_solve_batch<E: Elem>(
    g: impl FnMut(&[E], &[usize], &mut [E]),
    zs: &mut [E],
    d: usize,
    tau: f64,
    tol: f64,
    max_iters: usize,
    ws: &mut Workspace<E>,
    stats: &mut [ColStats],
) {
    let spec = SolverSpec::picard(tau).with_tol(tol).with_max_iters(max_iters);
    let mut solver: Box<dyn FixedPointSolver<E>> = spec.build::<E>();
    let mut sess = Session::from_workspace(std::mem::take(ws));
    let mut g = g;
    solver.solve_batch(&mut sess, &mut g, zs, d, stats);
    *ws = sess.into_workspace();
}

/// The batched Picard body (driven by the session API's `PicardSolver`).
pub(crate) fn picard_batch_core<E: Elem>(
    g: impl FnMut(&[E], &[usize], &mut [E]),
    zs: &mut [E],
    d: usize,
    tau: f64,
    tol: f64,
    max_iters: usize,
    ws: &mut Workspace<E>,
    stats: &mut [ColStats],
) {
    /// Stateless per-column ops: the whole active block updates with one
    /// fused axpy (z ← z − τ g(z)), elementwise-identical to the sequential
    /// [`picard_solve`] update.
    struct PicardOps {
        tau: f64,
    }
    impl<E: Elem> BatchCols<E> for PicardOps {
        fn swap(&mut self, _j: usize, _k: usize) {}
        fn update(&mut self, zs: &mut [E], r: &[E], _d: usize, _ws: &mut Workspace<E>) {
            axpy(-self.tau, r, zs);
        }
    }
    batch_solve_driver(g, zs, d, tol, max_iters, ws, stats, &mut PicardOps { tau });
}

/// Reusable batched Anderson(m) driver: one [`AndersonState`] per batch
/// slot, kept alive across batches by the serving engine so a steady-state
/// batch solve performs zero heap allocations (the states recycle their own
/// history buffers on reset).
pub struct AndersonBatch<E: Elem> {
    d: usize,
    beta: f64,
    states: Vec<AndersonState<E>>,
}

impl<E: Elem> AndersonBatch<E> {
    /// Allocate per-column state for up to `max_cols` concurrent columns of
    /// dimension `d` with history depth `m` and mixing parameter `beta`.
    pub fn new(d: usize, m: usize, beta: f64, max_cols: usize, ws: &mut Workspace<E>) -> Self {
        let states = (0..max_cols).map(|_| AndersonState::new(d, m, ws)).collect();
        AndersonBatch { d, beta, states }
    }

    pub fn max_cols(&self) -> usize {
        self.states.len()
    }

    /// Batched Anderson solve on the d × B column-major block `zs`
    /// (B ≤ `max_cols`). Same contract as [`picard_solve_batch`] — one
    /// residual evaluation per iteration over the active block, swap-to-back
    /// retirement (per-column states travel with their columns), per-column
    /// trajectories bit-identical to independent [`anderson_solve_ws`] runs.
    pub fn solve(
        &mut self,
        g: impl FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        tol: f64,
        max_iters: usize,
        ws: &mut Workspace<E>,
        stats: &mut [ColStats],
    ) {
        let d = self.d;
        if zs.is_empty() || d == 0 {
            return;
        }
        debug_assert_eq!(zs.len() % d, 0);
        let b = zs.len() / d;
        assert!(
            b <= self.states.len(),
            "batch of {b} columns exceeds AndersonBatch capacity {}",
            self.states.len()
        );
        for st in self.states.iter_mut().take(b) {
            st.reset();
        }
        /// Per-column ops: the Anderson states travel with their columns on
        /// compaction swaps, and each active column advances through its
        /// own state machine (bit-identical to [`anderson_solve_ws`]).
        struct AndersonOps<'a, E: Elem> {
            states: &'a mut [AndersonState<E>],
            beta: f64,
        }
        impl<E: Elem> BatchCols<E> for AndersonOps<'_, E> {
            fn swap(&mut self, j: usize, k: usize) {
                self.states.swap(j, k);
            }
            fn update(&mut self, zs: &mut [E], r: &[E], d: usize, ws: &mut Workspace<E>) {
                let active = zs.len() / d;
                for j in 0..active {
                    self.states[j].advance(
                        &mut zs[j * d..(j + 1) * d],
                        &r[j * d..(j + 1) * d],
                        self.beta,
                        ws,
                    );
                }
            }
        }
        let mut ops = AndersonOps {
            states: &mut self.states[..b],
            beta: self.beta,
        };
        batch_solve_driver(g, zs, d, tol, max_iters, ws, stats, &mut ops);
    }

    /// Return every internal buffer to the workspace (reverse construction
    /// order, keeping the pools warm for the next `new`).
    pub fn release(self, ws: &mut Workspace<E>) {
        for st in self.states.into_iter().rev() {
            st.release(ws);
        }
    }

    // ---- streaming-admission hooks (continuous batching) ------------------
    //
    // The discrete `solve` above owns the whole retirement loop; the serving
    // engine's continuous-batching loop owns it instead (per-column iteration
    // counters and deadlines live there) and drives the per-column Anderson
    // states through these three hooks. Injecting a request into a freed
    // column only touches that column's state — `reset_col` parks its
    // history buffers for reuse and never reads a neighbour — so resident
    // columns' trajectories are unperturbed (pinned by the mid-solve
    // admission parity tests in `rust/tests/serve_batch.rs`).

    /// Forget column `j`'s solve history ahead of admitting a new request
    /// into that slot. Allocation-free: the history buffers are parked on
    /// the state's spare list.
    pub fn reset_col(&mut self, j: usize) {
        self.states[j].reset();
    }

    /// Per-column state follows a compaction swap of block columns `a`/`b`.
    pub fn swap_state(&mut self, a: usize, b: usize) {
        self.states.swap(a, b);
    }

    /// Advance every column of the active prefix one Anderson step given the
    /// freshly evaluated residual block `r` (same layout as `zs`). Exactly
    /// the per-column body of the discrete batched solve.
    pub fn advance_cols(&mut self, zs: &mut [E], r: &[E], ws: &mut Workspace<E>) {
        let d = self.d;
        debug_assert_eq!(zs.len(), r.len());
        debug_assert_eq!(zs.len() % d, 0);
        let active = zs.len() / d;
        assert!(
            active <= self.states.len(),
            "active block of {active} columns exceeds AndersonBatch capacity {}",
            self.states.len()
        );
        for j in 0..active {
            self.states[j].advance(
                &mut zs[j * d..(j + 1) * d],
                &r[j * d..(j + 1) * d],
                self.beta,
                ws,
            );
        }
    }
}

/// One-shot batched Anderson solve (owns its per-column states for the
/// call; long-lived consumers hold a session-API `AndersonSolver` — or the
/// underlying [`AndersonBatch`] — so repeated batches stay allocation-free).
///
/// **Deprecated shim** over the session API
/// ([`FixedPointSolver::solve_batch`](crate::solvers::session::FixedPointSolver::solve_batch)).
pub fn anderson_solve_batch<E: Elem>(
    g: impl FnMut(&[E], &[usize], &mut [E]),
    zs: &mut [E],
    d: usize,
    m: usize,
    beta: f64,
    tol: f64,
    max_iters: usize,
    ws: &mut Workspace<E>,
    stats: &mut [ColStats],
) {
    let spec = SolverSpec::anderson(m, beta).with_tol(tol).with_max_iters(max_iters);
    let mut solver: Box<dyn FixedPointSolver<E>> = spec.build::<E>();
    let mut sess = Session::from_workspace(std::mem::take(ws));
    let mut g = g;
    solver.solve_batch(&mut sess, &mut g, zs, d, stats);
    solver.release(&mut sess);
    *ws = sess.into_workspace();
}

/// In-place Gaussian elimination with partial pivoting on a dense row-major
/// `n×n` system; the solution overwrites `b`. Returns false on a vanishing
/// pivot (caller falls back to plain mixing). Allocation-free — this is the
/// small Anderson Gram system, k ≤ m.
fn solve_in_place(a: &mut [f64], n: usize, b: &mut [f64]) -> bool {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    for col in 0..n {
        let mut piv = col;
        let mut best = a[col * n + col].abs();
        for row in col + 1..n {
            let v = a[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if !best.is_finite() || !(best > 1e-300) {
            return false;
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            b.swap(col, piv);
        }
        let inv = 1.0 / a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] * inv;
            if f != 0.0 {
                for j in col..n {
                    a[row * n + j] -= f * a[col * n + j];
                }
                b[row] -= f * b[col];
            }
        }
    }
    for row in (0..n).rev() {
        let mut acc = b[row];
        for j in row + 1..n {
            acc -= a[row * n + j] * b[j];
        }
        b[row] = acc / a[row * n + row];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Contractive test map: g(z) = z − (Az + b) with ‖A‖ < 1, evaluated
    /// allocation-free into the caller's buffer.
    fn contractive_g(rng: &mut Rng, n: usize) -> (impl Fn(&[f64], &mut [f64]), Vec<f64>) {
        let a = crate::linalg::dmat::DMat::randn(n, n, 0.3 / (n as f64).sqrt(), rng);
        let b = rng.normal_vec(n);
        // Fixed point solves (I − A) z = b.
        let mut ia = crate::linalg::dmat::DMat::eye(n);
        for i in 0..n {
            for j in 0..n {
                ia[(i, j)] -= a[(i, j)];
            }
        }
        let z_star = crate::linalg::lu::Lu::factor(&ia).unwrap().solve(&b);
        let g = move |z: &[f64], out: &mut [f64]| {
            a.matvec(z, out); // out = Az
            for i in 0..n {
                out[i] = z[i] - out[i] - b[i];
            }
        };
        (g, z_star)
    }

    #[test]
    fn broyden_finds_fixed_point() {
        prop::check("broyden-fp", 10, |rng| {
            let n = 5 + rng.below(20);
            let (g, z_star) = contractive_g(rng, n);
            let res = broyden_solve(g, &vec![0.0; n], &FpOptions::default());
            prop::ensure(res.converged, "converged")?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn broyden_beats_picard_iterations() {
        let mut rng = Rng::new(42);
        let n = 30;
        let (g, _) = contractive_g(&mut rng, n);
        let res = broyden_solve(&g, &vec![0.0; n], &FpOptions::default());
        let (_, _, picard_iters) = picard_solve(&g, &vec![0.0; n], 1.0, 1e-8, 500);
        assert!(
            res.iters < picard_iters,
            "broyden {} vs picard {picard_iters}",
            res.iters
        );
    }

    #[test]
    fn shared_workspace_reproduces_owned_run() {
        let mut rng = Rng::new(8);
        let n = 16;
        let (g, _) = contractive_g(&mut rng, n);
        let opts = FpOptions::default();
        let owned = broyden_solve(&g, &vec![0.0; n], &opts);
        let mut ws = Workspace::new();
        // Reusing one workspace across repeated solves must not change
        // results (buffers are re-zeroed on take).
        let first = broyden_solve_ws(&g, &vec![0.0; n], &opts, &mut ws);
        let second = broyden_solve_ws(&g, &vec![0.0; n], &opts, &mut ws);
        assert_eq!(owned.z, first.z);
        assert_eq!(first.z, second.z);
        assert_eq!(first.iters, second.iters);
    }

    #[test]
    fn f32_broyden_converges_on_contractive_map() {
        // The f32 instantiation must reach an f32-appropriate residual on
        // the same map (full parity with the f64 reference is covered by
        // rust/tests/precision_parity.rs).
        let mut rng = Rng::new(12);
        let n = 16;
        let (g, z_star) = contractive_g(&mut rng, n);
        let g32 = |z: &[f32], out: &mut [f32]| {
            let z64: Vec<f64> = z.iter().map(|&x| x as f64).collect();
            let mut o64 = vec![0.0; z.len()];
            g(&z64, &mut o64);
            for (o, &v) in out.iter_mut().zip(o64.iter()) {
                *o = v as f32;
            }
        };
        let opts = FpOptions {
            tol: 1e-4,
            ..Default::default()
        };
        let res = broyden_solve(g32, &vec![0.0f32; n], &opts);
        assert!(res.converged, "|g|={}", res.g_norm);
        for i in 0..n {
            assert!(
                (res.z[i] as f64 - z_star[i]).abs() < 1e-3 * (1.0 + z_star[i].abs()),
                "idx {i}: {} vs {}",
                res.z[i],
                z_star[i]
            );
        }
    }

    #[test]
    fn line_search_variant_converges() {
        prop::check("broyden-fp-ls", 5, |rng| {
            let n = 10;
            let (g, z_star) = contractive_g(rng, n);
            let opts = FpOptions {
                line_search: true,
                ..FpOptions::default()
            };
            let res = broyden_solve(g, &vec![0.0; n], &opts);
            prop::ensure(res.converged, "converged")?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn anderson_converges() {
        prop::check("anderson-fp", 5, |rng| {
            let n = 12;
            let (g, z_star) = contractive_g(rng, n);
            let (z, rn, _) = anderson_solve(g, &vec![0.0; n], 5, 1e-9, 300, 1.0);
            prop::ensure(rn < 1e-8, &format!("residual {rn}"))?;
            prop::ensure_close_vec(&z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn anderson_incremental_gram_matches_small_histories() {
        // The incremental Gram must behave exactly like the full rebuild it
        // replaced: runs with different history sizes still converge to the
        // same fixed point, and a shared workspace reproduces an owned run.
        prop::check("anderson-incr-gram", 5, |rng| {
            let n = 10;
            let (g, z_star) = contractive_g(rng, n);
            let mut ws = Workspace::new();
            for m in [1usize, 2, 3, 6] {
                let (z, rn, _) = anderson_solve_ws(&g, &vec![0.0; n], m, 1e-9, 400, 1.0, &mut ws);
                prop::ensure(rn < 1e-8, &format!("m={m} residual {rn}"))?;
                prop::ensure_close_vec(&z, &z_star, 1e-5, "fixed point (shared ws)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn trace_is_recorded() {
        let mut rng = Rng::new(3);
        let (g, _) = contractive_g(&mut rng, 8);
        let res = broyden_solve(g, &vec![0.0; 8], &FpOptions::default());
        assert_eq!(res.trace.len(), res.iters + 1);
        assert!(res.trace.residuals[0] >= res.trace.residuals[res.iters]);
    }

    #[test]
    fn respects_max_iters() {
        // g has no root: the solver must stop exactly at max_iters.
        let g = |z: &[f64], out: &mut [f64]| out[0] = z[0] * z[0] + 1.0;
        let opts = FpOptions {
            max_iters: 3,
            tol: 1e-300,
            ..Default::default()
        };
        let res = broyden_solve(g, &[0.0], &opts);
        assert_eq!(res.iters, 3);
        assert!(!res.converged);
    }

    #[test]
    fn unpermute_cols_restores_submission_order() {
        // Block of 5 columns of width 3, scrambled by a known permutation.
        let d = 3;
        let perm = [3usize, 0, 4, 1, 2]; // physical p holds logical perm[p]
        let mut zs = vec![0.0f64; 5 * d];
        for (p, &l) in perm.iter().enumerate() {
            for i in 0..d {
                zs[p * d + i] = (l * 10 + i) as f64;
            }
        }
        let mut ids = perm.to_vec();
        unpermute_cols(&mut zs, d, &mut ids);
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        for l in 0..5 {
            for i in 0..d {
                assert_eq!(zs[l * d + i], (l * 10 + i) as f64);
            }
        }
    }

    /// Per-column linear test map with per-column contraction factor:
    /// g(z)[i] = z[i] − c·z[(i+1) mod d] − b[i]. Evaluated positionally in
    /// the batch closure through the ids slice.
    fn col_g(c: f64, b: &[f64], z: &[f64], out: &mut [f64]) {
        let d = z.len();
        for i in 0..d {
            out[i] = z[i] - c * z[(i + 1) % d] - b[i];
        }
    }

    #[test]
    fn picard_batch_matches_sequential_columns() {
        prop::check("picard-batch-parity", 5, |rng| {
            let d = 8 + rng.below(12);
            let nb = 2 + rng.below(5);
            let tau = 1.0;
            let tol = 1e-10;
            let max_iters = 400;
            // Per-column problems with spread-out difficulty so retirement
            // actually happens at different iterations.
            let cs: Vec<f64> = (0..nb).map(|j| 0.15 + 0.1 * j as f64 / nb as f64).collect();
            let bs: Vec<Vec<f64>> = (0..nb).map(|_| rng.normal_vec(d)).collect();
            let z0s: Vec<Vec<f64>> = (0..nb).map(|_| rng.normal_vec(d)).collect();
            let mut zs: Vec<f64> = Vec::with_capacity(nb * d);
            for z0 in &z0s {
                zs.extend_from_slice(z0);
            }
            let mut stats = vec![ColStats::default(); nb];
            let mut ws = Workspace::new();
            let g_batch = |block: &[f64], ids: &[usize], out: &mut [f64]| {
                for (p, &id) in ids.iter().enumerate() {
                    let (z, o) = (&block[p * d..(p + 1) * d], &mut out[p * d..(p + 1) * d]);
                    col_g(cs[id], &bs[id], z, o);
                }
            };
            picard_solve_batch(g_batch, &mut zs, d, tau, tol, max_iters, &mut ws, &mut stats);
            for j in 0..nb {
                let (z, rn, it) = picard_solve(
                    |z: &[f64], out: &mut [f64]| col_g(cs[j], &bs[j], z, out),
                    &z0s[j],
                    tau,
                    tol,
                    max_iters,
                );
                prop::ensure(zs[j * d..(j + 1) * d] == z[..], "batched z == sequential z")?;
                prop::ensure(stats[j].iters == it, &format!("iters {} vs {it}", stats[j].iters))?;
                prop::ensure(stats[j].residual == rn, "residual bits")?;
                prop::ensure(stats[j].converged, "converged")?;
            }
            Ok(())
        });
    }

    #[test]
    fn anderson_batch_matches_sequential_columns() {
        prop::check("anderson-batch-parity", 5, |rng| {
            let d = 10;
            let nb = 4;
            let m = 4;
            let beta = 1.0;
            let tol = 1e-9;
            let max_iters = 200;
            let cs: Vec<f64> = (0..nb).map(|j| 0.2 + 0.12 * j as f64).collect();
            let bs: Vec<Vec<f64>> = (0..nb).map(|_| rng.normal_vec(d)).collect();
            let mut zs = vec![0.0f64; nb * d];
            let mut stats = vec![ColStats::default(); nb];
            let mut ws = Workspace::new();
            let g_batch = |block: &[f64], ids: &[usize], out: &mut [f64]| {
                for (p, &id) in ids.iter().enumerate() {
                    let (z, o) = (&block[p * d..(p + 1) * d], &mut out[p * d..(p + 1) * d]);
                    col_g(cs[id], &bs[id], z, o);
                }
            };
            anderson_solve_batch(
                g_batch, &mut zs, d, m, beta, tol, max_iters, &mut ws, &mut stats,
            );
            let mut seq_ws = Workspace::new();
            for j in 0..nb {
                let (z, rn, it) = anderson_solve_ws(
                    |z: &[f64], out: &mut [f64]| col_g(cs[j], &bs[j], z, out),
                    &vec![0.0; d],
                    m,
                    tol,
                    max_iters,
                    beta,
                    &mut seq_ws,
                );
                prop::ensure(zs[j * d..(j + 1) * d] == z[..], "batched z == sequential z")?;
                prop::ensure(stats[j].iters == it, &format!("iters {} vs {it}", stats[j].iters))?;
                prop::ensure(stats[j].residual == rn, "residual bits")?;
            }
            Ok(())
        });
    }

    #[test]
    fn batch_retirement_handles_non_converging_columns() {
        // One divergence-free but slow column (c = 0.97) retired by
        // max_iters alongside fast ones: stats must mark it unconverged with
        // iters == max_iters, and the fast columns keep their exact counts.
        // (For this map ‖r_k‖ = |c|^k·‖r₀‖ exactly, so the fast columns
        // converge at iterations 7 and 9 — genuinely different retirement
        // points — while c = 0.97 cannot reach tol within the budget.)
        let d = 6;
        let cs = [0.15, 0.97, 0.25];
        let bs: Vec<Vec<f64>> = (0..3).map(|j| vec![0.5 + 0.2 * j as f64; d]).collect();
        let max_iters = 12;
        let tol = 1e-5;
        let mut zs = vec![0.0f64; 3 * d];
        let mut stats = vec![ColStats::default(); 3];
        let mut ws = Workspace::new();
        picard_solve_batch(
            |block: &[f64], ids: &[usize], out: &mut [f64]| {
                for (p, &id) in ids.iter().enumerate() {
                    let (z, o) = (&block[p * d..(p + 1) * d], &mut out[p * d..(p + 1) * d]);
                    col_g(cs[id], &bs[id], z, o);
                }
            },
            &mut zs,
            d,
            1.0,
            tol,
            max_iters,
            &mut ws,
            &mut stats,
        );
        assert!(!stats[1].converged);
        assert_eq!(stats[1].iters, max_iters);
        for j in [0usize, 2] {
            let (z, _, it) = picard_solve(
                |z: &[f64], out: &mut [f64]| col_g(cs[j], &bs[j], z, out),
                &vec![0.0; d],
                1.0,
                tol,
                max_iters,
            );
            assert_eq!(stats[j].iters, it, "col {j}");
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..], "col {j}");
        }
    }

    #[test]
    fn anderson_batch_reuse_is_deterministic() {
        // A persistent AndersonBatch driven across two batches must
        // reproduce the fresh-state result on the second batch (reset()
        // fully forgets the first solve).
        let d = 9;
        let nb = 3;
        let m = 3;
        let (tol, max_iters, beta) = (1e-9, 150, 1.0);
        let mut rng = Rng::new(77);
        let bs: Vec<Vec<f64>> = (0..nb).map(|_| rng.normal_vec(d)).collect();
        let g = |block: &[f64], ids: &[usize], out: &mut [f64]| {
            for (p, &id) in ids.iter().enumerate() {
                col_g(0.3, &bs[id], &block[p * d..(p + 1) * d], &mut out[p * d..(p + 1) * d]);
            }
        };
        let mut ws = Workspace::new();
        let mut batch = AndersonBatch::new(d, m, beta, nb, &mut ws);
        let mut stats = vec![ColStats::default(); nb];
        let mut zs1 = vec![0.0f64; nb * d];
        batch.solve(&g, &mut zs1, tol, max_iters, &mut ws, &mut stats);
        let iters1: Vec<usize> = stats.iter().map(|s| s.iters).collect();
        let mut zs2 = vec![0.0f64; nb * d];
        batch.solve(&g, &mut zs2, tol, max_iters, &mut ws, &mut stats);
        assert_eq!(zs1, zs2);
        assert_eq!(iters1, stats.iter().map(|s| s.iters).collect::<Vec<_>>());
        batch.release(&mut ws);
    }

    #[test]
    fn picard_batch_f32_matches_sequential() {
        // The f32 instantiation of the batched solver keeps the same
        // bit-parity guarantee against its own sequential runs.
        let d = 12;
        let nb = 3;
        let mut rng = Rng::new(5);
        let bs: Vec<Vec<f32>> = (0..nb).map(|_| rng.normal_vec_f32(d, 0.5)).collect();
        let g1 = |id: usize, z: &[f32], out: &mut [f32]| {
            for i in 0..d {
                out[i] = z[i] - 0.25 * z[(i + 1) % d] - bs[id][i];
            }
        };
        let mut zs = vec![0.0f32; nb * d];
        let mut stats = vec![ColStats::default(); nb];
        let mut ws: Workspace<f32> = Workspace::new();
        picard_solve_batch(
            |block: &[f32], ids: &[usize], out: &mut [f32]| {
                for (p, &id) in ids.iter().enumerate() {
                    g1(id, &block[p * d..(p + 1) * d], &mut out[p * d..(p + 1) * d]);
                }
            },
            &mut zs,
            d,
            1.0,
            1e-5,
            300,
            &mut ws,
            &mut stats,
        );
        for j in 0..nb {
            let (z, _, it) = picard_solve(
                |z: &[f32], out: &mut [f32]| g1(j, z, out),
                &vec![0.0f32; d],
                1.0,
                1e-5,
                300,
            );
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..], "col {j}");
            assert_eq!(stats[j].iters, it, "col {j}");
        }
    }

    #[test]
    fn solve_in_place_matches_direct() {
        // 3×3 system with known solution.
        let mut a = [2.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0];
        let x_true = [1.0, -2.0, 3.0];
        let mut b = [0.0; 3];
        for i in 0..3 {
            for j in 0..3 {
                b[i] += a[i * 3 + j] * x_true[j];
            }
        }
        assert!(solve_in_place(&mut a, 3, &mut b));
        for i in 0..3 {
            assert!((b[i] - x_true[i]).abs() < 1e-12, "x[{i}] = {}", b[i]);
        }
        // Singular system reports failure instead of NaNs.
        let mut s = [1.0, 2.0, 2.0, 4.0];
        let mut sb = [1.0, 2.0];
        assert!(!solve_in_place(&mut s, 2, &mut sb));
    }
}
