//! Root / fixed-point solvers for the DEQ forward pass.
//!
//! The primary solver is Broyden's method ([`broyden_solve`]) exactly as in
//! the DEQ line of work: limited memory, identity initialization, optional
//! derivative-free backtracking. It returns the final iterate *and* the qN
//! inverse estimate — the object SHINE shares with the backward pass.
//!
//! Residual evaluations use the write-into convention `g(z, out)` so the
//! solver loops are allocation-free: every iterate/residual/step buffer is
//! preallocated and double-buffered with `mem::swap`, and the qN update draws
//! its scratch from a [`Workspace`] (see `rust/tests/qn_alloc.rs` for the
//! counting-allocator proof). Use [`broyden_solve_ws`] to share one workspace
//! across many solves (the DEQ trainer does this across training steps).
//!
//! [`anderson_solve`] and [`picard_solve`] are baselines used in tests and
//! ablations.

use crate::linalg::vecops::{nrm2, sub};
use crate::qn::broyden::BroydenInverse;
use crate::qn::workspace::Workspace;
use crate::qn::MemoryPolicy;
use crate::solvers::Trace;
use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct FpOptions {
    /// Absolute tolerance on ‖g(z)‖ (the DEQ code stops on absolute residual
    /// scaled by √d; we expose the raw threshold).
    pub tol: f64,
    pub max_iters: usize,
    /// qN memory (paper: 30 for accelerated methods, Appendix C).
    pub memory: usize,
    pub policy: MemoryPolicy,
    /// Enable derivative-free backtracking line search.
    pub line_search: bool,
}

impl Default for FpOptions {
    fn default() -> Self {
        FpOptions {
            tol: 1e-8,
            max_iters: 200,
            memory: 30,
            policy: MemoryPolicy::Freeze,
            line_search: false,
        }
    }
}

#[derive(Debug)]
pub struct FpResult {
    pub z: Vec<f64>,
    pub g_norm: f64,
    pub iters: usize,
    pub converged: bool,
    /// Forward quasi-Newton estimate (H ≈ J_g⁻¹) — what SHINE reuses.
    pub qn: BroydenInverse,
    pub trace: Trace,
    /// Number of g evaluations (≠ iters when line search is active).
    pub n_g_evals: usize,
}

/// Broyden root solve of g(z) = 0 starting from `z0` (owns its workspace).
pub fn broyden_solve(
    g: impl FnMut(&[f64], &mut [f64]),
    z0: &[f64],
    opts: &FpOptions,
) -> FpResult {
    let mut ws = Workspace::new();
    broyden_solve_ws(g, z0, opts, &mut ws)
}

/// Broyden root solve with a caller-provided scratch arena. After the first
/// one or two iterations warm the workspace, the loop performs zero heap
/// allocations.
pub fn broyden_solve_ws(
    mut g: impl FnMut(&[f64], &mut [f64]),
    z0: &[f64],
    opts: &FpOptions,
    ws: &mut Workspace,
) -> FpResult {
    let d = z0.len();
    let sw = Stopwatch::start();
    let mut qn = BroydenInverse::new(d, opts.memory, opts.policy);
    let mut z = z0.to_vec();
    let mut gz = vec![0.0; d];
    g(&z, &mut gz);
    let mut n_g_evals = 1usize;
    let mut g_norm = nrm2(&gz);
    let mut trace = Trace::with_capacity(opts.max_iters.saturating_add(1).min(1 << 16));
    trace.push(g_norm, sw.elapsed());
    // All loop state is preallocated here; the iteration below only swaps.
    let mut p = vec![0.0; d];
    let mut z_new = vec![0.0; d];
    let mut g_new = vec![0.0; d];
    let mut s = vec![0.0; d];
    let mut y = vec![0.0; d];
    let mut zt = vec![0.0; d]; // line-search trial point
    let mut gt = vec![0.0; d]; // line-search trial residual
    let mut iters = 0;
    while g_norm > opts.tol && iters < opts.max_iters {
        qn.direction_ws(&gz, &mut p, ws);
        let alpha = if opts.line_search {
            let mut evals = 0usize;
            let a = crate::solvers::line_search::backtrack_residual(
                g_norm,
                |a| {
                    evals += 1;
                    for i in 0..d {
                        zt[i] = z[i] + a * p[i];
                    }
                    g(&zt[..], &mut gt[..]);
                    nrm2(&gt)
                },
                0.5,
                1e-4,
                8,
            );
            n_g_evals += evals;
            a
        } else {
            1.0
        };
        for i in 0..d {
            z_new[i] = z[i] + alpha * p[i];
        }
        g(&z_new, &mut g_new);
        n_g_evals += 1;
        sub(&z_new, &z, &mut s);
        sub(&g_new, &gz, &mut y);
        qn.update_ws(&s, &y, ws);
        std::mem::swap(&mut z, &mut z_new);
        std::mem::swap(&mut gz, &mut g_new);
        g_norm = nrm2(&gz);
        iters += 1;
        trace.push(g_norm, sw.elapsed());
    }
    FpResult {
        converged: g_norm <= opts.tol,
        z,
        g_norm,
        iters,
        qn,
        trace,
        n_g_evals,
    }
}

/// Damped Picard iteration z ← z − τ g(z) (baseline / pre-training warmup).
pub fn picard_solve(
    mut g: impl FnMut(&[f64], &mut [f64]),
    z0: &[f64],
    tau: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, f64, usize) {
    let d = z0.len();
    let mut z = z0.to_vec();
    let mut gz = vec![0.0; d];
    let mut iters = 0;
    loop {
        g(&z, &mut gz);
        let n = nrm2(&gz);
        if n <= tol || iters >= max_iters {
            return (z, n, iters);
        }
        for i in 0..d {
            z[i] -= tau * gz[i];
        }
        iters += 1;
    }
}

/// Anderson acceleration (type-II) on the fixed-point map  z ↦ z − g(z)
/// (owns its workspace).
pub fn anderson_solve(
    g: impl FnMut(&[f64], &mut [f64]),
    z0: &[f64],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
) -> (Vec<f64>, f64, usize) {
    let mut ws = Workspace::new();
    anderson_solve_ws(g, z0, m, tol, max_iters, beta, &mut ws)
}

/// Anderson acceleration with a caller-provided workspace. The iterate and
/// residual histories live in recycled buffers (O(1) eviction by rotating
/// the oldest buffer to the back); only the small k×k Gram system still
/// allocates per iteration.
pub fn anderson_solve_ws(
    mut g: impl FnMut(&[f64], &mut [f64]),
    z0: &[f64],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
    ws: &mut Workspace,
) -> (Vec<f64>, f64, usize) {
    let d = z0.len();
    let mut z = z0.to_vec();
    let mut r = vec![0.0; d];
    let mut z_next = vec![0.0; d];
    let mut hist_z: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
    let mut hist_r: Vec<Vec<f64>> = Vec::with_capacity(m + 1);
    // ΔR difference rows, reused across iterations.
    let mut dr: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut iters = 0;
    let rn = loop {
        g(&z, &mut r);
        let rn = nrm2(&r);
        if rn <= tol || iters >= max_iters {
            break rn;
        }
        // Append (z, r) to the history, recycling the evicted buffers.
        let (mut zb, mut rb) = if hist_z.len() >= m && !hist_z.is_empty() {
            (hist_z.remove(0), hist_r.remove(0))
        } else {
            (ws.take(d), ws.take(d))
        };
        zb.copy_from_slice(&z);
        rb.copy_from_slice(&r);
        hist_z.push(zb);
        hist_r.push(rb);
        let k = hist_z.len();
        // Solve min ‖Σ αᵢ rᵢ‖² s.t. Σ αᵢ = 1 via normal equations on
        // differences (small k×k dense system with Tikhonov damping).
        let alphas = if k == 1 {
            vec![1.0]
        } else {
            let kk = k - 1;
            while dr.len() < kk {
                dr.push(ws.take(d));
            }
            for (i, row) in dr.iter_mut().enumerate().take(kk) {
                sub(&hist_r[i + 1], &hist_r[i], row);
            }
            let mut gram = crate::linalg::dmat::DMat::zeros(kk, kk);
            let mut rhs = vec![0.0; kk];
            for i in 0..kk {
                for j in 0..kk {
                    gram[(i, j)] = crate::linalg::vecops::dot(&dr[i], &dr[j]);
                }
                gram[(i, i)] += 1e-10;
                rhs[i] = crate::linalg::vecops::dot(&dr[i], &hist_r[k - 1]);
            }
            let gamma = match crate::linalg::lu::Lu::factor(&gram) {
                Ok(lu) => lu.solve(&rhs),
                Err(_) => vec![0.0; kk],
            };
            // α from γ: α_i are the barycentric weights.
            let mut a = vec![0.0; k];
            a[k - 1] = 1.0;
            for i in 0..kk {
                a[i + 1] -= gamma[i];
                a[i] += gamma[i];
            }
            a
        };
        z_next.iter_mut().for_each(|v| *v = 0.0);
        for (i, alpha) in alphas.iter().enumerate() {
            // mixing: z⁺ = Σ αᵢ (zᵢ − β rᵢ)
            for j in 0..d {
                z_next[j] += alpha * (hist_z[i][j] - beta * hist_r[i][j]);
            }
        }
        std::mem::swap(&mut z, &mut z_next);
        iters += 1;
    };
    // Park the history buffers back in the pool so a shared workspace stays
    // warm across repeated solves.
    for b in hist_z.drain(..).chain(hist_r.drain(..)).chain(dr.drain(..)) {
        ws.give(b);
    }
    (z, rn, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Contractive test map: g(z) = z − (Az + b) with ‖A‖ < 1, evaluated
    /// allocation-free into the caller's buffer.
    fn contractive_g(
        rng: &mut Rng,
        n: usize,
    ) -> (impl Fn(&[f64], &mut [f64]), Vec<f64>) {
        let a = crate::linalg::dmat::DMat::randn(n, n, 0.3 / (n as f64).sqrt(), rng);
        let b = rng.normal_vec(n);
        // Fixed point solves (I − A) z = b.
        let mut ia = crate::linalg::dmat::DMat::eye(n);
        for i in 0..n {
            for j in 0..n {
                ia[(i, j)] -= a[(i, j)];
            }
        }
        let z_star = crate::linalg::lu::Lu::factor(&ia).unwrap().solve(&b);
        let g = move |z: &[f64], out: &mut [f64]| {
            a.matvec(z, out); // out = Az
            for i in 0..n {
                out[i] = z[i] - out[i] - b[i];
            }
        };
        (g, z_star)
    }

    #[test]
    fn broyden_finds_fixed_point() {
        prop::check("broyden-fp", 10, |rng| {
            let n = 5 + rng.below(20);
            let (g, z_star) = contractive_g(rng, n);
            let res = broyden_solve(g, &vec![0.0; n], &FpOptions::default());
            prop::ensure(res.converged, "converged")?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn broyden_beats_picard_iterations() {
        let mut rng = Rng::new(42);
        let n = 30;
        let (g, _) = contractive_g(&mut rng, n);
        let res = broyden_solve(&g, &vec![0.0; n], &FpOptions::default());
        let (_, _, picard_iters) = picard_solve(&g, &vec![0.0; n], 1.0, 1e-8, 500);
        assert!(
            res.iters < picard_iters,
            "broyden {} vs picard {picard_iters}",
            res.iters
        );
    }

    #[test]
    fn shared_workspace_reproduces_owned_run() {
        let mut rng = Rng::new(8);
        let n = 16;
        let (g, _) = contractive_g(&mut rng, n);
        let opts = FpOptions::default();
        let owned = broyden_solve(&g, &vec![0.0; n], &opts);
        let mut ws = Workspace::new();
        // Reusing one workspace across repeated solves must not change
        // results (buffers are re-zeroed on take).
        let first = broyden_solve_ws(&g, &vec![0.0; n], &opts, &mut ws);
        let second = broyden_solve_ws(&g, &vec![0.0; n], &opts, &mut ws);
        assert_eq!(owned.z, first.z);
        assert_eq!(first.z, second.z);
        assert_eq!(first.iters, second.iters);
    }

    #[test]
    fn line_search_variant_converges() {
        prop::check("broyden-fp-ls", 5, |rng| {
            let n = 10;
            let (g, z_star) = contractive_g(rng, n);
            let opts = FpOptions {
                line_search: true,
                ..FpOptions::default()
            };
            let res = broyden_solve(g, &vec![0.0; n], &opts);
            prop::ensure(res.converged, "converged")?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn anderson_converges() {
        prop::check("anderson-fp", 5, |rng| {
            let n = 12;
            let (g, z_star) = contractive_g(rng, n);
            let (z, rn, _) = anderson_solve(g, &vec![0.0; n], 5, 1e-9, 300, 1.0);
            prop::ensure(rn < 1e-8, &format!("residual {rn}"))?;
            prop::ensure_close_vec(&z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn trace_is_recorded() {
        let mut rng = Rng::new(3);
        let (g, _) = contractive_g(&mut rng, 8);
        let res = broyden_solve(g, &vec![0.0; 8], &FpOptions::default());
        assert_eq!(res.trace.len(), res.iters + 1);
        assert!(res.trace.residuals[0] >= res.trace.residuals[res.iters]);
    }

    #[test]
    fn respects_max_iters() {
        // g has no root: the solver must stop exactly at max_iters.
        let g = |z: &[f64], out: &mut [f64]| out[0] = z[0] * z[0] + 1.0;
        let opts = FpOptions {
            max_iters: 3,
            tol: 1e-300,
            ..Default::default()
        };
        let res = broyden_solve(g, &[0.0], &opts);
        assert_eq!(res.iters, 3);
        assert!(!res.converged);
    }
}
