//! Root / fixed-point solvers for the DEQ forward pass.
//!
//! The primary solver is Broyden's method ([`broyden_solve`]) exactly as in
//! the DEQ line of work: limited memory, identity initialization, optional
//! derivative-free backtracking. It returns the final iterate *and* the qN
//! inverse estimate — the object SHINE shares with the backward pass.
//!
//! Every solver here is generic over the storage precision
//! [`Elem`] (`f32` for the DEQ path, `f64` default elsewhere); residual
//! norms, mixing weights and the Anderson Gram system stay in f64 per the
//! crate's precision contract ([`crate::linalg::vecops`]).
//!
//! Residual evaluations use the write-into convention `g(z, out)` so the
//! solver loops are allocation-free: every iterate/residual/step buffer is
//! preallocated and double-buffered with `mem::swap`, and the qN update draws
//! its scratch from a [`Workspace`] (see `rust/tests/qn_alloc.rs` for the
//! counting-allocator proof). Use [`broyden_solve_ws`] to share one workspace
//! across many solves (the DEQ trainer does this across training steps).
//!
//! [`anderson_solve`] and [`picard_solve`] are baselines used in tests and
//! ablations. Since the incremental-Gram rework, [`anderson_solve_ws`] is
//! allocation-free per iteration too: the k×k Gram matrix persists in the
//! workspace's accumulator pool and is updated by a row/column shift per
//! evicted history entry plus one fresh row of dots — O(k·d) per iteration
//! instead of the old O(k²·d) rebuild — and the small solve runs in place
//! (no `DMat`/LU allocation).

use crate::linalg::vecops::{add_scaled, axpy, dot, nrm2, sub, zero, Elem};
use crate::qn::broyden::BroydenInverse;
use crate::qn::workspace::Workspace;
use crate::qn::MemoryPolicy;
use crate::solvers::Trace;
use crate::util::timer::Stopwatch;

#[derive(Clone, Debug)]
pub struct FpOptions {
    /// Absolute tolerance on ‖g(z)‖ (the DEQ code stops on absolute residual
    /// scaled by √d; we expose the raw threshold).
    pub tol: f64,
    pub max_iters: usize,
    /// qN memory (paper: 30 for accelerated methods, Appendix C).
    pub memory: usize,
    pub policy: MemoryPolicy,
    /// Enable derivative-free backtracking line search.
    pub line_search: bool,
}

impl Default for FpOptions {
    fn default() -> Self {
        FpOptions {
            tol: 1e-8,
            max_iters: 200,
            memory: 30,
            policy: MemoryPolicy::Freeze,
            line_search: false,
        }
    }
}

#[derive(Debug)]
pub struct FpResult<E: Elem = f64> {
    pub z: Vec<E>,
    pub g_norm: f64,
    pub iters: usize,
    pub converged: bool,
    /// Forward quasi-Newton estimate (H ≈ J_g⁻¹) — what SHINE reuses.
    pub qn: BroydenInverse<E>,
    pub trace: Trace,
    /// Number of g evaluations (≠ iters when line search is active).
    pub n_g_evals: usize,
}

/// Broyden root solve of g(z) = 0 starting from `z0` (owns its workspace).
pub fn broyden_solve<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    opts: &FpOptions,
) -> FpResult<E> {
    let mut ws = Workspace::new();
    broyden_solve_ws(g, z0, opts, &mut ws)
}

/// Broyden root solve with a caller-provided scratch arena. After the first
/// one or two iterations warm the workspace, the loop performs zero heap
/// allocations.
pub fn broyden_solve_ws<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    opts: &FpOptions,
    ws: &mut Workspace<E>,
) -> FpResult<E> {
    let d = z0.len();
    let sw = Stopwatch::start();
    let mut qn = BroydenInverse::new(d, opts.memory, opts.policy);
    let mut z = z0.to_vec();
    let mut gz = vec![E::ZERO; d];
    g(&z, &mut gz);
    let mut n_g_evals = 1usize;
    let mut g_norm = nrm2(&gz);
    let mut trace = Trace::with_capacity(opts.max_iters.saturating_add(1).min(1 << 16));
    trace.push(g_norm, sw.elapsed());
    // All loop state is preallocated here; the iteration below only swaps.
    let mut p = vec![E::ZERO; d];
    let mut z_new = vec![E::ZERO; d];
    let mut g_new = vec![E::ZERO; d];
    let mut s = vec![E::ZERO; d];
    let mut y = vec![E::ZERO; d];
    let mut zt = vec![E::ZERO; d]; // line-search trial point
    let mut gt = vec![E::ZERO; d]; // line-search trial residual
    let mut iters = 0;
    while g_norm > opts.tol && iters < opts.max_iters {
        qn.direction_ws(&gz, &mut p, ws);
        let alpha = if opts.line_search {
            let mut evals = 0usize;
            let a = crate::solvers::line_search::backtrack_residual(
                g_norm,
                |a| {
                    evals += 1;
                    add_scaled(&z, a, &p, &mut zt);
                    g(&zt[..], &mut gt[..]);
                    nrm2(&gt)
                },
                0.5,
                1e-4,
                8,
            );
            n_g_evals += evals;
            a
        } else {
            1.0
        };
        add_scaled(&z, alpha, &p, &mut z_new);
        g(&z_new, &mut g_new);
        n_g_evals += 1;
        sub(&z_new, &z, &mut s);
        sub(&g_new, &gz, &mut y);
        qn.update_ws(&s, &y, ws);
        std::mem::swap(&mut z, &mut z_new);
        std::mem::swap(&mut gz, &mut g_new);
        g_norm = nrm2(&gz);
        iters += 1;
        trace.push(g_norm, sw.elapsed());
    }
    FpResult {
        converged: g_norm <= opts.tol,
        z,
        g_norm,
        iters,
        qn,
        trace,
        n_g_evals,
    }
}

/// Damped Picard iteration z ← z − τ g(z) (baseline / pre-training warmup).
pub fn picard_solve<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    tau: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<E>, f64, usize) {
    let d = z0.len();
    let mut z = z0.to_vec();
    let mut gz = vec![E::ZERO; d];
    let mut iters = 0;
    loop {
        g(&z, &mut gz);
        let n = nrm2(&gz);
        if n <= tol || iters >= max_iters {
            return (z, n, iters);
        }
        axpy(-tau, &gz, &mut z);
        iters += 1;
    }
}

/// Anderson acceleration (type-II) on the fixed-point map  z ↦ z − g(z)
/// (owns its workspace).
pub fn anderson_solve<E: Elem>(
    g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
) -> (Vec<E>, f64, usize) {
    let mut ws = Workspace::new();
    anderson_solve_ws(g, z0, m, tol, max_iters, beta, &mut ws)
}

/// Anderson acceleration with a caller-provided workspace — allocation-free
/// per iteration once the workspace is warm:
///
/// * the iterate/residual histories live in recycled buffers (O(1) eviction
///   by rotating the oldest buffer to the back);
/// * the k×k Gram matrix of the ΔR difference rows **persists across
///   iterations** in the workspace's f64 accumulator pool — evicting the
///   oldest history entry shifts it one row+column up-left in place, and
///   each iteration appends a single fresh row/column of dots (O(k·d)
///   instead of rebuilding all k² entries);
/// * the damped normal-equation solve runs by in-place Gaussian elimination
///   on a workspace scratch copy — no `DMat`/LU allocation.
pub fn anderson_solve_ws<E: Elem>(
    mut g: impl FnMut(&[E], &mut [E]),
    z0: &[E],
    m: usize,
    tol: f64,
    max_iters: usize,
    beta: f64,
    ws: &mut Workspace<E>,
) -> (Vec<E>, f64, usize) {
    let d = z0.len();
    let mut z = z0.to_vec();
    let mut r = vec![E::ZERO; d];
    let mut z_next = vec![E::ZERO; d];
    let mut hist_z: Vec<Vec<E>> = Vec::with_capacity(m + 1);
    let mut hist_r: Vec<Vec<E>> = Vec::with_capacity(m + 1);
    // ΔR difference rows (logical oldest → newest), at most m−1 live.
    let mut dr: Vec<Vec<E>> = Vec::with_capacity(m);
    let mut ndr = 0usize;
    // Persistent small-system scratch (f64 accumulator pool). `gs` is the
    // Gram stride; give-backs below run in reverse take order so the pool
    // hands the same capacities back on the next solve.
    let gs = m.max(1);
    let mut gram = ws.take_acc(gs * gs);
    let mut lu = ws.take_acc(gs * gs);
    let mut rhs = ws.take_acc(gs);
    let mut alphas = ws.take_acc(gs + 1);
    let mut iters = 0;
    let rn = loop {
        g(&z, &mut r);
        let rn = nrm2(&r);
        if rn <= tol || iters >= max_iters {
            break rn;
        }
        // --- incremental ΔR / Gram maintenance (only defined for m ≥ 2).
        if m >= 2 && !hist_r.is_empty() {
            if ndr + 1 >= m {
                // The oldest history entry is about to be evicted: drop ΔR₀
                // by shifting the Gram block up-left and rotating the row
                // buffer to the back for reuse as the new newest row.
                for i in 1..ndr {
                    for j in 1..ndr {
                        gram[(i - 1) * gs + (j - 1)] = gram[i * gs + j];
                    }
                }
                dr[..ndr].rotate_left(1);
                ndr -= 1;
            }
            if dr.len() == ndr {
                dr.push(ws.take(d));
            }
            // ΔR_new = r − r_prev (the history still ends at r_prev here).
            let prev = hist_r.last().unwrap();
            sub(&r, prev, &mut dr[ndr]);
            for j in 0..ndr {
                let gij = dot(&dr[ndr], &dr[j]);
                gram[ndr * gs + j] = gij;
                gram[j * gs + ndr] = gij;
            }
            gram[ndr * gs + ndr] = dot(&dr[ndr], &dr[ndr]);
            ndr += 1;
        }
        // --- append (z, r) to the history, recycling the evicted buffers.
        let (mut zb, mut rb) = if hist_z.len() >= m && !hist_z.is_empty() {
            (hist_z.remove(0), hist_r.remove(0))
        } else {
            (ws.take(d), ws.take(d))
        };
        zb.copy_from_slice(&z);
        rb.copy_from_slice(&r);
        hist_z.push(zb);
        hist_r.push(rb);
        let k = hist_z.len();
        debug_assert!(m < 2 || ndr == k - 1);
        // --- solve min ‖Σ αᵢ rᵢ‖² s.t. Σ αᵢ = 1 via the damped normal
        // equations on the persistent Gram (solution γ lands in `rhs`).
        let kk = ndr;
        for a in alphas.iter_mut().take(k) {
            *a = 0.0;
        }
        alphas[k - 1] = 1.0;
        if kk > 0 {
            for i in 0..kk {
                for j in 0..kk {
                    lu[i * kk + j] = gram[i * gs + j];
                }
                lu[i * kk + i] += 1e-10;
                rhs[i] = dot(&dr[i], &r);
            }
            if solve_in_place(&mut lu[..kk * kk], kk, &mut rhs[..kk]) {
                // α from γ: barycentric weights (singular systems keep the
                // plain-mixing fallback α = e_{k−1}).
                for i in 0..kk {
                    alphas[i + 1] -= rhs[i];
                    alphas[i] += rhs[i];
                }
            }
        }
        // --- mixing: z⁺ = Σ αᵢ (zᵢ − β rᵢ), accumulated in f64.
        zero(&mut z_next);
        for i in 0..k {
            let a = alphas[i];
            if a != 0.0 {
                for j in 0..d {
                    z_next[j] = E::from_f64(
                        z_next[j].to_f64()
                            + a * (hist_z[i][j].to_f64() - beta * hist_r[i][j].to_f64()),
                    );
                }
            }
        }
        std::mem::swap(&mut z, &mut z_next);
        iters += 1;
    };
    // Park every buffer back in the pools so a shared workspace stays warm
    // across repeated solves (acc buffers in reverse take order).
    for b in hist_z.drain(..).chain(hist_r.drain(..)).chain(dr.drain(..)) {
        ws.give(b);
    }
    ws.give_acc(alphas);
    ws.give_acc(rhs);
    ws.give_acc(lu);
    ws.give_acc(gram);
    (z, rn, iters)
}

/// In-place Gaussian elimination with partial pivoting on a dense row-major
/// `n×n` system; the solution overwrites `b`. Returns false on a vanishing
/// pivot (caller falls back to plain mixing). Allocation-free — this is the
/// small Anderson Gram system, k ≤ m.
fn solve_in_place(a: &mut [f64], n: usize, b: &mut [f64]) -> bool {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    for col in 0..n {
        let mut piv = col;
        let mut best = a[col * n + col].abs();
        for row in col + 1..n {
            let v = a[row * n + col].abs();
            if v > best {
                best = v;
                piv = row;
            }
        }
        if !best.is_finite() || !(best > 1e-300) {
            return false;
        }
        if piv != col {
            for j in 0..n {
                a.swap(col * n + j, piv * n + j);
            }
            b.swap(col, piv);
        }
        let inv = 1.0 / a[col * n + col];
        for row in col + 1..n {
            let f = a[row * n + col] * inv;
            if f != 0.0 {
                for j in col..n {
                    a[row * n + j] -= f * a[col * n + j];
                }
                b[row] -= f * b[col];
            }
        }
    }
    for row in (0..n).rev() {
        let mut acc = b[row];
        for j in row + 1..n {
            acc -= a[row * n + j] * b[j];
        }
        b[row] = acc / a[row * n + row];
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    /// Contractive test map: g(z) = z − (Az + b) with ‖A‖ < 1, evaluated
    /// allocation-free into the caller's buffer.
    fn contractive_g(rng: &mut Rng, n: usize) -> (impl Fn(&[f64], &mut [f64]), Vec<f64>) {
        let a = crate::linalg::dmat::DMat::randn(n, n, 0.3 / (n as f64).sqrt(), rng);
        let b = rng.normal_vec(n);
        // Fixed point solves (I − A) z = b.
        let mut ia = crate::linalg::dmat::DMat::eye(n);
        for i in 0..n {
            for j in 0..n {
                ia[(i, j)] -= a[(i, j)];
            }
        }
        let z_star = crate::linalg::lu::Lu::factor(&ia).unwrap().solve(&b);
        let g = move |z: &[f64], out: &mut [f64]| {
            a.matvec(z, out); // out = Az
            for i in 0..n {
                out[i] = z[i] - out[i] - b[i];
            }
        };
        (g, z_star)
    }

    #[test]
    fn broyden_finds_fixed_point() {
        prop::check("broyden-fp", 10, |rng| {
            let n = 5 + rng.below(20);
            let (g, z_star) = contractive_g(rng, n);
            let res = broyden_solve(g, &vec![0.0; n], &FpOptions::default());
            prop::ensure(res.converged, "converged")?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn broyden_beats_picard_iterations() {
        let mut rng = Rng::new(42);
        let n = 30;
        let (g, _) = contractive_g(&mut rng, n);
        let res = broyden_solve(&g, &vec![0.0; n], &FpOptions::default());
        let (_, _, picard_iters) = picard_solve(&g, &vec![0.0; n], 1.0, 1e-8, 500);
        assert!(
            res.iters < picard_iters,
            "broyden {} vs picard {picard_iters}",
            res.iters
        );
    }

    #[test]
    fn shared_workspace_reproduces_owned_run() {
        let mut rng = Rng::new(8);
        let n = 16;
        let (g, _) = contractive_g(&mut rng, n);
        let opts = FpOptions::default();
        let owned = broyden_solve(&g, &vec![0.0; n], &opts);
        let mut ws = Workspace::new();
        // Reusing one workspace across repeated solves must not change
        // results (buffers are re-zeroed on take).
        let first = broyden_solve_ws(&g, &vec![0.0; n], &opts, &mut ws);
        let second = broyden_solve_ws(&g, &vec![0.0; n], &opts, &mut ws);
        assert_eq!(owned.z, first.z);
        assert_eq!(first.z, second.z);
        assert_eq!(first.iters, second.iters);
    }

    #[test]
    fn f32_broyden_converges_on_contractive_map() {
        // The f32 instantiation must reach an f32-appropriate residual on
        // the same map (full parity with the f64 reference is covered by
        // rust/tests/precision_parity.rs).
        let mut rng = Rng::new(12);
        let n = 16;
        let (g, z_star) = contractive_g(&mut rng, n);
        let g32 = |z: &[f32], out: &mut [f32]| {
            let z64: Vec<f64> = z.iter().map(|&x| x as f64).collect();
            let mut o64 = vec![0.0; z.len()];
            g(&z64, &mut o64);
            for (o, &v) in out.iter_mut().zip(o64.iter()) {
                *o = v as f32;
            }
        };
        let opts = FpOptions {
            tol: 1e-4,
            ..Default::default()
        };
        let res = broyden_solve(g32, &vec![0.0f32; n], &opts);
        assert!(res.converged, "|g|={}", res.g_norm);
        for i in 0..n {
            assert!(
                (res.z[i] as f64 - z_star[i]).abs() < 1e-3 * (1.0 + z_star[i].abs()),
                "idx {i}: {} vs {}",
                res.z[i],
                z_star[i]
            );
        }
    }

    #[test]
    fn line_search_variant_converges() {
        prop::check("broyden-fp-ls", 5, |rng| {
            let n = 10;
            let (g, z_star) = contractive_g(rng, n);
            let opts = FpOptions {
                line_search: true,
                ..FpOptions::default()
            };
            let res = broyden_solve(g, &vec![0.0; n], &opts);
            prop::ensure(res.converged, "converged")?;
            prop::ensure_close_vec(&res.z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn anderson_converges() {
        prop::check("anderson-fp", 5, |rng| {
            let n = 12;
            let (g, z_star) = contractive_g(rng, n);
            let (z, rn, _) = anderson_solve(g, &vec![0.0; n], 5, 1e-9, 300, 1.0);
            prop::ensure(rn < 1e-8, &format!("residual {rn}"))?;
            prop::ensure_close_vec(&z, &z_star, 1e-5, "fixed point")
        });
    }

    #[test]
    fn anderson_incremental_gram_matches_small_histories() {
        // The incremental Gram must behave exactly like the full rebuild it
        // replaced: runs with different history sizes still converge to the
        // same fixed point, and a shared workspace reproduces an owned run.
        prop::check("anderson-incr-gram", 5, |rng| {
            let n = 10;
            let (g, z_star) = contractive_g(rng, n);
            let mut ws = Workspace::new();
            for m in [1usize, 2, 3, 6] {
                let (z, rn, _) = anderson_solve_ws(&g, &vec![0.0; n], m, 1e-9, 400, 1.0, &mut ws);
                prop::ensure(rn < 1e-8, &format!("m={m} residual {rn}"))?;
                prop::ensure_close_vec(&z, &z_star, 1e-5, "fixed point (shared ws)")?;
            }
            Ok(())
        });
    }

    #[test]
    fn trace_is_recorded() {
        let mut rng = Rng::new(3);
        let (g, _) = contractive_g(&mut rng, 8);
        let res = broyden_solve(g, &vec![0.0; 8], &FpOptions::default());
        assert_eq!(res.trace.len(), res.iters + 1);
        assert!(res.trace.residuals[0] >= res.trace.residuals[res.iters]);
    }

    #[test]
    fn respects_max_iters() {
        // g has no root: the solver must stop exactly at max_iters.
        let g = |z: &[f64], out: &mut [f64]| out[0] = z[0] * z[0] + 1.0;
        let opts = FpOptions {
            max_iters: 3,
            tol: 1e-300,
            ..Default::default()
        };
        let res = broyden_solve(g, &[0.0], &opts);
        assert_eq!(res.iters, 3);
        assert!(!res.converged);
    }

    #[test]
    fn solve_in_place_matches_direct() {
        // 3×3 system with known solution.
        let mut a = [2.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 2.0];
        let x_true = [1.0, -2.0, 3.0];
        let mut b = [0.0; 3];
        for i in 0..3 {
            for j in 0..3 {
                b[i] += a[i * 3 + j] * x_true[j];
            }
        }
        assert!(solve_in_place(&mut a, 3, &mut b));
        for i in 0..3 {
            assert!((b[i] - x_true[i]).abs() < 1e-12, "x[{i}] = {}", b[i]);
        }
        // Singular system reports failure instead of NaNs.
        let mut s = [1.0, 2.0, 2.0, 4.0];
        let mut sb = [1.0, 2.0];
        assert!(!solve_in_place(&mut s, 2, &mut sb));
    }
}
