//! Backward-pass linear solvers: the "iterative inversion of a huge Jacobian"
//! that SHINE is designed to avoid (the *Original* / HOAG baseline), and the
//! warm-startable variants that implement the *refine* strategy.
//!
//! Two cases, as in the paper:
//! * symmetric `J` (bi-level optimization: `J` is the inner Hessian) —
//!   conjugate gradient, as in HOAG (Pedregosa 2016);
//! * general `J` (DEQ) — Broyden's method on the linear residual
//!   `r(w) = Jᵀ w − c`, driven by vector–Jacobian products, as in the DEQ
//!   implementation of Bai et al.
//!
//! Both solvers are generic over the storage precision [`Elem`]: the DEQ
//! trainer runs them at `f32` against the artifact VJPs (no boundary casts),
//! the bi-level/HOAG stack at the `f64` default. CG scalars (α, β, residual
//! norms) are always f64 reductions.
//!
//! Operators use the write-into convention (`apply_a(x, out)` / `vjp(w, out)`)
//! and both solvers preallocate their loop state, so iterations are
//! allocation-free apart from whatever the operator itself does.

use crate::linalg::vecops::{add, axpy, dot, nrm2, sub, Elem};
use crate::qn::broyden::BroydenInverse;
use crate::qn::low_rank::LowRank;
use crate::qn::workspace::Workspace;
use crate::qn::MemoryPolicy;

#[derive(Debug)]
pub struct LinSolveResult<E: Elem = f64> {
    pub x: Vec<E>,
    pub residual: f64,
    pub iters: usize,
    pub converged: bool,
    /// Matrix–vector products consumed (the paper's backward-cost unit).
    pub n_matvecs: usize,
}

/// Conjugate gradient for SPD systems A x = b.
///
/// `x0` warm start (HOAG warm-restarts the Hessian inversion across outer
/// iterations, Appendix C). Stops on ‖Ax − b‖ ≤ tol or `max_iters`.
pub fn cg_solve<E: Elem>(
    mut apply_a: impl FnMut(&[E], &mut [E]),
    b: &[E],
    x0: Option<&[E]>,
    tol: f64,
    max_iters: usize,
) -> LinSolveResult<E> {
    let n = b.len();
    let mut x = x0.map(|v| v.to_vec()).unwrap_or_else(|| vec![E::ZERO; n]);
    let mut ap = vec![E::ZERO; n];
    apply_a(&x, &mut ap);
    let mut n_matvecs = 1;
    let mut r = vec![E::ZERO; n];
    sub(b, &ap, &mut r);
    let mut p = r.clone();
    let mut rs = dot(&r, &r);
    let mut iters = 0;
    while rs.sqrt() > tol && iters < max_iters {
        apply_a(&p, &mut ap);
        n_matvecs += 1;
        let p_ap = dot(&p, &ap);
        if p_ap <= 0.0 {
            break; // not SPD numerically; bail with current iterate
        }
        let alpha = rs / p_ap;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = E::from_f64(r[i].to_f64() + beta * p[i].to_f64());
        }
        rs = rs_new;
        iters += 1;
    }
    LinSolveResult {
        converged: rs.sqrt() <= tol,
        residual: rs.sqrt(),
        x,
        iters,
        n_matvecs,
    }
}

/// Broyden solve of the left-inversion system `Jᵀ w = c` given a VJP oracle
/// `vjp(w, out)` writing `Jᵀ w` (one VJP per iteration — the expensive unit
/// of the DEQ backward pass). Owns its workspace; see
/// [`broyden_solve_left_ws`] to share one across backward passes.
///
/// * `w0` — warm start for the iterate (refine: `B⁻ᵀ∇L`; HOAG: previous w).
/// * `h_init` — warm start for the qN *matrix* (refine strategy: the
///   transposed forward estimate, since (Jᵀ)⁻¹ = (J⁻¹)ᵀ ≈ Hᵀ).
#[allow(clippy::too_many_arguments)]
pub fn broyden_solve_left<E: Elem>(
    vjp: impl FnMut(&[E], &mut [E]),
    c: &[E],
    w0: Option<&[E]>,
    h_init: Option<LowRank<E>>,
    tol: f64,
    max_iters: usize,
    memory: usize,
) -> LinSolveResult<E> {
    let mut ws = Workspace::new();
    broyden_solve_left_ws(vjp, c, w0, h_init, tol, max_iters, memory, &mut ws)
}

/// [`broyden_solve_left`] with a caller-provided scratch arena.
#[allow(clippy::too_many_arguments)]
pub fn broyden_solve_left_ws<E: Elem>(
    mut vjp: impl FnMut(&[E], &mut [E]),
    c: &[E],
    w0: Option<&[E]>,
    h_init: Option<LowRank<E>>,
    tol: f64,
    max_iters: usize,
    memory: usize,
    ws: &mut Workspace<E>,
) -> LinSolveResult<E> {
    let n = c.len();
    let mut qn = match h_init {
        Some(h) => BroydenInverse::from_low_rank(
            h.with_max_mem(memory + max_iters, MemoryPolicy::Freeze),
        ),
        None => BroydenInverse::new(n, memory, MemoryPolicy::Freeze),
    };
    let mut w = w0.map(|v| v.to_vec()).unwrap_or_else(|| vec![E::ZERO; n]);
    let mut jw = vec![E::ZERO; n];
    vjp(&w, &mut jw);
    let mut n_matvecs = 1;
    let mut r = vec![E::ZERO; n];
    sub(&jw, c, &mut r);
    let mut r_norm = nrm2(&r);
    let mut p = vec![E::ZERO; n];
    let mut w_new = vec![E::ZERO; n];
    let mut r_new = vec![E::ZERO; n];
    let mut s = vec![E::ZERO; n];
    let mut y = vec![E::ZERO; n];
    let mut iters = 0;
    while r_norm > tol && iters < max_iters {
        qn.direction_ws(&r, &mut p, ws);
        add(&w, &p, &mut w_new);
        vjp(&w_new, &mut jw);
        n_matvecs += 1;
        sub(&jw, c, &mut r_new);
        sub(&w_new, &w, &mut s);
        sub(&r_new, &r, &mut y);
        qn.update_ws(&s, &y, ws);
        std::mem::swap(&mut w, &mut w_new);
        std::mem::swap(&mut r, &mut r_new);
        r_norm = nrm2(&r);
        iters += 1;
    }
    LinSolveResult {
        converged: r_norm <= tol,
        residual: r_norm,
        x: w,
        iters,
        n_matvecs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dmat::DMat;
    use crate::linalg::lu::Lu;
    use crate::qn::InvOp;
    use crate::util::prop;

    #[test]
    fn cg_solves_spd() {
        prop::check("cg-spd", 15, |rng| {
            let n = 4 + rng.below(20);
            let a = DMat::random_spd(n, 0.5, 10.0, rng);
            let x_true = rng.normal_vec(n);
            let mut b = vec![0.0; n];
            a.matvec(&x_true, &mut b);
            let res = cg_solve(
                |v: &[f64], out: &mut [f64]| a.matvec(v, out),
                &b,
                None,
                1e-10,
                10 * n,
            );
            prop::ensure(res.converged, "cg converged")?;
            prop::ensure_close_vec(&res.x, &x_true, 1e-6, "solution")
        });
    }

    #[test]
    fn cg_warm_start_helps() {
        let mut rng = crate::util::rng::Rng::new(4);
        let n = 30;
        let a = DMat::random_spd(n, 0.5, 50.0, &mut rng);
        let x_true = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        a.matvec(&x_true, &mut b);
        let apply = |v: &[f64], out: &mut [f64]| a.matvec(v, out);
        let cold = cg_solve(apply, &b, None, 1e-9, 500);
        // Warm start near the solution.
        let near: Vec<f64> = x_true.iter().map(|&x| x + 1e-4).collect();
        let warm = cg_solve(apply, &b, Some(&near), 1e-9, 500);
        assert!(warm.iters <= cold.iters);
    }

    #[test]
    fn broyden_left_solves_general() {
        prop::check("broyden-left", 10, |rng| {
            let n = 5 + rng.below(10);
            // Well-conditioned nonsymmetric J.
            let mut j = DMat::randn(n, n, 0.3 / (n as f64).sqrt(), rng);
            for i in 0..n {
                j[(i, i)] += 1.0;
            }
            let c = rng.normal_vec(n);
            let res = broyden_solve_left(
                |w: &[f64], out: &mut [f64]| j.matvec_t(w, out),
                &c,
                None,
                None,
                1e-9,
                40 * n,
                200,
            );
            prop::ensure(res.converged, &format!("residual={}", res.residual))?;
            let want = Lu::factor(&j).unwrap().solve_t(&c);
            prop::ensure_close_vec(&res.x, &want, 1e-5, "w = J⁻ᵀ c")
        });
    }

    #[test]
    fn warm_qn_matrix_accelerates() {
        // Refine strategy claim: initializing the backward solver's qN matrix
        // from the forward estimate reduces iterations.
        let mut rng = crate::util::rng::Rng::new(9);
        let n = 25;
        let mut j = DMat::randn(n, n, 0.25 / (n as f64).sqrt(), &mut rng);
        for i in 0..n {
            j[(i, i)] += 1.0;
        }
        let c = rng.normal_vec(n);
        let vjp = |w: &[f64], out: &mut [f64]| j.matvec_t(w, out);
        let cold = broyden_solve_left(vjp, &c, None, None, 1e-9, 500, 200);
        assert!(cold.converged);
        // Build a forward-like estimate of J⁻¹ by running Broyden on the
        // *right* system J z = b for some b, then transpose it (O(1) panel
        // swap on a clone of the forward estimate).
        let b = rng.normal_vec(n);
        let fwd = crate::solvers::fixed_point::broyden_solve(
            |z: &[f64], out: &mut [f64]| {
                j.matvec(z, out);
                for i in 0..n {
                    out[i] -= b[i];
                }
            },
            &vec![0.0; n],
            &crate::solvers::fixed_point::FpOptions {
                tol: 1e-10,
                max_iters: 300,
                memory: 300,
                ..Default::default()
            },
        );
        assert!(fwd.converged);
        let h_t = fwd.qn.low_rank().clone().into_transposed();
        let w0 = h_t.apply_vec(&c);
        let warm = broyden_solve_left(vjp, &c, Some(&w0), Some(h_t), 1e-9, 500, 200);
        assert!(warm.converged);
        assert!(
            warm.iters <= cold.iters,
            "warm {} vs cold {}",
            warm.iters,
            cold.iters
        );
    }
}
