//! Synthetic DEQ-shaped serving workload for the throughput bench and the
//! `serve-bench` CLI: a contractive block-dense fixed-point map whose
//! batched residual has the same cost profile as the native DEQ block
//! (dense per-row mixing, one thread fan-out per batched evaluation).

use crate::linalg::vecops::Elem;
use crate::util::rng::Rng;
use crate::util::threads;

/// Contractive fixed-point model g(z) = z − tanh(W_blk z + b): the state
/// splits into `d / s` blocks of width `s`, each mixed by one shared dense
/// `s × s` matrix (cache-hot) and passed through tanh. The matrix is scaled
/// so the map's Jacobian norm stays ≈ 0.5 — Picard with τ = 1 contracts at
/// ~0.5/iteration toward the map's unique fixed point (requests differ in
/// initial iterate and cotangent, the realistic shape for a shared-model
/// serving tier).
///
/// The residual depends only on a column's own values and its position
/// inside the column, so batched evaluation over any compaction permutation
/// is well-defined without per-request context (the ids slice of the
/// batched closure is unused here).
pub struct SynthDeq<E: Elem> {
    d: usize,
    /// Dense mixing block width.
    s: usize,
    /// Shared `s × s` mixing matrix, row-major.
    w: Vec<E>,
    /// Per-position bias (length d).
    bias: Vec<E>,
    /// Thread-sharding threshold in block elements: a single request's
    /// column usually sits below it (serial eval), a B-wide block crosses
    /// it — which is exactly the batching win the bench measures.
    par_min: usize,
}

impl<E: Elem> SynthDeq<E> {
    pub fn new(d: usize, s: usize, seed: u64) -> SynthDeq<E> {
        assert!(s >= 1 && d % s == 0, "block width must divide d");
        let mut rng = Rng::new(seed ^ 0x5E2F);
        // Spectral norm of an s×s matrix with N(0, σ²) entries ≈ 2σ√s;
        // σ = 0.25/√s keeps it near 0.5.
        let sigma = 0.25 / (s as f64).sqrt();
        let w = (0..s * s).map(|_| E::from_f64(rng.normal() * sigma)).collect();
        let bias = (0..d).map(|_| E::from_f64(rng.normal() * 0.3)).collect();
        SynthDeq {
            d,
            s,
            w,
            bias,
            par_min: 1 << 15,
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Batched residual over `k` stacked columns — the closure body the
    /// batched solvers evaluate once per iteration. One parallel region for
    /// the whole block (whole `s`-rows per worker); per-row f64 accumulation
    /// makes the result identical at any worker count, so batched and
    /// sequential serving agree bit-for-bit.
    pub fn residual_batch(&self, zs: &[E], k: usize, out: &mut [E]) {
        debug_assert_eq!(zs.len(), k * self.d);
        debug_assert_eq!(out.len(), k * self.d);
        let s = self.s;
        let d = self.d;
        let workers = threads::workers_for(k * d, self.par_min, 16);
        threads::par_row_chunks_mut(out, s, workers, |row0, chunk| {
            for (bi, orow) in chunk.chunks_exact_mut(s).enumerate() {
                let off = (row0 + bi) * s;
                let zrow = &zs[off..off + s];
                // Bias indexes by position within the column (blocks never
                // straddle columns since s divides d).
                let boff = off % d;
                let brow = &self.bias[boff..boff + s];
                for i in 0..s {
                    let mut acc = brow[i].to_f64();
                    for j in 0..s {
                        acc += self.w[i * s + j].to_f64() * zrow[j].to_f64();
                    }
                    orow[i] = E::from_f64(zrow[i].to_f64() - acc.tanh());
                }
            }
        });
    }
}

/// One scheduled model misbehaviour, keyed to a request id by a
/// [`FaultPlan`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// The residual evaluation panics (on the shard worker's thread — the
    /// supervision trigger).
    Panic,
    /// The faulted request's residual column fills with NaN (only its own
    /// column: batched neighbours stay clean, which is what the per-column
    /// outcome classification and chaos parity rely on).
    Nan,
    /// The evaluation sleeps `delay_s` before returning correct values — a
    /// straggler. Value-neutral, so a straggled request still matches the
    /// fault-free reference bit-for-bit.
    Straggle { delay_s: f64 },
}

/// A seeded, replayable chaos schedule: which request ids misbehave and
/// how. The plan is pure data keyed by caller request id — replaying the
/// same seed against the same workload injects the identical faults no
/// matter how requests batch, shard, or interleave, which is what makes
/// the chaos harness deterministic.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// `(request id, fault)`, sorted by id.
    faults: Vec<(usize, Fault)>,
}

impl FaultPlan {
    /// Sample a plan over request ids `0..total`: `panics` + `nans` +
    /// `straggles` distinct victims (must fit in `total`), assignment and
    /// placement fully determined by `seed`.
    pub fn seeded(
        seed: u64,
        total: usize,
        panics: usize,
        nans: usize,
        straggles: usize,
    ) -> FaultPlan {
        let n = panics + nans + straggles;
        assert!(n <= total, "more faults than requests");
        let mut rng = Rng::new(seed ^ 0xFA17);
        let victims = rng.choose_k(total, n);
        let mut faults: Vec<(usize, Fault)> = victims
            .iter()
            .enumerate()
            .map(|(i, &id)| {
                let f = if i < panics {
                    Fault::Panic
                } else if i < panics + nans {
                    Fault::Nan
                } else {
                    Fault::Straggle {
                        delay_s: rng.uniform_in(0.5e-3, 2e-3),
                    }
                };
                (id, f)
            })
            .collect();
        faults.sort_by_key(|(id, _)| *id);
        FaultPlan { faults }
    }

    /// An explicit plan (tests that want exact placement).
    pub fn from_faults(mut faults: Vec<(usize, Fault)>) -> FaultPlan {
        faults.sort_by_key(|(id, _)| *id);
        FaultPlan { faults }
    }

    /// The fault scheduled for `id`, if any.
    pub fn fault(&self, id: usize) -> Option<Fault> {
        self.faults
            .binary_search_by_key(&id, |(i, _)| *i)
            .ok()
            .map(|p| self.faults[p].1)
    }

    /// Scheduled faults in id order.
    pub fn faults(&self) -> &[(usize, Fault)] {
        &self.faults
    }

    /// Ids whose requests are fault-free (the bit-parity witness set).
    pub fn clean_ids(&self, total: usize) -> Vec<usize> {
        (0..total).filter(|id| self.fault(*id).is_none()).collect()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }
}

/// A [`BatchResidual`] wrapper executing a [`FaultPlan`]: clean requests
/// pass straight through to the inner model; scheduled victims panic, go
/// NaN, or straggle *inside the residual evaluation* — the exact site a
/// real model fault would occur, on the worker thread that owns the batch.
///
/// Faults key off the id-aware entry point only: calibration probes (and
/// any other id-less evaluation) always run clean, so a faulted workload
/// still calibrates the same estimate as a clean one.
///
/// [`BatchResidual`]: crate::serve::BatchResidual
pub struct FaultyModel<E: Elem> {
    inner: std::sync::Arc<dyn crate::serve::router::BatchResidual<E> + Send + Sync>,
    plan: FaultPlan,
}

impl<E: Elem> FaultyModel<E> {
    pub fn new(
        inner: std::sync::Arc<dyn crate::serve::router::BatchResidual<E> + Send + Sync>,
        plan: FaultPlan,
    ) -> FaultyModel<E> {
        FaultyModel { inner, plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl<E: Elem> crate::serve::router::BatchResidual<E> for FaultyModel<E> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn residual_batch(&self, zs: &[E], k: usize, out: &mut [E]) {
        self.inner.residual_batch(zs, k, out);
    }

    fn residual_batch_ids(&self, zs: &[E], ids: &[usize], out: &mut [E]) {
        // Panics and stragglers fire before the evaluation (a panic must
        // not leave `out` half-written with plausible values; a straggler
        // models a slow dependency).
        for &id in ids {
            match self.plan.fault(id) {
                Some(Fault::Panic) => panic!("injected fault: request {id} panics"),
                Some(Fault::Straggle { delay_s }) => {
                    std::thread::sleep(std::time::Duration::from_secs_f64(delay_s));
                }
                _ => {}
            }
        }
        self.inner.residual_batch_ids(zs, ids, out);
        let d = self.inner.dim();
        for (p, &id) in ids.iter().enumerate() {
            if self.plan.fault(id) == Some(Fault::Nan) {
                out[p * d..(p + 1) * d].fill(E::from_f64(f64::NAN));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::nrm2;
    use crate::qn::workspace::Workspace;
    use crate::serve::router::BatchResidual;
    use crate::solvers::fixed_point::{picard_solve, picard_solve_batch, ColStats};
    use std::sync::Arc;

    #[test]
    fn batched_residual_matches_per_column() {
        let d = 96;
        let model: SynthDeq<f64> = SynthDeq::new(d, 16, 9);
        let mut rng = Rng::new(4);
        let k = 5;
        let zs: Vec<f64> = (0..k * d).map(|_| rng.normal()).collect();
        let mut batched = vec![0.0; k * d];
        model.residual_batch(&zs, k, &mut batched);
        for j in 0..k {
            let mut single = vec![0.0; d];
            model.residual_batch(&zs[j * d..(j + 1) * d], 1, &mut single);
            assert_eq!(&batched[j * d..(j + 1) * d], &single[..], "col {j}");
        }
    }

    #[test]
    fn picard_converges_on_synth_model() {
        let d = 64;
        let model: SynthDeq<f32> = SynthDeq::new(d, 16, 3);
        let (z, rn, iters) = picard_solve(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &vec![0.0f32; d],
            1.0,
            1e-4,
            200,
        );
        assert!(rn <= 1e-4, "residual {rn} after {iters} iters");
        assert!(iters < 100, "contraction too slow: {iters} iters");
        assert!(nrm2(&z) > 0.0, "non-trivial fixed point");
    }

    #[test]
    fn batched_solve_matches_sequential_on_synth() {
        let d = 48;
        let model: SynthDeq<f32> = SynthDeq::new(d, 12, 11);
        let b = 4;
        let mut rng = Rng::new(6);
        // Distinct initial iterates per request.
        let z0s: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec_f32(d, 0.5)).collect();
        let mut zs: Vec<f32> = Vec::new();
        for z0 in &z0s {
            zs.extend_from_slice(z0);
        }
        let mut stats = vec![ColStats::default(); b];
        let mut ws: Workspace<f32> = Workspace::new();
        picard_solve_batch(
            |block: &[f32], _ids: &[usize], out: &mut [f32]| {
                model.residual_batch(block, block.len() / d, out)
            },
            &mut zs,
            d,
            1.0,
            1e-5,
            300,
            &mut ws,
            &mut stats,
        );
        for j in 0..b {
            let (z, _, it) = picard_solve(
                |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
                &z0s[j],
                1.0,
                1e-5,
                300,
            );
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..], "col {j}");
            assert_eq!(stats[j].iters, it, "col {j}");
            assert!(stats[j].converged);
        }
    }

    #[test]
    fn fault_plan_is_seeded_and_replayable() {
        let (total, panics, nans, straggles) = (64, 2, 3, 4);
        let a = FaultPlan::seeded(7, total, panics, nans, straggles);
        let b = FaultPlan::seeded(7, total, panics, nans, straggles);
        assert_eq!(a.faults(), b.faults(), "same seed, same plan");
        let c = FaultPlan::seeded(8, total, panics, nans, straggles);
        assert_ne!(a.faults(), c.faults(), "different seed, different plan");
        assert_eq!(a.len(), panics + nans + straggles);
        let mut by_kind = [0usize; 3];
        for &(id, f) in a.faults() {
            assert!(id < total);
            match f {
                Fault::Panic => by_kind[0] += 1,
                Fault::Nan => by_kind[1] += 1,
                Fault::Straggle { delay_s } => {
                    assert!(delay_s > 0.0 && delay_s < 0.01);
                    by_kind[2] += 1;
                }
            }
        }
        assert_eq!(by_kind, [panics, nans, straggles]);
        // Lookup agrees with the schedule; clean ids complement it.
        for &(id, f) in a.faults() {
            assert_eq!(a.fault(id), Some(f));
        }
        assert_eq!(a.clean_ids(total).len(), total - a.len());
    }

    #[test]
    fn faulty_model_nans_only_its_own_column() {
        let d = 32;
        let inner: Arc<dyn BatchResidual<f64> + Send + Sync> =
            Arc::new(SynthDeq::<f64>::new(d, 8, 5));
        let plan = FaultPlan::from_faults(vec![(1, Fault::Nan)]);
        let faulty = FaultyModel::new(Arc::clone(&inner), plan);
        let mut rng = Rng::new(3);
        let zs: Vec<f64> = (0..3 * d).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 3 * d];
        faulty.residual_batch_ids(&zs, &[0, 1, 2], &mut out);
        let mut clean = vec![0.0; 3 * d];
        inner.residual_batch(&zs, 3, &mut clean);
        assert_eq!(&out[..d], &clean[..d], "col 0 untouched");
        assert!(out[d..2 * d].iter().all(|v| v.is_nan()), "victim column NaN");
        assert_eq!(&out[2 * d..], &clean[2 * d..], "col 2 untouched");
        // The id-less entry point (calibration) never faults.
        let mut calib = vec![0.0; d];
        faulty.residual_batch(&zs[d..2 * d], 1, &mut calib);
        assert_eq!(&calib[..], &clean[d..2 * d]);
    }

    #[test]
    fn faulty_model_panics_on_schedule_and_straggles_value_neutrally() {
        let d = 16;
        let inner: Arc<dyn BatchResidual<f64> + Send + Sync> =
            Arc::new(SynthDeq::<f64>::new(d, 8, 5));
        let plan = FaultPlan::from_faults(vec![
            (0, Fault::Panic),
            (2, Fault::Straggle { delay_s: 1e-4 }),
        ]);
        let faulty = FaultyModel::new(Arc::clone(&inner), plan);
        let zs = vec![0.25; d];
        let mut out = vec![0.0; d];
        // The straggler returns bit-identical values, just later.
        faulty.residual_batch_ids(&zs, &[2], &mut out);
        let mut clean = vec![0.0; d];
        inner.residual_batch(&zs, 1, &mut clean);
        assert_eq!(out, clean);
        // The panic victim fires inside the evaluation.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut out = vec![0.0; d];
            faulty.residual_batch_ids(&zs, &[0], &mut out);
        }));
        assert!(r.is_err(), "scheduled panic fired");
    }
}
