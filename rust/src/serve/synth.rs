//! Synthetic DEQ-shaped serving workload for the throughput bench and the
//! `serve-bench` CLI: a contractive block-dense fixed-point map whose
//! batched residual has the same cost profile as the native DEQ block
//! (dense per-row mixing, one thread fan-out per batched evaluation).

use crate::linalg::vecops::Elem;
use crate::util::rng::Rng;
use crate::util::threads;

/// Contractive fixed-point model g(z) = z − tanh(W_blk z + b): the state
/// splits into `d / s` blocks of width `s`, each mixed by one shared dense
/// `s × s` matrix (cache-hot) and passed through tanh. The matrix is scaled
/// so the map's Jacobian norm stays ≈ 0.5 — Picard with τ = 1 contracts at
/// ~0.5/iteration toward the map's unique fixed point (requests differ in
/// initial iterate and cotangent, the realistic shape for a shared-model
/// serving tier).
///
/// The residual depends only on a column's own values and its position
/// inside the column, so batched evaluation over any compaction permutation
/// is well-defined without per-request context (the ids slice of the
/// batched closure is unused here).
pub struct SynthDeq<E: Elem> {
    d: usize,
    /// Dense mixing block width.
    s: usize,
    /// Shared `s × s` mixing matrix, row-major.
    w: Vec<E>,
    /// Per-position bias (length d).
    bias: Vec<E>,
    /// Thread-sharding threshold in block elements: a single request's
    /// column usually sits below it (serial eval), a B-wide block crosses
    /// it — which is exactly the batching win the bench measures.
    par_min: usize,
}

impl<E: Elem> SynthDeq<E> {
    pub fn new(d: usize, s: usize, seed: u64) -> SynthDeq<E> {
        assert!(s >= 1 && d % s == 0, "block width must divide d");
        let mut rng = Rng::new(seed ^ 0x5E2F);
        // Spectral norm of an s×s matrix with N(0, σ²) entries ≈ 2σ√s;
        // σ = 0.25/√s keeps it near 0.5.
        let sigma = 0.25 / (s as f64).sqrt();
        let w = (0..s * s).map(|_| E::from_f64(rng.normal() * sigma)).collect();
        let bias = (0..d).map(|_| E::from_f64(rng.normal() * 0.3)).collect();
        SynthDeq {
            d,
            s,
            w,
            bias,
            par_min: 1 << 15,
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Batched residual over `k` stacked columns — the closure body the
    /// batched solvers evaluate once per iteration. One parallel region for
    /// the whole block (whole `s`-rows per worker); per-row f64 accumulation
    /// makes the result identical at any worker count, so batched and
    /// sequential serving agree bit-for-bit.
    pub fn residual_batch(&self, zs: &[E], k: usize, out: &mut [E]) {
        debug_assert_eq!(zs.len(), k * self.d);
        debug_assert_eq!(out.len(), k * self.d);
        let s = self.s;
        let d = self.d;
        let workers = threads::workers_for(k * d, self.par_min, 16);
        threads::par_row_chunks_mut(out, s, workers, |row0, chunk| {
            for (bi, orow) in chunk.chunks_exact_mut(s).enumerate() {
                let off = (row0 + bi) * s;
                let zrow = &zs[off..off + s];
                // Bias indexes by position within the column (blocks never
                // straddle columns since s divides d).
                let boff = off % d;
                let brow = &self.bias[boff..boff + s];
                for i in 0..s {
                    let mut acc = brow[i].to_f64();
                    for j in 0..s {
                        acc += self.w[i * s + j].to_f64() * zrow[j].to_f64();
                    }
                    orow[i] = E::from_f64(zrow[i].to_f64() - acc.tanh());
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::nrm2;
    use crate::qn::workspace::Workspace;
    use crate::solvers::fixed_point::{picard_solve, picard_solve_batch, ColStats};

    #[test]
    fn batched_residual_matches_per_column() {
        let d = 96;
        let model: SynthDeq<f64> = SynthDeq::new(d, 16, 9);
        let mut rng = Rng::new(4);
        let k = 5;
        let zs: Vec<f64> = (0..k * d).map(|_| rng.normal()).collect();
        let mut batched = vec![0.0; k * d];
        model.residual_batch(&zs, k, &mut batched);
        for j in 0..k {
            let mut single = vec![0.0; d];
            model.residual_batch(&zs[j * d..(j + 1) * d], 1, &mut single);
            assert_eq!(&batched[j * d..(j + 1) * d], &single[..], "col {j}");
        }
    }

    #[test]
    fn picard_converges_on_synth_model() {
        let d = 64;
        let model: SynthDeq<f32> = SynthDeq::new(d, 16, 3);
        let (z, rn, iters) = picard_solve(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &vec![0.0f32; d],
            1.0,
            1e-4,
            200,
        );
        assert!(rn <= 1e-4, "residual {rn} after {iters} iters");
        assert!(iters < 100, "contraction too slow: {iters} iters");
        assert!(nrm2(&z) > 0.0, "non-trivial fixed point");
    }

    #[test]
    fn batched_solve_matches_sequential_on_synth() {
        let d = 48;
        let model: SynthDeq<f32> = SynthDeq::new(d, 12, 11);
        let b = 4;
        let mut rng = Rng::new(6);
        // Distinct initial iterates per request.
        let z0s: Vec<Vec<f32>> = (0..b).map(|_| rng.normal_vec_f32(d, 0.5)).collect();
        let mut zs: Vec<f32> = Vec::new();
        for z0 in &z0s {
            zs.extend_from_slice(z0);
        }
        let mut stats = vec![ColStats::default(); b];
        let mut ws: Workspace<f32> = Workspace::new();
        picard_solve_batch(
            |block: &[f32], _ids: &[usize], out: &mut [f32]| {
                model.residual_batch(block, block.len() / d, out)
            },
            &mut zs,
            d,
            1.0,
            1e-5,
            300,
            &mut ws,
            &mut stats,
        );
        for j in 0..b {
            let (z, _, it) = picard_solve(
                |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
                &z0s[j],
                1.0,
                1e-5,
                300,
            );
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..], "col {j}");
            assert_eq!(stats[j].iters, it, "col {j}");
            assert!(stats[j].converged);
        }
    }
}
