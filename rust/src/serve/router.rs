//! Multi-model serve routing: several models (and several parameter
//! versions of each) behind ONE admission queue, with a per-key
//! calibration-estimate cache and a trip-rate-driven re-calibration policy.
//!
//! The ROADMAP follow-on the session API unlocks: because a serving engine
//! is now "a [`crate::solvers::session::SolverSpec`]-built solver + an
//! [`crate::solvers::session::EstimateHandle`]", a model version is just a
//! cache key — [`ModelKey`] = model id + parameter version — and a routed
//! tier is a map from keys to engines:
//!
//! * [`KeyedScheduler`] — one bounded admission surface for all models,
//!   organized as per-key FIFO queues. Batch formation **never crosses
//!   keys**: a batch is released either when some key has `max_batch`
//!   requests queued, or when the oldest request has waited `max_wait`
//!   (releasing the oldest request's key only). FIFO order is preserved
//!   within each key. Drained-empty queues are garbage-collected (their
//!   buffers recycled through a bounded spare pool) so a long tail of cold
//!   keys cannot grow the key map, and whole per-key queues can be moved
//!   between schedulers ([`KeyedScheduler::take_queue`] /
//!   [`KeyedScheduler::inject_queue`]) — the work-stealing primitive
//!   [`crate::serve::shard::ShardedRouter`] builds on.
//! * [`Router`] — per-key [`ServeEngine`]s plus their residual models.
//!   [`Router::register`] calibrates the new key's engine and **evicts any
//!   older parameter version of the same model** (a version bump
//!   invalidates exactly that model's cached estimate — other models keep
//!   theirs, pinned by `rust/tests/serve_routing.rs`).
//! * **Re-calibration policy** — after each routed batch the router checks
//!   the engine's fallback-guard trip rate ([`crate::serve::RecalibPolicy`]);
//!   a stale estimate is evicted and re-captured from a fresh probe solve,
//!   implementing the ROADMAP "continuous re-calibration" seedling.
//!
//! The closed-loop routed load driver lives in
//! [`crate::serve::loadgen::run_routed_closed_loop`] and backs the
//! `serve-bench --models N` CLI path (CI runs the two-model smoke).

use crate::linalg::vecops::Elem;
use crate::serve::engine::{BatchReport, EngineConfig, ServeEngine};
use crate::serve::scheduler::{
    AdaptiveWidth, AdaptiveWidthConfig, ConfigError, QueueEntry, Rejected, SchedStats,
    SchedulerConfig,
};
use crate::serve::synth::SynthDeq;
use crate::solvers::fixed_point::ColStats;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;

/// Identity of one servable model snapshot: which model, at which
/// parameter version. The calibration-estimate cache is keyed by this, so
/// bumping `version` naturally invalidates the stale estimate.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelKey {
    pub model: u32,
    pub version: u32,
}

impl ModelKey {
    pub fn new(model: u32, version: u32) -> ModelKey {
        ModelKey { model, version }
    }
}

impl std::fmt::Display for ModelKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "m{}v{}", self.model, self.version)
    }
}

/// A servable model: the batched residual map one engine solves against.
/// (The synthetic serving model implements this; the PJRT-backed DEQ can
/// once the runtime wiring lands.)
pub trait BatchResidual<E: Elem> {
    fn dim(&self) -> usize;
    /// Evaluate the residual over `k` stacked d-columns (see
    /// [`crate::serve::SynthDeq::residual_batch`] for the contract).
    fn residual_batch(&self, zs: &[E], k: usize, out: &mut [E]);
    /// Id-aware variant: `ids[p]` names the request whose state occupies
    /// column `p`. The default ignores the ids and delegates; the
    /// fault-injection wrapper ([`crate::serve::synth::FaultyModel`])
    /// overrides it to target scheduled request indices. Calibration probes
    /// always go through the id-less entry point, so injected faults never
    /// perturb the deterministic z₀ = 0 probe.
    fn residual_batch_ids(&self, zs: &[E], ids: &[usize], out: &mut [E]) {
        self.residual_batch(zs, ids.len(), out);
    }
}

impl<E: Elem> BatchResidual<E> for SynthDeq<E> {
    fn dim(&self) -> usize {
        SynthDeq::dim(self)
    }
    fn residual_batch(&self, zs: &[E], k: usize, out: &mut [E]) {
        SynthDeq::residual_batch(self, zs, k, out)
    }
}

/// Emptied per-key queues hand their buffer back to a bounded spare pool
/// so a steady-state workload churns zero allocations while a long tail of
/// cold keys still cannot grow the pool without bound.
const SPARE_QUEUE_CAP: usize = 8;

/// One live per-key FIFO: [`QueueEntry`]s in admission order.
#[derive(Debug)]
struct KeyQueue<T> {
    key: ModelKey,
    q: VecDeque<QueueEntry<T>>,
}

/// One admission surface for every model: per-key bounded FIFO queues
/// (shared `queue_cap` across keys) with per-key batch formation. Same
/// clock-agnostic discipline as [`crate::serve::Scheduler`] — every
/// operation takes `now` — and the same backpressure contract (`push`
/// rejects when the shared capacity is exhausted).
///
/// The key map is self-cleaning: a key's entry is created when the first
/// request of a cohort arrives and garbage-collected the moment its queue
/// drains empty (buffer recycled through a bounded spare pool), so a
/// long-running server visited by a long tail of cold [`ModelKey`]s holds
/// at most `O(live keys + SPARE_QUEUE_CAP)` queue state — pinned by
/// `keyed_scheduler_gcs_cold_keys`. Entries are kept in cohort-arrival
/// order, which is what makes `ready`'s full-batch tie-breaking and
/// `next_deadline` deterministic.
///
/// Whole queues can also be moved between schedulers —
/// [`KeyedScheduler::take_queue`] / [`KeyedScheduler::inject_queue`] —
/// preserving per-request arrival stamps and FIFO order. That is the
/// work-stealing primitive [`crate::serve::shard::ShardedRouter`] uses to
/// re-home a backlogged key onto an idle shard.
#[derive(Debug)]
pub struct KeyedScheduler<T> {
    cfg: SchedulerConfig,
    /// Live per-key queues, in cohort-arrival order (a key enters at the
    /// back when the first request of a cohort arrives and leaves when its
    /// queue empties). Every poll — `ready` / `next_deadline` run once per
    /// serving-loop iteration — is O(#live keys) and allocation-free.
    keys: Vec<KeyQueue<T>>,
    /// Recycled buffers from garbage-collected keys (bounded by
    /// [`SPARE_QUEUE_CAP`]).
    spare: Vec<VecDeque<QueueEntry<T>>>,
    /// Total queued requests across keys (the backpressure quantity).
    len: usize,
    /// Admission telemetry (accepted / rejected / deadline-expired).
    pub stats: SchedStats,
    /// Deadline-expired entries diverted at drain time, awaiting pickup as
    /// `(key, queue latency at GC, payload)` — the caller owes each one a
    /// typed `DeadlineExceeded` outcome.
    expired: Vec<(ModelKey, f64, T)>,
    /// Drain-rate EWMA (items/second) backing the `retry_after` hint.
    last_drain: Option<f64>,
    drain_rate: f64,
}

impl<T> KeyedScheduler<T> {
    /// Validating constructor: malformed configs come back as
    /// [`ConfigError`] instead of aborting the process.
    pub fn try_new(cfg: SchedulerConfig) -> Result<KeyedScheduler<T>, ConfigError> {
        cfg.validate()?;
        Ok(KeyedScheduler {
            cfg,
            keys: Vec::new(),
            spare: Vec::new(),
            len: 0,
            stats: SchedStats::default(),
            expired: Vec::new(),
            last_drain: None,
            drain_rate: 0.0,
        })
    }

    /// Panicking wrapper over [`KeyedScheduler::try_new`] for in-crate
    /// callers with static configs.
    pub fn new(cfg: SchedulerConfig) -> KeyedScheduler<T> {
        KeyedScheduler::try_new(cfg).unwrap_or_else(|e| panic!("invalid scheduler config: {e}"))
    }

    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Backoff hint for a rejected push: the reciprocal of the recent drain
    /// rate (≈ time for one slot to free), clamped to [1µs, 1s]; before any
    /// drain has been observed, `max_wait` (the batch-release cadence).
    pub fn retry_after(&self) -> f64 {
        if self.drain_rate > 0.0 {
            (1.0 / self.drain_rate).clamp(1e-6, 1.0)
        } else {
            self.cfg.max_wait.max(1e-6)
        }
    }

    fn note_drain(&mut self, now: f64, n: usize) {
        if n == 0 {
            return;
        }
        if let Some(prev) = self.last_drain {
            let dt = (now - prev).max(1e-9);
            let inst = n as f64 / dt;
            self.drain_rate = if self.drain_rate > 0.0 {
                0.7 * self.drain_rate + 0.3 * inst
            } else {
                inst
            };
        }
        self.last_drain = Some(now);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Live keys currently holding queued requests — the leak-regression
    /// observable: after every queue drains this must be 0.
    pub fn key_count(&self) -> usize {
        self.keys.len()
    }

    /// Recycled queue buffers held for reuse (bounded by
    /// [`SPARE_QUEUE_CAP`]).
    pub fn spare_queues(&self) -> usize {
        self.spare.len()
    }

    fn entry(&self, key: ModelKey) -> Option<&KeyQueue<T>> {
        self.keys.iter().find(|e| e.key == key)
    }

    /// Remove the (drained-empty) entry at `pos`, recycling its buffer.
    fn gc_at(&mut self, pos: usize) {
        let kq = self.keys.remove(pos);
        debug_assert!(kq.q.is_empty(), "only empty queues are collected");
        if self.spare.len() < SPARE_QUEUE_CAP {
            self.spare.push(kq.q);
        }
    }

    /// Admit a request for `key` at time `now`; rejects (returning the
    /// payload plus a [`Rejected::retry_after`] backoff hint) when the
    /// shared capacity is exhausted.
    pub fn push(&mut self, now: f64, key: ModelKey, item: T) -> Result<(), Rejected<T>> {
        self.push_deadline(now, f64::INFINITY, key, item)
    }

    /// [`KeyedScheduler::push`] with an absolute deadline: an entry still
    /// queued when the clock passes `deadline` is GC'd at drain time
    /// (counted in [`SchedStats::expired`], handed back via
    /// [`KeyedScheduler::take_expired`] for a typed outcome).
    pub fn push_deadline(
        &mut self,
        now: f64,
        deadline: f64,
        key: ModelKey,
        item: T,
    ) -> Result<(), Rejected<T>> {
        if self.len >= self.cfg.queue_cap {
            self.stats.rejected += 1;
            return Err(Rejected {
                item,
                retry_after: self.retry_after(),
            });
        }
        let entry = QueueEntry {
            at: now,
            deadline,
            item,
        };
        match self.keys.iter_mut().find(|e| e.key == key) {
            Some(e) => e.q.push_back(entry),
            None => {
                let mut q = self.spare.pop().unwrap_or_default();
                q.push_back(entry);
                self.keys.push(KeyQueue { key, q });
            }
        }
        self.len += 1;
        self.stats.accepted += 1;
        Ok(())
    }

    /// Queued requests for one key (O(#live keys) lookup).
    pub fn count_key(&self, key: ModelKey) -> usize {
        self.entry(key).map(|e| e.q.len()).unwrap_or(0)
    }

    /// The key of the oldest queued request (earliest front arrival across
    /// keys; cohort order breaks exact ties).
    pub fn front_key(&self) -> Option<ModelKey> {
        self.oldest_front().map(|(_, k)| k)
    }

    /// `(arrival, key)` of the oldest queued request. A linear min-scan —
    /// cohort order alone is not enough because `pop_front_key` can age a
    /// later cohort's front past an earlier one's.
    fn oldest_front(&self) -> Option<(f64, ModelKey)> {
        let mut best: Option<(f64, ModelKey)> = None;
        for e in &self.keys {
            if let Some(front) = e.q.front() {
                if best.map(|(bt, _)| front.at < bt).unwrap_or(true) {
                    best = Some((front.at, e.key));
                }
            }
        }
        best
    }

    /// The first key in cohort-arrival order with a full batch queued.
    /// O(#live keys), allocation-free — the routed serving loop polls this
    /// every iteration.
    fn first_full_key(&self) -> Option<ModelKey> {
        self.keys
            .iter()
            .find(|e| e.q.len() >= self.cfg.max_batch)
            .map(|e| e.key)
    }

    /// The key holding the most queued requests, as `(key, count)` — the
    /// work-stealing victim-selection probe (first key wins exact ties).
    pub fn heaviest_key(&self) -> Option<(ModelKey, usize)> {
        let mut best: Option<(ModelKey, usize)> = None;
        for e in &self.keys {
            if best.map(|(_, n)| e.q.len() > n).unwrap_or(true) {
                best = Some((e.key, e.q.len()));
            }
        }
        best.filter(|(_, n)| *n > 0)
    }

    /// The batch releasable at time `now`, as `(key, count)` — never mixes
    /// keys. A key with `max_batch` requests queued releases immediately
    /// (earliest such key by arrival order of its first request); otherwise
    /// once the *oldest* queued request has waited `max_wait`, its key
    /// releases whatever it has queued. Allocation-free.
    pub fn ready(&self, now: f64) -> Option<(ModelKey, usize)> {
        if let Some(k) = self.first_full_key() {
            return Some((k, self.cfg.max_batch));
        }
        let (t0, k0) = self.oldest_front()?;
        if now - t0 >= self.cfg.max_wait {
            // Below a full batch by the check above, so release everything
            // this key has queued.
            return Some((k0, self.count_key(k0)));
        }
        None
    }

    /// Earliest time a currently-queued partial batch becomes releasable
    /// (`None` when the queue is empty or some key already holds a full
    /// batch — then [`KeyedScheduler::ready`] is the authority).
    pub fn next_deadline(&self) -> Option<f64> {
        if self.first_full_key().is_some() {
            return None;
        }
        self.oldest_front().map(|(t, _)| t + self.cfg.max_wait)
    }

    /// Pop the single oldest request of `key` as a
    /// `(queue latency at now, payload)` pair — the streaming-admission
    /// primitive: [`crate::serve::ServeEngine::process_streaming`]'s admit
    /// callback pulls requests one at a time as columns free up, and FIFO
    /// within the key is preserved because this always takes the key's
    /// front. Other keys' requests keep their positions.
    pub fn pop_front_key(&mut self, key: ModelKey, now: f64) -> Option<(f64, T)> {
        let pos = self.keys.iter().position(|e| e.key == key)?;
        // Deadline-expired fronts are GC'd on the way (counted + diverted),
        // so streaming admission never spends a column on a dead request.
        let live = loop {
            match self.keys[pos].q.pop_front() {
                None => break None,
                Some(e) if e.deadline <= now => {
                    self.len -= 1;
                    self.stats.expired += 1;
                    self.expired.push((key, now - e.at, e.item));
                }
                Some(e) => {
                    self.len -= 1;
                    break Some((now - e.at, e.item));
                }
            }
        };
        self.note_drain(now, 1);
        if self.keys[pos].q.is_empty() {
            self.gc_at(pos);
        }
        live
    }

    /// Drain up to `n` oldest requests of `key` (FIFO within the key) into
    /// `out` as `(queue latency at now, payload)` pairs. Other keys'
    /// requests keep their positions; emptied queues are collected (no
    /// allocation beyond the caller's reused `out`). Entries whose deadline
    /// has passed are GC'd instead of released: counted in
    /// [`SchedStats::expired`] and diverted to
    /// [`KeyedScheduler::take_expired`], so the batch may come back smaller
    /// than `n`.
    pub fn drain_key(&mut self, key: ModelKey, n: usize, now: f64, out: &mut Vec<(f64, T)>) {
        let Some(pos) = self.keys.iter().position(|e| e.key == key) else {
            return;
        };
        let take = n.min(self.keys[pos].q.len());
        for _ in 0..take {
            let e = self.keys[pos].q.pop_front().expect("len checked");
            if e.deadline <= now {
                self.stats.expired += 1;
                self.expired.push((key, now - e.at, e.item));
            } else {
                out.push((now - e.at, e.item));
            }
        }
        self.len -= take;
        self.note_drain(now, take);
        if self.keys[pos].q.is_empty() {
            self.gc_at(pos);
        }
    }

    /// Hand over deadline-expired entries GC'd by earlier drains as
    /// `(key, queue latency at GC, payload)` triples. The caller owes each
    /// one a typed `DeadlineExceeded` outcome — GC never silently drops a
    /// request.
    pub fn take_expired(&mut self, out: &mut Vec<(ModelKey, f64, T)>) {
        out.append(&mut self.expired);
    }

    /// Remove `key`'s entire queue — arrival stamps and FIFO order intact —
    /// for injection into another scheduler ([`KeyedScheduler::inject_queue`]).
    /// This is the whole-queue work-stealing primitive: stealing the queue
    /// (rather than individual items) is what lets FIFO-within-key survive a
    /// shard migration. Returns `None` if the key holds nothing.
    pub fn take_queue(&mut self, key: ModelKey) -> Option<VecDeque<QueueEntry<T>>> {
        let pos = self.keys.iter().position(|e| e.key == key)?;
        let kq = self.keys.remove(pos);
        self.len -= kq.q.len();
        Some(kq.q)
    }

    /// Install a queue moved from another scheduler (the receiving half of
    /// [`KeyedScheduler::take_queue`]). The key must not already be live
    /// here — shard ownership guarantees a key's queue exists in exactly
    /// one scheduler at a time. Injection is exempt from `queue_cap`
    /// backpressure: the requests were already admitted once, and a steal
    /// must never drop them.
    pub fn inject_queue(&mut self, key: ModelKey, q: VecDeque<QueueEntry<T>>) {
        assert!(
            self.entry(key).is_none(),
            "inject_queue: {key} already live in this scheduler"
        );
        if q.is_empty() {
            if self.spare.len() < SPARE_QUEUE_CAP {
                self.spare.push(q);
            }
            return;
        }
        self.len += q.len();
        self.keys.push(KeyQueue { key, q });
    }
}

struct RouteEntry<E: Elem, EU: Elem, EV: Elem> {
    key: ModelKey,
    engine: ServeEngine<E, EU, EV>,
    model: Box<dyn BatchResidual<E>>,
    /// Stale-estimate evictions + re-calibrations performed by the policy.
    recalibrations: usize,
    /// Per-key AIMD width controller (None when the router was built
    /// without [`Router::with_adaptive_width`]).
    width: Option<AdaptiveWidth>,
}

/// Per-model serving engines behind one routing surface. Every registered
/// [`ModelKey`] owns a [`ServeEngine`] (built from one shared
/// [`EngineConfig`], so the [`crate::solvers::session::SolverSpec`]s stay
/// the single source of truth) and its calibration estimate;
/// [`Router::process`] dispatches a single-key batch and runs the
/// continuous re-calibration policy.
///
/// Like the engine, the router takes optional panel-storage parameters:
/// a `Router<f32, Bf16, f32>` serves every key's estimate in the mixed
/// reduced-precision layout while solves (and calibration probes) stay at
/// `E = f32` — the per-key demotion happens inside
/// [`ServeEngine::calibrate`], and the re-calibration policy guards the
/// whole tier against a layout too coarse for some key.
pub struct Router<E: Elem, EU: Elem = E, EV: Elem = EU> {
    cfg: EngineConfig,
    entries: Vec<RouteEntry<E, EU, EV>>,
    /// When set, every key registered afterwards gets its own
    /// [`AdaptiveWidth`] controller fed from served-batch latency.
    width_cfg: Option<AdaptiveWidthConfig>,
}

impl<E: Elem, EU: Elem, EV: Elem> Router<E, EU, EV> {
    pub fn new(cfg: EngineConfig) -> Router<E, EU, EV> {
        Router {
            cfg,
            entries: Vec::new(),
            width_cfg: None,
        }
    }

    /// Enable per-key adaptive batch width: each key registered after this
    /// call carries an [`AdaptiveWidth`] controller that
    /// [`Router::process`] feeds with the batch's per-request service
    /// latency (`(fwd_seconds + bwd_seconds) / batch` from
    /// [`BatchReport`]); [`Router::target_width`] exposes the width the
    /// serving loop should form batches at.
    pub fn with_adaptive_width(mut self, wc: AdaptiveWidthConfig) -> Router<E, EU, EV> {
        assert!(
            wc.max_width <= self.cfg.max_batch,
            "adaptive max_width cannot exceed engine max_batch"
        );
        self.width_cfg = Some(wc);
        self
    }

    /// The batch width `key`'s controller currently recommends (`None`
    /// when adaptive width is off or the key is unregistered — form
    /// batches at the scheduler's `max_batch` then).
    pub fn target_width(&self, key: ModelKey) -> Option<usize> {
        self.entries
            .iter()
            .find(|e| e.key == key)
            .and_then(|e| e.width.as_ref())
            .map(|w| w.width())
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Registered keys, in registration order.
    pub fn keys(&self) -> Vec<ModelKey> {
        self.entries.iter().map(|e| e.key).collect()
    }

    pub fn engine(&self, key: ModelKey) -> Option<&ServeEngine<E, EU, EV>> {
        self.entries.iter().find(|e| e.key == key).map(|e| &e.engine)
    }

    /// Stale-estimate re-calibrations performed for `key`.
    pub fn recalibrations(&self, key: ModelKey) -> usize {
        self.entries
            .iter()
            .find(|e| e.key == key)
            .map(|e| e.recalibrations)
            .unwrap_or(0)
    }

    /// Whether `key`'s circuit breaker is currently open — the engine is
    /// serving degraded Jacobian-free backwards instead of the cached SHINE
    /// estimate (see [`crate::serve::CircuitBreaker`]). `false` when the
    /// key is unregistered or the breaker is disabled.
    pub fn breaker_open(&self, key: ModelKey) -> bool {
        self.entries
            .iter()
            .find(|e| e.key == key)
            .map(|e| e.engine.breaker_open())
            .unwrap_or(false)
    }

    /// Register (or roll) a model snapshot: builds its engine, calibrates
    /// it from z₀ = 0, and **evicts any older (or same-version) snapshot of
    /// the same model id** — the version bump invalidates exactly that
    /// model's stale cache entries, never a different model's and never a
    /// NEWER version (replaying a stale registration cannot tear down a
    /// live engine). Returns the calibration probe's (iterations, final
    /// residual).
    pub fn register(&mut self, key: ModelKey, model: Box<dyn BatchResidual<E>>) -> (usize, f64) {
        self.entries
            .retain(|e| e.key.model != key.model || e.key.version > key.version);
        let d = model.dim();
        let mut engine = ServeEngine::new(d, self.cfg);
        let probe = engine.calibrate(
            |z: &[E], out: &mut [E]| model.residual_batch(z, 1, out),
            &vec![E::ZERO; d],
        );
        self.entries.push(RouteEntry {
            key,
            engine,
            model,
            recalibrations: 0,
            width: self.width_cfg.map(AdaptiveWidth::new),
        });
        probe
    }

    /// Serve one single-key batch (same block contract as
    /// [`ServeEngine::process`]); afterwards, if the engine's trip-rate
    /// policy flags the shared estimate stale, evict it and re-calibrate
    /// from a fresh probe solve (the continuous re-calibration policy).
    pub fn process(
        &mut self,
        key: ModelKey,
        zs: &mut [E],
        cotangents: &[E],
        w_out: &mut [E],
        stats: &mut [ColStats],
    ) -> Result<BatchReport> {
        let entry = self
            .entries
            .iter_mut()
            .find(|e| e.key == key)
            .ok_or_else(|| anyhow!("no engine registered for {key}"))?;
        let d = entry.model.dim();
        let model = &entry.model;
        let report = entry.engine.process(
            |block: &[E], _ids: &[usize], out: &mut [E]| {
                model.residual_batch(block, block.len() / d, out)
            },
            zs,
            cotangents,
            w_out,
            stats,
        );
        if report.estimate_stale {
            entry.engine.invalidate_estimate();
            entry.engine.calibrate(
                |z: &[E], out: &mut [E]| model.residual_batch(z, 1, out),
                &vec![E::ZERO; d],
            );
            entry.recalibrations += 1;
        }
        if let Some(w) = entry.width.as_mut() {
            w.observe((report.fwd_seconds + report.bwd_seconds) / report.batch.max(1) as f64);
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qn::InvOp;
    use crate::serve::scheduler::SchedulerConfig;

    fn ks(max_batch: usize, max_wait: f64, cap: usize) -> KeyedScheduler<u32> {
        KeyedScheduler::new(SchedulerConfig {
            max_batch,
            max_wait,
            queue_cap: cap,
        })
    }

    const A: ModelKey = ModelKey { model: 0, version: 0 };
    const B: ModelKey = ModelKey { model: 1, version: 0 };

    #[test]
    fn keyed_scheduler_never_mixes_keys() {
        let mut s = ks(3, 1.0, 16);
        // Interleave two keys: A B A B A → A reaches the full batch first.
        for (i, k) in [A, B, A, B, A].iter().enumerate() {
            s.push(0.1 * i as f64, *k, i as u32).unwrap();
        }
        let (k, n) = s.ready(0.5).expect("full batch for A");
        assert_eq!(k, A);
        assert_eq!(n, 3);
        let mut out = Vec::new();
        s.drain_key(k, n, 0.5, &mut out);
        // FIFO within the key: A's payloads were 0, 2, 4.
        assert_eq!(out.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 2, 4]);
        // Only B's requests remain, in order.
        assert_eq!(s.len(), 2);
        assert_eq!(s.front_key(), Some(B));
        assert_eq!(s.count_key(A), 0);
        assert_eq!(s.count_key(B), 2);
    }

    #[test]
    fn keyed_scheduler_deadline_releases_oldest_key_only() {
        let mut s = ks(8, 0.5, 16);
        s.push(1.0, B, 10).unwrap();
        s.push(1.1, A, 20).unwrap();
        s.push(1.2, B, 30).unwrap();
        assert_eq!(s.ready(1.4), None);
        assert_eq!(s.next_deadline(), Some(1.5));
        // Oldest (B) waited max_wait: release B's two requests, not A's.
        let (k, n) = s.ready(1.5).expect("deadline release");
        assert_eq!(k, B);
        assert_eq!(n, 2);
        let mut out = Vec::new();
        s.drain_key(k, n, 1.5, &mut out);
        assert_eq!(out.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![10, 30]);
        assert_eq!(s.count_key(A), 1);
    }

    #[test]
    fn pop_front_key_is_fifo_and_keeps_registry_consistent() {
        let mut s = ks(4, 1.0, 16);
        for (i, k) in [A, B, A, B, A].iter().enumerate() {
            s.push(0.1 * i as f64, *k, i as u32).unwrap();
        }
        // Streaming admission pulls A's requests one at a time, in FIFO
        // order, without disturbing B's queue positions.
        assert_eq!(s.pop_front_key(A, 1.0).map(|(_, p)| p), Some(0));
        assert_eq!(s.pop_front_key(A, 1.0).map(|(_, p)| p), Some(2));
        assert_eq!(s.count_key(A), 1);
        assert_eq!(s.count_key(B), 2);
        assert_eq!(s.front_key(), Some(B));
        let (wait, p) = s.pop_front_key(A, 1.0).unwrap();
        assert_eq!(p, 4);
        assert!((wait - 0.6).abs() < 1e-12);
        // A is drained: registry entry removed, further pops return None.
        assert_eq!(s.count_key(A), 0);
        assert_eq!(s.pop_front_key(A, 2.0), None);
        assert_eq!(s.pop_front_key(B, 2.0).map(|(_, p)| p), Some(1));
        assert_eq!(s.pop_front_key(B, 2.0).map(|(_, p)| p), Some(3));
        assert!(s.is_empty());
    }

    #[test]
    fn keyed_scheduler_backpressure() {
        let mut s = ks(2, 1.0, 2);
        assert!(s.push(0.0, A, 1).is_ok());
        assert!(s.push(0.0, B, 2).is_ok());
        let r = s.push(0.0, A, 3).unwrap_err();
        assert_eq!(r.item, 3);
        assert!(r.retry_after > 0.0, "rejection carries a backoff hint");
        assert_eq!(s.stats.accepted, 2);
        assert_eq!(s.stats.rejected, 1);
    }

    #[test]
    fn keyed_scheduler_gcs_expired_entries_at_drain() {
        let mut s = ks(4, 0.1, 16);
        s.push_deadline(0.0, 0.5, A, 10).unwrap(); // dead by drain time
        s.push(0.0, A, 20).unwrap();
        s.push_deadline(0.0, 9.0, A, 30).unwrap(); // still live
        let (k, n) = s.ready(1.0).expect("oldest waited past max_wait");
        assert_eq!(k, A);
        let mut out = Vec::new();
        s.drain_key(k, n, 1.0, &mut out);
        // The expired entry never reaches the batch…
        assert_eq!(out.iter().map(|&(_, p)| p).collect::<Vec<_>>(), vec![20, 30]);
        assert_eq!(s.stats.expired, 1);
        // …but is handed back, attributed to its key, for a typed outcome.
        let mut exp = Vec::new();
        s.take_expired(&mut exp);
        assert_eq!(exp.len(), 1);
        assert_eq!((exp[0].0, exp[0].2), (A, 10));
        assert!(s.is_empty());
        // pop_front_key GCs expired fronts too (streaming admission).
        s.push_deadline(2.0, 2.1, B, 40).unwrap();
        s.push(2.0, B, 50).unwrap();
        assert_eq!(s.pop_front_key(B, 3.0).map(|(_, p)| p), Some(50));
        assert_eq!(s.stats.expired, 2);
    }

    #[test]
    fn keyed_scheduler_gcs_cold_keys() {
        // Regression for the key-map leak: a long tail of cold ModelKeys,
        // each seen once and drained, must not grow the key map. Before the
        // per-key-queue GC the registry kept one entry per key ever seen.
        let mut s = ks(4, 1.0, 64);
        let mut out = Vec::new();
        for i in 0..500u32 {
            let k = ModelKey::new(i, 0);
            s.push(i as f64, k, i).unwrap();
            // At most two keys live at once (one cold key queued while the
            // previous drains).
            assert!(s.key_count() <= 2, "key map grew to {}", s.key_count());
            out.clear();
            s.drain_key(k, 4, i as f64 + 0.5, &mut out);
            assert_eq!(out.len(), 1);
        }
        assert_eq!(s.key_count(), 0, "all cold keys collected");
        assert!(s.is_empty());
        // Buffers are recycled, not hoarded: the spare pool stays bounded.
        assert!(s.spare_queues() <= 8, "spare pool bounded");
        assert!(s.spare_queues() >= 1, "drained buffers are recycled");
        assert_eq!(s.stats.accepted, 500);
    }

    #[test]
    fn keyed_scheduler_pop_gc_and_heaviest() {
        let mut s = ks(8, 1.0, 16);
        s.push(0.0, A, 0).unwrap();
        s.push(0.1, B, 1).unwrap();
        s.push(0.2, B, 2).unwrap();
        assert_eq!(s.heaviest_key(), Some((B, 2)));
        assert_eq!(s.key_count(), 2);
        // pop_front_key drains A empty: its entry is collected.
        assert_eq!(s.pop_front_key(A, 1.0).map(|(_, p)| p), Some(0));
        assert_eq!(s.key_count(), 1);
        assert_eq!(s.count_key(A), 0);
        assert_eq!(s.heaviest_key(), Some((B, 2)));
        assert_eq!(s.pop_front_key(B, 1.0).map(|(_, p)| p), Some(1));
        assert_eq!(s.pop_front_key(B, 1.0).map(|(_, p)| p), Some(2));
        assert_eq!(s.key_count(), 0);
        assert_eq!(s.heaviest_key(), None);
    }

    #[test]
    fn take_and_inject_queue_preserve_fifo_and_stamps() {
        // The work-stealing primitive: move B's whole queue from a "victim"
        // scheduler into a "thief" and verify arrival stamps + FIFO order
        // survive the migration, and that the victim's view is consistent.
        let mut victim = ks(4, 1.0, 16);
        let mut thief = ks(4, 1.0, 16);
        for (i, k) in [A, B, A, B, B].iter().enumerate() {
            victim.push(0.1 * i as f64, *k, i as u32).unwrap();
        }
        assert_eq!(victim.take_queue(ModelKey::new(9, 9)).map(|q| q.len()), None);
        let q = victim.take_queue(B).expect("B queued");
        assert_eq!(q.len(), 3);
        assert_eq!(victim.len(), 2);
        assert_eq!(victim.count_key(B), 0);
        assert_eq!(victim.key_count(), 1);
        thief.inject_queue(B, q);
        assert_eq!(thief.len(), 3);
        assert_eq!(thief.count_key(B), 3);
        // FIFO + stamps: payloads 1, 3, 4 with their original arrivals.
        let (w, p) = thief.pop_front_key(B, 1.0).unwrap();
        assert_eq!(p, 1);
        assert!((w - 0.9).abs() < 1e-12, "arrival stamp moved with the queue");
        let mut out = Vec::new();
        thief.drain_key(B, 8, 1.0, &mut out);
        assert_eq!(out.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![3, 4]);
        assert!(thief.is_empty());
        // The victim still serves A untouched, in order.
        let mut out = Vec::new();
        victim.drain_key(A, 8, 1.0, &mut out);
        assert_eq!(out.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 2]);
    }

    fn router_cfg(b: usize) -> EngineConfig {
        EngineConfig {
            max_batch: b,
            ..Default::default()
        }
        .with_tol(1e-6)
    }

    #[test]
    fn version_bump_invalidates_only_that_models_estimate() {
        let d = 32;
        let mut router: Router<f64> = Router::new(router_cfg(4));
        router.register(ModelKey::new(0, 0), Box::new(SynthDeq::<f64>::new(d, 8, 1)));
        router.register(ModelKey::new(1, 0), Box::new(SynthDeq::<f64>::new(d, 8, 2)));
        assert_eq!(router.keys(), vec![ModelKey::new(0, 0), ModelKey::new(1, 0)]);
        // Snapshot model 1's cached estimate behaviour before the bump.
        let probe: Vec<f64> = (0..d).map(|i| (i as f64 * 0.31).sin()).collect();
        let before = router
            .engine(ModelKey::new(1, 0))
            .unwrap()
            .estimate()
            .unwrap()
            .apply_t_vec(&probe);
        // Parameter-version bump on model 0.
        router.register(ModelKey::new(0, 1), Box::new(SynthDeq::<f64>::new(d, 8, 3)));
        // (0,0) is gone, (0,1) live, (1,0) untouched — bit-identical cache.
        assert!(router.engine(ModelKey::new(0, 0)).is_none());
        assert!(router.engine(ModelKey::new(0, 1)).is_some());
        let after = router
            .engine(ModelKey::new(1, 0))
            .unwrap()
            .estimate()
            .unwrap()
            .apply_t_vec(&probe);
        assert_eq!(before, after, "model 1's cached estimate must survive");
    }

    #[test]
    fn stale_registration_cannot_evict_newer_version() {
        let d = 32;
        let mut router: Router<f64> = Router::new(router_cfg(4));
        router.register(ModelKey::new(0, 1), Box::new(SynthDeq::<f64>::new(d, 8, 1)));
        // Replaying an OLD snapshot must not tear down the live v1 engine.
        router.register(ModelKey::new(0, 0), Box::new(SynthDeq::<f64>::new(d, 8, 2)));
        assert!(router.engine(ModelKey::new(0, 1)).is_some(), "newer version survives");
        assert!(router.engine(ModelKey::new(0, 0)).is_some(), "old snapshot coexists");
        // Re-registering the SAME version replaces it (one entry per key).
        router.register(ModelKey::new(0, 1), Box::new(SynthDeq::<f64>::new(d, 8, 3)));
        assert_eq!(
            router.keys().iter().filter(|k| **k == ModelKey::new(0, 1)).count(),
            1
        );
    }

    #[test]
    fn adaptive_width_is_per_key_and_fed_by_served_batches() {
        let d = 24;
        let b = 4;
        // A microsecond target no real solve can meet: every served batch
        // must push its key's controller down, other keys untouched.
        let wc = AdaptiveWidthConfig {
            min_width: 1,
            max_width: b,
            target_latency: 1e-9,
            alpha: 1.0,
        };
        let mut router: Router<f32> = Router::new(router_cfg(b)).with_adaptive_width(wc);
        let k0 = ModelKey::new(0, 0);
        let k1 = ModelKey::new(1, 0);
        router.register(k0, Box::new(SynthDeq::<f32>::new(d, 8, 5)));
        router.register(k1, Box::new(SynthDeq::<f32>::new(d, 8, 6)));
        assert_eq!(router.target_width(k0), Some(b));
        assert_eq!(router.target_width(k1), Some(b));
        assert_eq!(router.target_width(ModelKey::new(9, 9)), None);
        let mut zs = vec![0.0f32; b * d];
        let cots = vec![1.0f32; b * d];
        let mut w = vec![0.0f32; b * d];
        let mut stats = vec![ColStats::default(); b];
        router.process(k0, &mut zs, &cots, &mut w, &mut stats).unwrap();
        assert_eq!(router.target_width(k0), Some(b / 2), "served key halves");
        assert_eq!(router.target_width(k1), Some(b), "idle key untouched");
    }

    #[test]
    fn routed_batches_serve_and_unknown_key_errors() {
        let d = 24;
        let b = 3;
        let mut router: Router<f32> = Router::new(router_cfg(b));
        let k0 = ModelKey::new(7, 0);
        router.register(k0, Box::new(SynthDeq::<f32>::new(d, 8, 5)));
        let mut zs = vec![0.0f32; b * d];
        let cots = vec![1.0f32; b * d];
        let mut w = vec![0.0f32; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = router.process(k0, &mut zs, &cots, &mut w, &mut stats).unwrap();
        assert!(rep.all_converged);
        assert_eq!(rep.batch, b);
        assert!(router
            .process(ModelKey::new(9, 9), &mut zs, &cots, &mut w, &mut stats)
            .is_err());
    }
}
