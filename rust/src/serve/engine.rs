//! The batch-serving engine: batched fixed-point forward + one-sweep SHINE
//! backward over a shared calibration estimate (module-level contract in
//! [`crate::serve`]).
//!
//! Since the session-API redesign the engine is a consumer of
//! [`crate::solvers::session`]: [`EngineConfig`] carries two
//! [`SolverSpec`]s (the batched forward solver and the Broyden calibration
//! probe — the **single source of truth** for tolerances and iteration
//! budgets; nothing is restated here), the engine drives a built
//! [`FixedPointSolver`] trait object over the state block, and the shared
//! estimate is the [`EstimateHandle`] captured by the probe's
//! `SolveOutcome` — the serving-side instance of the SHINE hand-off.
//!
//! The engine also tracks **estimate staleness**: the cumulative §3
//! fallback-guard trip rate since the last calibration. A drifting model
//! makes the shared estimate blow up more cotangents; when the trip rate
//! crosses [`RecalibPolicy::trip_rate`] the estimate is flagged stale
//! ([`BatchReport::estimate_stale`], [`ServeEngine::estimate_stale`]) and
//! the owner — [`crate::serve::Router`] in the multi-model tier — evicts
//! and re-calibrates it.

use crate::linalg::vecops::{nrm2, Elem};
use crate::qn::{InvOp, LowRank};
use crate::serve::scheduler::ConfigError;
use crate::solvers::fixed_point::{swap_cols, ColStats};
use crate::solvers::session::{EstimateHandle, FixedPointSolver, Session, SolverSpec};
use crate::util::timer::Stopwatch;

/// Continuous re-calibration policy: when the fallback-guard trip rate
/// since calibration exceeds `trip_rate` (measured over at least
/// `min_cols` guarded columns, so one unlucky batch cannot evict a fresh
/// estimate), the shared estimate is considered stale.
#[derive(Clone, Copy, Debug)]
pub struct RecalibPolicy {
    /// Stale when trips / guarded columns exceeds this.
    pub trip_rate: f64,
    /// Minimum guarded columns before the rate is meaningful.
    pub min_cols: usize,
}

impl Default for RecalibPolicy {
    fn default() -> Self {
        RecalibPolicy {
            trip_rate: 0.25,
            min_cols: 8,
        }
    }
}

/// Per-key circuit breaker policy: how many consecutive faulted batches
/// (non-finite residual/cotangent norms or a failed calibration) open the
/// breaker, and how many degraded batches it serves before the half-open
/// probe. Batch-granular and clock-free, so replays are deterministic.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive faulted batches before the breaker opens.
    pub threshold: u32,
    /// Degraded batches served while open before the half-open probe.
    pub cooldown: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            threshold: 3,
            cooldown: 4,
        }
    }
}

/// Circuit-breaker state ([`CircuitBreaker`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: the backward serves the cached SHINE estimate.
    Closed,
    /// Degrading: `remaining` more batches serve the Jacobian-free
    /// direction before the half-open probe.
    Open { remaining: u32 },
    /// Probing: the next batch runs through the estimate again; a clean
    /// batch closes the breaker, a faulted one re-opens it.
    HalfOpen,
}

/// Graceful-degradation circuit breaker for one serving key.
///
/// A key whose model emits non-finite values (or whose calibration probe
/// fails) would otherwise trip the §3 guard on every batch forever. The
/// breaker counts *consecutive* faulted batches; at
/// [`BreakerConfig::threshold`] it opens and the engine degrades the
/// backward from the cached SHINE estimate to the guaranteed-cheap
/// Jacobian-free direction (`w = dz` — the
/// [`JacobianFree`](crate::solvers::session::Backward) variant) while the
/// estimate itself is retained. After [`BreakerConfig::cooldown`] degraded
/// batches it half-opens: one probe batch runs through the estimate, and a
/// clean probe closes the breaker. Everything is counted in batches, not
/// wall-clock, so a seeded fault plan replays bit-for-bit.
#[derive(Clone, Copy, Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    strikes: u32,
    trips: usize,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: BreakerState::Closed,
            strikes: 0,
            trips: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Whether the breaker currently degrades the backward (open only; the
    /// half-open probe deliberately serves the estimate again).
    pub fn is_open(&self) -> bool {
        matches!(self.state, BreakerState::Open { .. })
    }

    /// Times the breaker has opened over its lifetime.
    pub fn trips(&self) -> usize {
        self.trips
    }

    /// Record one served batch (or one failed calibration, which counts as
    /// a faulted batch): advances the Closed → Open → HalfOpen → Closed
    /// cycle.
    pub fn on_batch(&mut self, faulted: bool) {
        match self.state {
            BreakerState::Closed => {
                if faulted {
                    self.strikes += 1;
                    if self.strikes >= self.cfg.threshold {
                        self.state = BreakerState::Open {
                            remaining: self.cfg.cooldown,
                        };
                        self.trips += 1;
                    }
                } else {
                    self.strikes = 0;
                }
            }
            BreakerState::Open { remaining } => {
                // The batch just served degraded; burn one cooldown slot
                // regardless of its health (degraded output is w = dz, so
                // its health says nothing about the estimate).
                if remaining <= 1 {
                    self.state = BreakerState::HalfOpen;
                } else {
                    self.state = BreakerState::Open {
                        remaining: remaining - 1,
                    };
                }
            }
            BreakerState::HalfOpen => {
                if faulted {
                    self.state = BreakerState::Open {
                        remaining: self.cfg.cooldown,
                    };
                    self.trips += 1;
                } else {
                    self.state = BreakerState::Closed;
                    self.strikes = 0;
                }
            }
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Widest batch `process` accepts (per-column solver state is sized for
    /// it up front).
    pub max_batch: usize,
    /// The batched forward solver — method, tolerance and iteration budget
    /// in one value (Picard/Anderson batch; a Broyden spec solves columns
    /// sequentially).
    pub solver: SolverSpec,
    /// The calibration probe whose captured inverse estimate the batch
    /// backward reuses (Broyden; paper memory 30).
    pub calib: SolverSpec,
    /// SHINE fallback guard per column (paper §3): a cotangent whose panel
    /// answer grows beyond `ratio · ‖dz‖` reverts to the Jacobian-free
    /// direction. `None` disables the guard.
    pub fallback_ratio: Option<f64>,
    /// Estimate-staleness policy driven by the guard trip rate. `None`
    /// never flags the estimate stale.
    pub recalib: Option<RecalibPolicy>,
    /// Continuous batching only ([`ServeEngine::process_streaming`]):
    /// iterations a column may spend in one block residency before the
    /// streaming loop **evicts** it for retry, so a single hard request
    /// cannot hold a slot for the solver's whole `max_iters` while admitted
    /// work queues behind it. The evicted iterate is preserved and handed
    /// back for re-admission. `None` disables eviction; the discrete
    /// [`ServeEngine::process`] path ignores this.
    pub col_budget: Option<usize>,
    /// Per-key circuit breaker ([`CircuitBreaker`]): opens after
    /// `threshold` consecutive faulted batches and degrades the backward to
    /// the Jacobian-free direction while open. `None` disables breaking
    /// (legacy behaviour — a sick key trips the §3 guard forever).
    pub breaker: Option<BreakerConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 32,
            solver: SolverSpec::picard(1.0).with_tol(1e-6).with_max_iters(200),
            calib: SolverSpec::broyden(30).with_tol(1e-6).with_max_iters(60),
            fallback_ratio: None,
            recalib: None,
            col_budget: None,
            breaker: None,
        }
    }
}

impl EngineConfig {
    /// Set one tolerance on both the forward solver and the calibration
    /// probe (the common case; callers needing different tolerances set the
    /// specs directly).
    pub fn with_tol(mut self, tol: f64) -> EngineConfig {
        self.solver = self.solver.with_tol(tol);
        self.calib = self.calib.with_tol(tol);
        self
    }

    /// Typed validation of every engine invariant
    /// ([`ServeEngine::try_new`] calls this); malformed CLI input becomes
    /// an error instead of an abort.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_batch == 0 {
            return Err(ConfigError::ZeroMaxBatch);
        }
        // Only a quasi-Newton probe captures the inverse estimate
        // `calibrate` stores.
        if !matches!(
            self.calib.method,
            crate::solvers::session::SolverMethod::Broyden { .. }
        ) {
            return Err(ConfigError::NonBroydenCalibration);
        }
        if let Some(r) = self.fallback_ratio {
            if !r.is_finite() || r <= 0.0 {
                return Err(ConfigError::BadFallbackRatio(r));
            }
        }
        if let Some(p) = self.recalib {
            if !p.trip_rate.is_finite() || p.trip_rate <= 0.0 {
                return Err(ConfigError::BadTripRate(p.trip_rate));
            }
            if p.min_cols == 0 {
                return Err(ConfigError::ZeroMinCols);
            }
        }
        if self.col_budget == Some(0) {
            return Err(ConfigError::ZeroColBudget);
        }
        if let Some(bk) = self.breaker {
            if bk.threshold == 0 {
                return Err(ConfigError::ZeroBreakerThreshold);
            }
        }
        Ok(())
    }
}

/// What the admission callback hands [`ServeEngine::process_streaming`] for
/// one injected request: the caller-side request id (threaded through the
/// batched residual's `ids` slice and the retirement callback) and the
/// iteration budget of this residency.
#[derive(Clone, Copy, Debug)]
pub struct Admission {
    /// Caller-side request id.
    pub id: usize,
    /// Iterations this request may spend (across residencies) before it is
    /// retired unconverged; re-admitted evictees pass their remaining
    /// budget. Capped per residency by [`EngineConfig::col_budget`].
    pub budget: usize,
}

/// Telemetry for one [`ServeEngine::process_streaming`] call (which serves
/// many requests: the loop runs until the in-flight block drains and the
/// admission callback reports no more work).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamReport {
    /// Requests retired for good (converged or budget-exhausted);
    /// evictions are not counted here.
    pub served: usize,
    /// Eviction events — stragglers that hit
    /// [`EngineConfig::col_budget`] and were handed back for retry.
    pub evictions: usize,
    /// Residual sweeps over the active block (one batched `g` evaluation
    /// each — the streaming analogue of `fwd_iters_max`).
    pub sweeps: usize,
    /// Mean active width per sweep (block utilisation under the offered
    /// load; the continuous-batching win is keeping this high while
    /// discrete batch formation idles).
    pub mean_width: f64,
    /// Sum of per-residency iteration counts across all retirements.
    pub col_iters_total: usize,
    /// Columns reverted to the Jacobian-free direction by the §3 guard.
    pub fallback_cols: usize,
    /// Retired columns whose residual or cotangent norm was non-finite
    /// (each counts as a guard trip and a circuit-breaker strike).
    pub nonfinite_cols: usize,
    /// Whether any wave of this call served the degraded (breaker-open)
    /// Jacobian-free backward.
    pub degraded: bool,
    /// Every finally-retired request converged.
    pub all_converged: bool,
    /// Whether the shared estimate crossed the staleness threshold as of
    /// the end of this call.
    pub estimate_stale: bool,
    /// Wall-clock of the whole call.
    pub seconds: f64,
    /// Wall-clock spent in the per-wave backward sweeps.
    pub bwd_seconds: f64,
}

/// Telemetry for one served batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Columns in this batch.
    pub batch: usize,
    /// Forward iterations of the slowest column (= solver sweeps run).
    pub fwd_iters_max: usize,
    /// Sum of per-column forward iterations (what a sequential server would
    /// have paid in residual evaluations).
    pub fwd_col_iters_total: usize,
    pub all_converged: bool,
    /// Columns reverted to the Jacobian-free direction by the guard.
    pub fallback_cols: usize,
    /// Columns whose residual or cotangent norm was non-finite — the model
    /// (or the caller's seed) emitted NaN/Inf. Each counts as a guard trip
    /// and a circuit-breaker strike; none of them can poison
    /// `fallback_rate`, which stays a finite integer ratio.
    pub nonfinite_cols: usize,
    /// Whether this batch served the degraded (breaker-open) Jacobian-free
    /// backward instead of the cached SHINE estimate.
    pub degraded: bool,
    /// This batch's guard trip rate (`fallback_cols / batch`).
    pub fallback_rate: f64,
    /// Whether the shared estimate crossed the staleness threshold
    /// ([`RecalibPolicy`]) as of this batch — the owner should evict and
    /// re-calibrate.
    pub estimate_stale: bool,
    pub fwd_seconds: f64,
    pub bwd_seconds: f64,
}

/// Serves batches of DEQ requests against one residual map: batched forward
/// solve on a contiguous state block, then a single multi-RHS panel sweep
/// answering every SHINE cotangent. Holds the built forward solver (whose
/// per-column state persists across batches), the solve session and the
/// shared calibration estimate — nothing is allocated per batch once warm.
///
/// The engine carries three storage parameters: `E` is the state/cotangent
/// precision every solve runs in, and `EU`/`EV` (defaulting to `E`) are the
/// **panel storage** precisions of the cached estimate. Calibration always
/// runs at `E`; the captured estimate is then *demoted* into the
/// `LowRank<EU, EV>` layout (e.g. `ServeEngine<f32, Bf16, f32>` — the mixed
/// layout, half the U-panel traffic on the backward sweep), and the §3
/// fallback guard plus [`RecalibPolicy`] bound the damage a too-coarse
/// panel can do. Training and the bi-level experiments never see these
/// parameters — reduced precision is a serve-tier storage decision.
pub struct ServeEngine<E: Elem, EU: Elem = E, EV: Elem = EU> {
    d: usize,
    cfg: EngineConfig,
    /// Shared SHINE estimate demoted from the calibration probe's capture;
    /// `None` serves the Jacobian-free direction (w = dz).
    h: Option<LowRank<EU, EV>>,
    sess: Session<E>,
    solver: Box<dyn FixedPointSolver<E>>,
    /// Guarded columns / guard trips since the last calibration (the
    /// staleness counters the re-calibration policy reads).
    guard_cols: usize,
    guard_trips: usize,
    /// Calibrations performed over this engine's lifetime.
    calibrations: usize,
    /// Graceful-degradation breaker (None when `cfg.breaker` is None).
    breaker: Option<CircuitBreaker>,
}

impl<E: Elem, EU: Elem, EV: Elem> ServeEngine<E, EU, EV> {
    /// Build an engine, panicking on an invalid config (the in-process
    /// construction path where a bad config is a programming error; CLI
    /// surfaces go through [`ServeEngine::try_new`]).
    pub fn new(d: usize, cfg: EngineConfig) -> ServeEngine<E, EU, EV> {
        match Self::try_new(d, cfg) {
            Ok(e) => e,
            Err(e) => panic!("invalid engine config: {e}"),
        }
    }

    /// Build an engine, rejecting an invalid config with a typed error
    /// ([`EngineConfig::validate`]) instead of aborting the process.
    pub fn try_new(d: usize, cfg: EngineConfig) -> Result<ServeEngine<E, EU, EV>, ConfigError> {
        cfg.validate()?;
        let mut sess = Session::new();
        let mut solver = cfg.solver.build::<E>();
        solver.prepare_batch(d, cfg.max_batch, &mut sess);
        Ok(ServeEngine {
            d,
            cfg,
            h: None,
            sess,
            solver,
            guard_cols: 0,
            guard_trips: 0,
            calibrations: 0,
            breaker: cfg.breaker.map(CircuitBreaker::new),
        })
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The shared inverse estimate in its serving storage layout (None
    /// until [`ServeEngine::calibrate`]).
    pub fn estimate(&self) -> Option<&LowRank<EU, EV>> {
        self.h.as_ref()
    }

    /// Fallback-guard trip rate since the last calibration.
    pub fn trip_rate(&self) -> f64 {
        self.guard_trips as f64 / self.guard_cols.max(1) as f64
    }

    /// Whether the configured [`RecalibPolicy`] currently flags the shared
    /// estimate stale.
    pub fn estimate_stale(&self) -> bool {
        match self.cfg.recalib {
            Some(p) => {
                self.h.is_some()
                    && self.guard_cols >= p.min_cols
                    && self.trip_rate() > p.trip_rate
            }
            None => false,
        }
    }

    /// Drop the shared estimate (serving falls back to the Jacobian-free
    /// direction until the next [`ServeEngine::calibrate`]) and reset the
    /// staleness counters.
    pub fn invalidate_estimate(&mut self) {
        self.h = None;
        self.guard_cols = 0;
        self.guard_trips = 0;
    }

    /// Calibrations performed over this engine's lifetime.
    pub fn calibrations(&self) -> usize {
        self.calibrations
    }

    /// The graceful-degradation breaker, if configured.
    pub fn breaker(&self) -> Option<&CircuitBreaker> {
        self.breaker.as_ref()
    }

    /// Whether the breaker is currently open (degraded Jacobian-free
    /// serving). `false` when no breaker is configured.
    pub fn breaker_open(&self) -> bool {
        self.breaker.as_ref().is_some_and(|bk| bk.is_open())
    }

    /// Install an externally captured estimate (the router's per-key cache
    /// hand-off; tests use it to inject adversarial estimates), demoting it
    /// into the engine's panel storage layout. Resets the staleness
    /// counters — a fresh estimate starts with a clean record. At the
    /// homogeneous default (`EU = EV = E`) the demotion is a bit-exact copy.
    pub fn install_estimate(&mut self, h: EstimateHandle<E>) {
        self.h = Some(h.low_rank().convert());
        self.guard_cols = 0;
        self.guard_trips = 0;
    }

    /// Capture the shared SHINE estimate: one Broyden probe solve
    /// (`cfg.calib`) of the single-request residual `g1` from `z0`, whose
    /// captured [`EstimateHandle`] (`H ≈ J_g⁻¹`, exactly what SHINE shares
    /// with the backward pass) becomes the operator every batch backward
    /// applies. Returns the probe's (iterations, final residual).
    /// Re-calibrate whenever the served model's parameters move — or let
    /// the [`RecalibPolicy`] trip-rate tracking tell you when.
    pub fn calibrate(&mut self, g1: impl FnMut(&[E], &mut [E]), z0: &[E]) -> (usize, f64) {
        debug_assert_eq!(z0.len(), self.d);
        let mut probe = self.cfg.calib.build::<E>();
        let mut g1 = g1;
        let out = probe.solve(&mut self.sess, &mut g1, z0);
        let stats = (out.iters, out.residual);
        if out.residual_finite() {
            // Demote the freshly captured estimate into the serving layout
            // — the one narrow-once conversion point of the
            // reduced-precision path (bit-exact at the homogeneous
            // default).
            self.h = Some(
                out.estimate
                    .expect("calibration probe must capture an inverse estimate")
                    .low_rank()
                    .convert(),
            );
            if let Some(bk) = self.breaker.as_mut() {
                bk.on_batch(false);
            }
        } else {
            // Failed calibration: the model emitted NaN/Inf under the
            // probe. Whatever the probe captured approximates a garbage
            // Jacobian — serve Jacobian-free until a healthy probe lands,
            // and strike the breaker.
            self.h = None;
            if let Some(bk) = self.breaker.as_mut() {
                bk.on_batch(true);
            }
        }
        self.guard_cols = 0;
        self.guard_trips = 0;
        self.calibrations += 1;
        stats
    }

    /// Serve one batch.
    ///
    /// * `g` — batched residual: `g(block, ids, out)` evaluates
    ///   `ids.len()` active columns in one call (`ids[p]` = caller-side
    ///   column at physical position `p`, for per-request context lookup).
    /// * `zs` — d × B column-major initial iterates, overwritten with the
    ///   fixed points (submission order).
    /// * `cotangents` / `w_out` — d × B blocks: per-request backward seeds
    ///   `dz` and their SHINE directions `w ≈ J_g⁻ᵀ dz`, answered by ONE
    ///   `apply_t_multi` panel sweep for the whole batch (no per-request
    ///   panel applies).
    /// * `stats` — per-column forward outcomes (length ≥ B).
    ///
    /// Allocation-free once the engine is warm (see the module contract).
    ///
    /// # Examples
    ///
    /// Migrating from the deprecated free-function surface: a pre-session
    /// caller ran
    /// [`picard_solve_batch`](crate::solvers::fixed_point::picard_solve_batch)
    /// and then applied the shared panel once per request; the engine
    /// replaces both with one call (batched forward + a single multi-RHS
    /// backward sweep), with the solver and tolerances named once in
    /// [`EngineConfig`]:
    ///
    /// ```
    /// use shine::serve::{EngineConfig, ServeEngine, SynthDeq};
    /// use shine::solvers::fixed_point::ColStats;
    ///
    /// let (d, b) = (24, 2);
    /// let model: SynthDeq<f32> = SynthDeq::new(d, 6, 7);
    /// let mut engine: ServeEngine<f32> = ServeEngine::new(
    ///     d,
    ///     EngineConfig { max_batch: b, ..Default::default() }.with_tol(1e-5),
    /// );
    /// // One Broyden probe captures the shared SHINE estimate H ≈ J_g⁻¹.
    /// engine.calibrate(|z, out| model.residual_batch(z, 1, out), &vec![0.0f32; d]);
    ///
    /// let mut zs = vec![0.0f32; b * d]; // initial iterates, column-major
    /// let cots = vec![1.0f32; b * d]; // per-request cotangents dz
    /// let mut w = vec![0.0f32; b * d]; // receives w ≈ J_g⁻ᵀ dz per request
    /// let mut stats = vec![ColStats::default(); b];
    /// let report = engine.process(
    ///     |block, _ids, out| model.residual_batch(block, block.len() / d, out),
    ///     &mut zs,
    ///     &cots,
    ///     &mut w,
    ///     &mut stats,
    /// );
    /// assert!(report.all_converged && report.batch == b);
    /// ```
    pub fn process(
        &mut self,
        mut g: impl FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        cotangents: &[E],
        w_out: &mut [E],
        stats: &mut [ColStats],
    ) -> BatchReport {
        let d = self.d;
        assert_eq!(zs.len() % d, 0, "state block must be a whole number of columns");
        let b = zs.len() / d;
        assert!(b <= self.cfg.max_batch, "batch {b} exceeds max_batch {}", self.cfg.max_batch);
        assert_eq!(cotangents.len(), b * d);
        assert_eq!(w_out.len(), b * d);
        assert!(stats.len() >= b);
        let sw = Stopwatch::start();
        let solver = &mut self.solver;
        let sess = &mut self.sess;
        solver.solve_batch(sess, &mut g, zs, d, stats);
        let fwd_seconds = sw.elapsed();

        let sw = Stopwatch::start();
        // Backward: the whole batch of cotangents through ONE multi-RHS
        // panel sweep against the shared forward estimate — this is the
        // SHINE serving contract (uncalibrated engines answer with the
        // Jacobian-free identity direction). An open breaker degrades to
        // the same Jacobian-free direction with the estimate retained.
        // (Field access, not the accessor: `sess` above still borrows
        // `self.sess` mutably.)
        let degraded = self.breaker.as_ref().is_some_and(|bk| bk.is_open());
        let mut nonfinite_cols = 0usize;
        match &self.h {
            Some(h) if !degraded => h.apply_t_multi_into(cotangents, w_out, sess.workspace()),
            _ => w_out.copy_from_slice(cotangents),
        }
        let mut fallback_cols = 0usize;
        if let Some(ratio) = self.cfg.fallback_ratio {
            if self.h.is_some() && !degraded {
                for j in 0..b {
                    let dzn = nrm2(&cotangents[j * d..(j + 1) * d]);
                    let wn = nrm2(&w_out[j * d..(j + 1) * d]);
                    // A non-finite norm on either side is an unconditional
                    // trip: NaN fails every `>` comparison, so without the
                    // explicit check a NaN column would sail through the
                    // guard untouched.
                    let broken = !dzn.is_finite() || !wn.is_finite();
                    if broken || wn > ratio * dzn {
                        w_out[j * d..(j + 1) * d]
                            .copy_from_slice(&cotangents[j * d..(j + 1) * d]);
                        fallback_cols += 1;
                        if broken {
                            nonfinite_cols += 1;
                        }
                    }
                }
                // Staleness tracking: every guarded column counts toward the
                // cumulative trip rate of this calibration.
                self.guard_cols += b;
                self.guard_trips += fallback_cols;
            }
        }
        let bwd_seconds = sw.elapsed();

        let mut fwd_iters_max = 0usize;
        let mut fwd_col_iters_total = 0usize;
        let mut all_converged = true;
        for s in stats.iter().take(b) {
            fwd_iters_max = fwd_iters_max.max(s.iters);
            fwd_col_iters_total += s.iters;
            all_converged &= s.converged;
            if !s.residual.is_finite() {
                nonfinite_cols += 1;
            }
        }
        // One breaker observation per batch: any non-finite column is a
        // strike; a clean batch resets the strike run (or closes a
        // half-open breaker).
        if let Some(bk) = self.breaker.as_mut() {
            bk.on_batch(nonfinite_cols > 0);
        }
        BatchReport {
            batch: b,
            fwd_iters_max,
            fwd_col_iters_total,
            all_converged,
            fallback_cols,
            nonfinite_cols,
            degraded,
            fallback_rate: fallback_cols as f64 / b.max(1) as f64,
            estimate_stale: self.estimate_stale(),
            fwd_seconds,
            bwd_seconds,
        }
    }

    /// Serve a continuous stream of requests — the continuous-batching
    /// loop. Instead of drain → solve → drain discrete cycles, the engine
    /// keeps a long-lived in-flight d × B block and admits new requests
    /// directly into columns freed by retirement, **mid-solve**. Each
    /// column carries its own iteration counter and budget; injected
    /// columns get their per-column solver state reset
    /// ([`FixedPointSolver::stream_admit`]) without perturbing neighbours'
    /// trajectories, so every request still follows the bit-identical solo
    /// trajectory from its injection point (pinned by the admission-parity
    /// tests in `rust/tests/serve_batch.rs`).
    ///
    /// * `g` — batched residual, same contract as [`ServeEngine::process`]
    ///   (`ids[p]` = the admitted request id at physical column `p`).
    /// * `width` — polled once per sweep for the current admission cap
    ///   (clamped to `1..=max_batch`): the hook for the per-key adaptive
    ///   width controller ([`crate::serve::AdaptiveWidth`]). Shrinking it
    ///   never evicts residents — the block just drains to the new cap.
    /// * `admit` — called while slots are free: fill the column's initial
    ///   iterate and cotangent (both `d`-slices) and return the
    ///   [`Admission`], or `None` when no request is available right now.
    /// * `retire` — `retire(id, z, w, stats, evicted)` for every column
    ///   leaving the block. Final retirements get `w` = the SHINE
    ///   direction of the admitted cotangent (answered in per-wave
    ///   multi-RHS panel sweeps, §3 guard applied per column, exactly the
    ///   [`ServeEngine::process`] backward contract); evictions
    ///   (`evicted == true`: residency hit [`EngineConfig::col_budget`]
    ///   with budget left) get an empty `w` and the preserved iterate `z`
    ///   to re-admit with.
    ///
    /// Returns when the block is empty and `admit` reports no work — call
    /// again when new requests arrive; solver state and buffers stay warm.
    pub fn process_streaming(
        &mut self,
        mut g: impl FnMut(&[E], &[usize], &mut [E]),
        mut width: impl FnMut() -> usize,
        mut admit: impl FnMut(&mut [E], &mut [E]) -> Option<Admission>,
        mut retire: impl FnMut(usize, &[E], &[E], ColStats, bool),
    ) -> StreamReport {
        assert!(
            self.solver.supports_streaming(),
            "solver '{}' does not support streaming (continuous batching needs \
             per-column-independent updates; use picard or anderson)",
            self.cfg.solver.method.name()
        );
        let d = self.d;
        let cap = self.cfg.max_batch;
        let tol = self.cfg.solver.tol;
        let sw = Stopwatch::start();
        // In-flight block state: iterates, residuals, cotangents, the
        // retirement staging blocks, and the per-column id/counter/budget
        // registers. All pooled; give-backs below run in reverse take
        // order per the workspace's LIFO discipline.
        let (mut zs, mut r, mut cot, mut stage_z, mut stage_cot, mut stage_w) = {
            let ws = self.sess.workspace();
            (
                ws.take(cap * d),
                ws.take(cap * d),
                ws.take(cap * d),
                ws.take(cap * d),
                ws.take(cap * d),
                ws.take(cap * d),
            )
        };
        let (mut ids, mut iters_col, mut budgets) = {
            let ws = self.sess.workspace();
            (ws.take_idx(cap), ws.take_idx(cap), ws.take_idx(cap))
        };
        // Retirement wave of the current sweep: (request id, stats,
        // evicted). One small allocation per call, not per batch.
        let mut wave: Vec<(usize, ColStats, bool)> = Vec::with_capacity(cap);
        let mut rep = StreamReport {
            all_converged: true,
            ..Default::default()
        };
        let mut occupancy = 0usize;
        let mut active = 0usize;
        loop {
            // --- admission into freed tail slots, up to the polled width.
            let w_cap = width().clamp(1, cap);
            while active < w_cap {
                let (zcol, ccol) = (
                    &mut zs[active * d..(active + 1) * d],
                    &mut cot[active * d..(active + 1) * d],
                );
                match admit(zcol, ccol) {
                    Some(a) => {
                        ids[active] = a.id;
                        budgets[active] = a.budget;
                        iters_col[active] = 0;
                        self.solver.stream_admit(active);
                        active += 1;
                    }
                    None => break,
                }
            }
            if active == 0 {
                break;
            }
            // --- one residual evaluation over the whole active prefix.
            g(&zs[..active * d], &ids[..active], &mut r[..active * d]);
            rep.sweeps += 1;
            occupancy += active;
            // --- retirement scan (re-examine j after each swap: the
            // swapped-in column's residual moved with it).
            wave.clear();
            let mut bw = 0usize; // staged backward columns (non-evicted)
            let mut wave_fault = false;
            let mut j = 0usize;
            while j < active {
                let n = nrm2(&r[j * d..(j + 1) * d]);
                // A non-finite residual can only get worse: retire the
                // column now (as a final, unconverged outcome — never an
                // eviction) instead of burning its whole budget on NaN
                // sweeps. This is the mid-solve fault-eviction path.
                let broken = !n.is_finite();
                let converged = n <= tol;
                let exhausted = !converged && iters_col[j] >= budgets[j];
                let evict = !converged
                    && !exhausted
                    && !broken
                    && self.cfg.col_budget.is_some_and(|cb| iters_col[j] >= cb);
                if broken {
                    rep.nonfinite_cols += 1;
                    wave_fault = true;
                }
                if converged || exhausted || evict || broken {
                    let wi = wave.len();
                    let st = ColStats {
                        iters: iters_col[j],
                        residual: n,
                        converged,
                    };
                    wave.push((ids[j], st, evict));
                    stage_z[wi * d..(wi + 1) * d].copy_from_slice(&zs[j * d..(j + 1) * d]);
                    if !evict {
                        stage_cot[bw * d..(bw + 1) * d].copy_from_slice(&cot[j * d..(j + 1) * d]);
                        bw += 1;
                    }
                    active -= 1;
                    if j != active {
                        swap_cols(&mut zs, d, j, active);
                        swap_cols(&mut r, d, j, active);
                        swap_cols(&mut cot, d, j, active);
                        ids.swap(j, active);
                        iters_col.swap(j, active);
                        budgets.swap(j, active);
                        self.solver.stream_swap(j, active);
                    }
                } else {
                    j += 1;
                }
            }
            // --- one multi-RHS backward sweep for this retirement wave,
            // then the §3 guard per column (the `process` contract).
            if bw > 0 {
                let swb = Stopwatch::start();
                let degraded = self.breaker_open();
                if degraded {
                    rep.degraded = true;
                }
                match &self.h {
                    Some(h) if !degraded => h.apply_t_multi_into(
                        &stage_cot[..bw * d],
                        &mut stage_w[..bw * d],
                        self.sess.workspace(),
                    ),
                    _ => stage_w[..bw * d].copy_from_slice(&stage_cot[..bw * d]),
                }
                if let Some(ratio) = self.cfg.fallback_ratio {
                    if self.h.is_some() && !degraded {
                        let mut trips = 0usize;
                        for k in 0..bw {
                            let dzn = nrm2(&stage_cot[k * d..(k + 1) * d]);
                            let wn = nrm2(&stage_w[k * d..(k + 1) * d]);
                            // Non-finite on either side trips
                            // unconditionally (NaN fails `>`, see
                            // `process`).
                            let broken = !dzn.is_finite() || !wn.is_finite();
                            if broken || wn > ratio * dzn {
                                stage_w[k * d..(k + 1) * d]
                                    .copy_from_slice(&stage_cot[k * d..(k + 1) * d]);
                                trips += 1;
                                if broken {
                                    rep.nonfinite_cols += 1;
                                    wave_fault = true;
                                }
                            }
                        }
                        self.guard_cols += bw;
                        self.guard_trips += trips;
                        rep.fallback_cols += trips;
                    }
                }
                rep.bwd_seconds += swb.elapsed();
            }
            // One breaker observation per retirement wave (the streaming
            // analogue of a served batch).
            if !wave.is_empty() {
                if let Some(bk) = self.breaker.as_mut() {
                    bk.on_batch(wave_fault);
                }
            }
            // --- hand every retired column back to the caller.
            let mut k = 0usize;
            for (wi, &(id, st, evicted)) in wave.iter().enumerate() {
                let z_fin = &stage_z[wi * d..(wi + 1) * d];
                rep.col_iters_total += st.iters;
                if evicted {
                    rep.evictions += 1;
                    retire(id, z_fin, &[], st, true);
                } else {
                    rep.served += 1;
                    rep.all_converged &= st.converged;
                    retire(id, z_fin, &stage_w[k * d..(k + 1) * d], st, false);
                    k += 1;
                }
            }
            // --- advance the survivors one iteration.
            if active > 0 {
                self.solver.stream_advance(
                    &mut self.sess,
                    &mut zs[..active * d],
                    &r[..active * d],
                    d,
                );
                for it in iters_col.iter_mut().take(active) {
                    *it += 1;
                }
            }
        }
        rep.mean_width = occupancy as f64 / rep.sweeps.max(1) as f64;
        rep.estimate_stale = self.estimate_stale();
        rep.seconds = sw.elapsed();
        let ws = self.sess.workspace();
        ws.give_idx(budgets);
        ws.give_idx(iters_col);
        ws.give_idx(ids);
        ws.give(stage_w);
        ws.give(stage_cot);
        ws.give(stage_z);
        ws.give(cot);
        ws.give(r);
        ws.give(zs);
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qn::{LowRank, MemoryPolicy};
    use crate::solvers::fixed_point::picard_solve;
    use crate::util::rng::Rng;

    /// Positional contractive residual shared by every column:
    /// g(z)[i] = z[i] − 0.3·z[(i+1) mod d] − bias[i mod d].
    fn test_g(bias: &[f64], block: &[f64], d: usize, out: &mut [f64]) {
        let k = block.len() / d;
        for p in 0..k {
            for i in 0..d {
                let zn = block[p * d + (i + 1) % d];
                out[p * d + i] = block[p * d + i] - 0.3 * zn - bias[i];
            }
        }
    }

    #[test]
    fn uncalibrated_engine_serves_jacobian_free() {
        let d = 16;
        let b = 3;
        let mut rng = Rng::new(1);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                ..Default::default()
            }
            .with_tol(1e-10),
        );
        let mut zs = vec![0.0; b * d];
        let cots: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(rep.all_converged);
        assert_eq!(w, cots); // identity backward without calibration
        // Forward parity with the sequential solver, column by column.
        for j in 0..b {
            let (z, _, it) = picard_solve(
                |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
                &vec![0.0; d],
                1.0,
                1e-10,
                200,
            );
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..]);
            assert_eq!(stats[j].iters, it);
        }
    }

    #[test]
    fn calibrated_backward_is_one_shared_sweep() {
        use crate::qn::InvOp;
        let d = 20;
        let b = 4;
        let mut rng = Rng::new(2);
        let bias = rng.normal_vec(d);
        let mut cfg = EngineConfig {
            max_batch: b,
            ..Default::default()
        }
        .with_tol(1e-11);
        cfg.calib = SolverSpec::broyden(10).with_tol(1e-11).with_max_iters(60);
        let mut eng: ServeEngine<f64> = ServeEngine::new(d, cfg);
        let (it, rn) = eng.calibrate(
            |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
            &vec![0.0; d],
        );
        assert!(rn <= 1e-11, "probe residual {rn} after {it} iters");
        assert_eq!(eng.calibrations(), 1);
        let mut zs = vec![0.0; b * d];
        let cots: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        // The one-sweep multi answer must equal per-column H^T applies.
        let h = eng.estimate().unwrap();
        for j in 0..b {
            let want = h.apply_t_vec(&cots[j * d..(j + 1) * d]);
            assert_eq!(&w[j * d..(j + 1) * d], &want[..], "col {j}");
        }
    }

    #[test]
    fn mixed_precision_engine_tracks_f32_backward() {
        // ServeEngine<f32, Bf16, f32>: calibration runs at f32, the capture
        // is demoted into the mixed panel layout, and the backward sweep
        // stays within bf16 storage tolerance of the homogeneous f32 engine
        // on the same request stream — with the §3 guard armed and silent.
        use crate::linalg::vecops::Bf16;
        let d = 24;
        let b = 3;
        let mut rng = Rng::new(12);
        let bias: Vec<f32> = rng.normal_vec(d).iter().map(|&x| x as f32 * 0.1).collect();
        let g32 = |block: &[f32], out: &mut [f32]| {
            let k = block.len() / d;
            for p in 0..k {
                for i in 0..d {
                    let zn = block[p * d + (i + 1) % d];
                    out[p * d + i] = block[p * d + i] - 0.3 * zn - bias[i];
                }
            }
        };
        let mut cfg = EngineConfig {
            max_batch: b,
            fallback_ratio: Some(4.0),
            ..Default::default()
        }
        .with_tol(1e-5);
        cfg.calib = SolverSpec::broyden(10).with_tol(1e-5).with_max_iters(60);
        let mut full: ServeEngine<f32> = ServeEngine::new(d, cfg);
        let mut mixed: ServeEngine<f32, Bf16, f32> = ServeEngine::new(d, cfg);
        let z0 = vec![0.0f32; d];
        full.calibrate(|z: &[f32], out: &mut [f32]| g32(z, out), &z0);
        mixed.calibrate(|z: &[f32], out: &mut [f32]| g32(z, out), &z0);
        let cots: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let mut stats = vec![ColStats::default(); b];
        let mut zs = vec![0.0f32; b * d];
        let mut w_full = vec![0.0f32; b * d];
        let rep_full = full.process(
            |block, _ids, out| g32(block, out),
            &mut zs,
            &cots,
            &mut w_full,
            &mut stats,
        );
        zs.iter_mut().for_each(|z| *z = 0.0);
        let mut w_mixed = vec![0.0f32; b * d];
        let rep_mixed = mixed.process(
            |block, _ids, out| g32(block, out),
            &mut zs,
            &cots,
            &mut w_mixed,
            &mut stats,
        );
        assert!(rep_full.all_converged && rep_mixed.all_converged);
        assert_eq!(rep_mixed.fallback_cols, 0, "guard must stay silent on a healthy estimate");
        // bf16 keeps ~8 mantissa bits: per-element agreement at ~1% of the
        // vector scale is the expected storage-rounding envelope here.
        for i in 0..b * d {
            let wf = w_full[i] as f64;
            assert!(
                (w_mixed[i] as f64 - wf).abs() <= 2e-2 * (1.0 + wf.abs()),
                "idx {i}: mixed {} vs f32 {}",
                w_mixed[i],
                wf
            );
        }
    }

    #[test]
    fn anderson_engine_converges_and_reuses_state() {
        let d = 14;
        let b = 3;
        let mut rng = Rng::new(3);
        let bias = rng.normal_vec(d);
        let mut cfg = EngineConfig {
            max_batch: b,
            ..Default::default()
        }
        .with_tol(1e-10);
        cfg.solver = SolverSpec::anderson(4, 1.0).with_tol(1e-10).with_max_iters(200);
        let mut eng: ServeEngine<f64> = ServeEngine::new(d, cfg);
        let cots = vec![0.0; b * d];
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let mut zs1 = vec![0.0; b * d];
        let r1 = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs1,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(r1.all_converged);
        // Second batch through the SAME engine (persistent Anderson state)
        // must reproduce the first bit-for-bit.
        let mut zs2 = vec![0.0; b * d];
        let r2 = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs2,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(zs1, zs2);
        assert_eq!(r1.fwd_iters_max, r2.fwd_iters_max);
    }

    /// An adversarial estimate: H = I + 10·e0 e0ᵀ blows up any cotangent
    /// with mass on coordinate 0.
    fn blown_estimate(d: usize) -> EstimateHandle<f64> {
        let mut h = LowRank::identity(d, 2, MemoryPolicy::Evict);
        let mut e0 = vec![0.0; d];
        e0[0] = 1.0;
        let u: Vec<f64> = e0.iter().map(|x| 10.0 * x).collect();
        h.push(&u, &e0);
        EstimateHandle::new(h)
    }

    #[test]
    fn fallback_guard_reverts_blown_up_columns() {
        let d = 8;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 2,
                fallback_ratio: Some(1.5),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        eng.install_estimate(blown_estimate(d));
        let mut zs = vec![0.0; 2 * d];
        let mut cots = vec![0.0; 2 * d];
        cots[0] = 1.0; // col 0: all mass on coordinate 0 → 11x growth
        cots[d + 1] = 1.0; // col 1: orthogonal to the factor → untouched
        let mut w = vec![0.0; 2 * d];
        let mut stats = vec![ColStats::default(); 2];
        let bias = vec![0.1; d];
        let rep = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(rep.fallback_cols, 1);
        assert!((rep.fallback_rate - 0.5).abs() < 1e-12);
        assert_eq!(&w[..d], &cots[..d]); // reverted to Jacobian-free
        assert_eq!(w[d + 1], 1.0); // untouched column passes through
    }

    #[test]
    fn streaming_serves_queue_through_narrow_block() {
        // Five requests stream through a width-2 block: admissions fill
        // freed columns mid-solve and every request still matches its solo
        // Picard run bit-for-bit.
        let d = 12;
        let mut rng = Rng::new(4);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 2,
                ..Default::default()
            }
            .with_tol(1e-10),
        );
        let n_req = 5;
        let z0s: Vec<Vec<f64>> = (0..n_req).map(|_| rng.normal_vec(d)).collect();
        let mut next = 0usize;
        let mut done: Vec<Option<(Vec<f64>, ColStats)>> = vec![None; n_req];
        let rep = eng.process_streaming(
            |block, _ids, out| test_g(&bias, block, d, out),
            || 2,
            |z, c| {
                if next >= n_req {
                    return None;
                }
                z.copy_from_slice(&z0s[next]);
                c.iter_mut().for_each(|x| *x = 0.0);
                let a = Admission {
                    id: next,
                    budget: 200,
                };
                next += 1;
                Some(a)
            },
            |id, z, _w, st, evicted| {
                assert!(!evicted);
                done[id] = Some((z.to_vec(), st));
            },
        );
        assert_eq!(rep.served, n_req);
        assert_eq!(rep.evictions, 0);
        assert!(rep.all_converged);
        assert!(rep.mean_width > 1.0, "block mostly full: {}", rep.mean_width);
        for (id, slot) in done.iter().enumerate() {
            let (z, st) = slot.as_ref().expect("every request retires");
            let (z_ref, rn, it) = picard_solve(
                |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
                &z0s[id],
                1.0,
                1e-10,
                200,
            );
            assert_eq!(&z[..], &z_ref[..], "req {id}: iterate bits");
            assert_eq!(st.iters, it, "req {id}: iteration count");
            assert_eq!(st.residual, rn, "req {id}: residual bits");
        }
    }

    #[test]
    fn eviction_preserves_iterate_and_resume_matches_solo() {
        // A col_budget below the iterations needed forces evict-and-retry:
        // each residency runs exactly col_budget iterations, the evicted
        // iterate is handed back intact, and the resumed trajectory lands
        // on the solo fixed point with the same total iteration count.
        let d = 10;
        let col_budget = 7usize;
        let mut rng = Rng::new(9);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 1,
                col_budget: Some(col_budget),
                ..Default::default()
            }
            .with_tol(1e-10),
        );
        let z0 = rng.normal_vec(d);
        let (z_ref, rn_ref, it_ref) = picard_solve(
            |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
            &z0,
            1.0,
            1e-10,
            200,
        );
        assert!(it_ref > col_budget, "need a straggler: {it_ref} iters");
        let mut pending: Option<(Vec<f64>, usize)> = Some((z0.clone(), 200));
        let mut done: Option<(Vec<f64>, ColStats)> = None;
        let mut total_iters = 0usize;
        let mut residencies = 0usize;
        while done.is_none() {
            let mut admit_src = pending.take();
            let mut handoff: Option<Vec<f64>> = None;
            let rep = eng.process_streaming(
                |block, _ids, out| test_g(&bias, block, d, out),
                || 1,
                |z, c| {
                    let (zi, budget) = admit_src.take()?;
                    z.copy_from_slice(&zi);
                    c.iter_mut().for_each(|x| *x = 0.0);
                    Some(Admission { id: 0, budget })
                },
                |_id, z, _w, st, evicted| {
                    total_iters += st.iters;
                    if evicted {
                        assert_eq!(st.iters, col_budget, "residency hits the cap");
                        handoff = Some(z.to_vec());
                    } else {
                        done = Some((z.to_vec(), st));
                    }
                },
            );
            residencies += 1;
            assert!(rep.sweeps > 0);
            if let Some(z) = handoff {
                pending = Some((z, 200 - total_iters));
            }
        }
        let (z_fin, st) = done.unwrap();
        assert_eq!(&z_fin[..], &z_ref[..], "resumed iterate bits");
        assert_eq!(total_iters, it_ref, "total iterations across residencies");
        assert_eq!(st.residual, rn_ref, "final residual bits");
        assert!(st.converged);
        assert_eq!(residencies, it_ref.div_ceil(col_budget));
    }

    #[test]
    fn trip_rate_staleness_flags_and_resets() {
        // Every cotangent has mass on coordinate 0, so the blown estimate
        // trips the guard on every column: after enough guarded columns the
        // policy must flag the estimate stale, and invalidation must reset
        // the counters and drop back to Jacobian-free serving.
        let d = 8;
        let b = 4;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                fallback_ratio: Some(1.5),
                recalib: Some(RecalibPolicy {
                    trip_rate: 0.5,
                    min_cols: 2 * b,
                }),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        eng.install_estimate(blown_estimate(d));
        let bias = vec![0.1; d];
        let mut cots = vec![0.0; b * d];
        for j in 0..b {
            cots[j * d] = 1.0;
        }
        let mut zs = vec![0.0; b * d];
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep1 = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        // First batch trips 100% but has not reached min_cols yet.
        assert_eq!(rep1.fallback_cols, b);
        assert!((rep1.fallback_rate - 1.0).abs() < 1e-12);
        assert!(!rep1.estimate_stale, "min_cols not reached after one batch");
        zs.iter_mut().for_each(|z| *z = 0.0);
        let rep2 = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(rep2.estimate_stale, "2·b guarded columns at 100% trip rate");
        assert!(eng.estimate_stale());
        assert!(eng.trip_rate() > 0.99);
        eng.invalidate_estimate();
        assert!(!eng.estimate_stale());
        assert!(eng.estimate().is_none());
        assert_eq!(eng.trip_rate(), 0.0);
        // Uncalibrated serving is Jacobian-free again.
        zs.iter_mut().for_each(|z| *z = 0.0);
        let rep3 = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(rep3.fallback_cols, 0);
        assert_eq!(w, cots);
    }

    #[test]
    fn engine_config_rejections_are_typed() {
        let ok = EngineConfig::default();
        assert!(ok.validate().is_ok());
        let mut c = ok;
        c.max_batch = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroMaxBatch));
        let mut c = ok;
        c.calib = SolverSpec::picard(1.0);
        assert_eq!(c.validate(), Err(ConfigError::NonBroydenCalibration));
        assert!(ServeEngine::<f64>::try_new(8, c).is_err());
        let mut c = ok;
        c.fallback_ratio = Some(f64::NAN);
        assert!(matches!(c.validate(), Err(ConfigError::BadFallbackRatio(r)) if r.is_nan()));
        let mut c = ok;
        c.fallback_ratio = Some(-1.0);
        assert_eq!(c.validate(), Err(ConfigError::BadFallbackRatio(-1.0)));
        let mut c = ok;
        c.recalib = Some(RecalibPolicy {
            trip_rate: 0.0,
            min_cols: 8,
        });
        assert_eq!(c.validate(), Err(ConfigError::BadTripRate(0.0)));
        let mut c = ok;
        c.recalib = Some(RecalibPolicy {
            trip_rate: 0.25,
            min_cols: 0,
        });
        assert_eq!(c.validate(), Err(ConfigError::ZeroMinCols));
        let mut c = ok;
        c.col_budget = Some(0);
        assert_eq!(c.validate(), Err(ConfigError::ZeroColBudget));
        let mut c = ok;
        c.breaker = Some(BreakerConfig {
            threshold: 0,
            cooldown: 2,
        });
        assert_eq!(c.validate(), Err(ConfigError::ZeroBreakerThreshold));
    }

    #[test]
    fn nan_cotangent_trips_guard_and_keeps_rate_finite() {
        // Regression for the NaN hole: `wn > ratio * dzn` is false when
        // either norm is NaN, so a poisoned column used to sail through
        // the guard and (worse) could make fallback_rate NaN. It must
        // count as a trip and a non-finite column instead.
        let d = 8;
        let b = 2;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                fallback_ratio: Some(1.5),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        eng.install_estimate(blown_estimate(d));
        let bias = vec![0.1; d];
        let mut zs = vec![0.0; b * d];
        let mut cots = vec![0.0; b * d];
        cots[0] = f64::NAN; // col 0 poisoned
        cots[d + 1] = 1.0; // col 1 healthy and orthogonal to the factor
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(rep.fallback_cols, 1, "NaN column must count as a trip");
        assert_eq!(rep.nonfinite_cols, 1);
        assert!(rep.fallback_rate.is_finite());
        assert!((rep.fallback_rate - 0.5).abs() < 1e-12);
        assert_eq!(w[d + 1], 1.0, "healthy column unaffected");
    }

    #[test]
    fn breaker_opens_degrades_and_recovers() {
        // Two faulted batches open the breaker; while open the backward is
        // the Jacobian-free direction even though the estimate is retained;
        // after the cooldown the half-open probe runs through the estimate
        // and a clean batch closes the breaker.
        let d = 8;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 1,
                fallback_ratio: Some(1e6), // guard present but lenient
                breaker: Some(BreakerConfig {
                    threshold: 2,
                    cooldown: 1,
                }),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        let bias = vec![0.1; d];
        let g = |block: &[f64], out: &mut [f64]| test_g(&bias, block, d, out);
        eng.calibrate(|z: &[f64], out: &mut [f64]| g(z, out), &vec![0.0; d]);
        let mut run = |eng: &mut ServeEngine<f64>, cot0: f64| {
            let mut zs = vec![0.0; d];
            let mut cots = vec![0.0; d];
            cots[0] = cot0;
            let mut w = vec![0.0; d];
            let mut stats = vec![ColStats::default(); 1];
            let rep = eng.process(
                |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
                &mut zs,
                &cots,
                &mut w,
                &mut stats,
            );
            (rep, w, cots)
        };
        // Strike 1 and 2: NaN cotangents.
        let (r1, _, _) = run(&mut eng, f64::NAN);
        assert_eq!(r1.nonfinite_cols, 1);
        assert!(!eng.breaker_open(), "one strike below threshold");
        let (_, _, _) = run(&mut eng, f64::NAN);
        assert!(eng.breaker_open(), "threshold reached: breaker open");
        assert_eq!(eng.breaker().unwrap().trips(), 1);
        // Open: a clean batch serves degraded (w == dz bit-for-bit despite
        // the installed estimate) and burns the cooldown slot.
        let (r3, w3, c3) = run(&mut eng, 1.0);
        assert!(r3.degraded);
        assert_eq!(w3, c3, "degraded backward is Jacobian-free");
        assert!(eng.estimate().is_some(), "estimate retained while open");
        assert_eq!(eng.breaker().unwrap().state(), BreakerState::HalfOpen);
        // Half-open probe: clean batch through the estimate closes it.
        let (r4, w4, c4) = run(&mut eng, 1.0);
        assert!(!r4.degraded);
        assert_ne!(w4, c4, "probe ran through the estimate");
        assert_eq!(eng.breaker().unwrap().state(), BreakerState::Closed);
        assert!(!eng.breaker_open());
    }

    #[test]
    fn failed_calibration_serves_jacobian_free_and_strikes_breaker() {
        // A model emitting NaN under the probe must not install a garbage
        // estimate: the engine keeps serving the Jacobian-free direction
        // and the breaker takes the strike.
        let d = 8;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 1,
                breaker: Some(BreakerConfig {
                    threshold: 1,
                    cooldown: 2,
                }),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        let (_, rn) = eng.calibrate(
            |_z: &[f64], out: &mut [f64]| out.iter_mut().for_each(|x| *x = f64::NAN),
            &vec![0.0; d],
        );
        assert!(!rn.is_finite());
        assert!(eng.estimate().is_none(), "garbage estimate must not install");
        assert_eq!(eng.calibrations(), 1);
        assert!(eng.breaker_open(), "threshold-1 breaker opens on the failure");
        // A healthy recalibration later installs and (via the cooldown →
        // half-open → close cycle) recovers.
        let bias = vec![0.1; d];
        let (_, rn2) = eng.calibrate(
            |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
            &vec![0.0; d],
        );
        assert!(rn2.is_finite());
        assert!(eng.estimate().is_some());
    }

    #[test]
    fn streaming_retires_nonfinite_columns_early() {
        // A request whose residual goes NaN mid-stream retires immediately
        // as a final unconverged outcome (no budget burn, no eviction) and
        // neighbours are untouched.
        let d = 10;
        let mut rng = Rng::new(11);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 2,
                ..Default::default()
            }
            .with_tol(1e-10),
        );
        let n_req = 3usize;
        let bad_id = 1usize;
        let z0s: Vec<Vec<f64>> = (0..n_req).map(|_| rng.normal_vec(d)).collect();
        let mut next = 0usize;
        let mut outcomes: Vec<Option<ColStats>> = vec![None; n_req];
        let rep = eng.process_streaming(
            |block, ids, out| {
                test_g(&bias, block, d, out);
                for (p, &id) in ids.iter().enumerate() {
                    if id == bad_id {
                        out[p * d..(p + 1) * d].iter_mut().for_each(|x| *x = f64::NAN);
                    }
                }
            },
            || 2,
            |z, c| {
                if next >= n_req {
                    return None;
                }
                z.copy_from_slice(&z0s[next]);
                c.iter_mut().for_each(|x| *x = 0.0);
                let a = Admission {
                    id: next,
                    budget: 200,
                };
                next += 1;
                Some(a)
            },
            |id, _z, _w, st, evicted| {
                assert!(!evicted, "broken columns must retire, not evict");
                outcomes[id] = Some(st);
            },
        );
        assert_eq!(rep.served, n_req);
        assert!(rep.nonfinite_cols >= 1);
        assert!(!rep.all_converged);
        let bad = outcomes[bad_id].expect("poisoned request still resolves");
        assert!(!bad.converged);
        assert!(!bad.residual.is_finite());
        assert!(bad.iters < 5, "no budget burn on NaN: {} iters", bad.iters);
        for (id, o) in outcomes.iter().enumerate() {
            if id != bad_id {
                assert!(o.expect("healthy request resolves").converged);
            }
        }
    }
}
