//! The batch-serving engine: batched fixed-point forward + one-sweep SHINE
//! backward over a shared calibration estimate (module-level contract in
//! [`crate::serve`]).

use crate::linalg::vecops::{nrm2, Elem};
use crate::qn::workspace::Workspace;
use crate::qn::{InvOp, LowRank};
use crate::solvers::fixed_point::{
    broyden_solve_ws, picard_solve_batch, AndersonBatch, ColStats, FpOptions,
};
use crate::util::timer::Stopwatch;

/// Forward solver the engine runs on the batched state block.
#[derive(Clone, Copy, Debug)]
pub enum ForwardSolver {
    /// Damped Picard iteration z ← z − τ g(z): the cheapest batchable
    /// forward; the whole active block updates with one fused axpy.
    Picard { tau: f64 },
    /// Anderson(m) acceleration with mixing parameter β; per-column state
    /// persists inside the engine across batches.
    Anderson { m: usize, beta: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Widest batch `process` accepts (Anderson state is sized for it).
    pub max_batch: usize,
    /// Per-column residual tolerance of the forward solve.
    pub tol: f64,
    /// Per-column forward iteration budget.
    pub max_iters: usize,
    pub solver: ForwardSolver,
    /// Broyden memory of the calibration probe whose inverse estimate the
    /// batch backward reuses (paper default 30).
    pub calib_memory: usize,
    /// Iteration budget of the calibration probe solve.
    pub calib_max_iters: usize,
    /// SHINE fallback guard per column (paper §3): a cotangent whose panel
    /// answer grows beyond `ratio · ‖dz‖` reverts to the Jacobian-free
    /// direction. `None` disables the guard.
    pub fallback_ratio: Option<f64>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 32,
            tol: 1e-6,
            max_iters: 200,
            solver: ForwardSolver::Picard { tau: 1.0 },
            calib_memory: 30,
            calib_max_iters: 60,
            fallback_ratio: None,
        }
    }
}

/// Telemetry for one served batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Columns in this batch.
    pub batch: usize,
    /// Forward iterations of the slowest column (= solver sweeps run).
    pub fwd_iters_max: usize,
    /// Sum of per-column forward iterations (what a sequential server would
    /// have paid in residual evaluations).
    pub fwd_col_iters_total: usize,
    pub all_converged: bool,
    /// Columns reverted to the Jacobian-free direction by the guard.
    pub fallback_cols: usize,
    pub fwd_seconds: f64,
    pub bwd_seconds: f64,
}

/// Serves batches of DEQ requests against one residual map: batched forward
/// solve on a contiguous state block, then a single multi-RHS panel sweep
/// answering every SHINE cotangent. Holds the shared calibration estimate,
/// the workspace and (for Anderson) the per-column solver states — nothing
/// is allocated per batch once warm.
pub struct ServeEngine<E: Elem> {
    d: usize,
    cfg: EngineConfig,
    /// Shared SHINE estimate `H ≈ J_g⁻¹` from the calibration probe; `None`
    /// serves the Jacobian-free direction (w = dz).
    h: Option<LowRank<E>>,
    ws: Workspace<E>,
    anderson: Option<AndersonBatch<E>>,
}

impl<E: Elem> ServeEngine<E> {
    pub fn new(d: usize, cfg: EngineConfig) -> ServeEngine<E> {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        let mut ws = Workspace::new();
        let anderson = match cfg.solver {
            ForwardSolver::Anderson { m, beta } => {
                Some(AndersonBatch::new(d, m, beta, cfg.max_batch, &mut ws))
            }
            ForwardSolver::Picard { .. } => None,
        };
        ServeEngine {
            d,
            cfg,
            h: None,
            ws,
            anderson,
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The shared inverse estimate (None until [`ServeEngine::calibrate`]).
    pub fn estimate(&self) -> Option<&LowRank<E>> {
        self.h.as_ref()
    }

    /// Capture the shared SHINE estimate: one Broyden probe solve of the
    /// single-request residual `g1` from `z0`, whose forward qN estimate
    /// (`H ≈ J_g⁻¹`, exactly what SHINE shares with the backward pass)
    /// becomes the operator every batch backward applies. Returns the
    /// probe's (iterations, final residual). Re-calibrate whenever the
    /// served model's parameters move.
    pub fn calibrate(&mut self, g1: impl FnMut(&[E], &mut [E]), z0: &[E]) -> (usize, f64) {
        debug_assert_eq!(z0.len(), self.d);
        let opts = FpOptions {
            tol: self.cfg.tol,
            max_iters: self.cfg.calib_max_iters,
            memory: self.cfg.calib_memory,
            ..Default::default()
        };
        let res = broyden_solve_ws(g1, z0, &opts, &mut self.ws);
        let out = (res.iters, res.g_norm);
        self.h = Some(res.qn.into_low_rank());
        out
    }

    /// Serve one batch.
    ///
    /// * `g` — batched residual: `g(block, ids, out)` evaluates
    ///   `ids.len()` active columns in one call (`ids[p]` = caller-side
    ///   column at physical position `p`, for per-request context lookup).
    /// * `zs` — d × B column-major initial iterates, overwritten with the
    ///   fixed points (submission order).
    /// * `cotangents` / `w_out` — d × B blocks: per-request backward seeds
    ///   `dz` and their SHINE directions `w ≈ J_g⁻ᵀ dz`, answered by ONE
    ///   `apply_t_multi` panel sweep for the whole batch (no per-request
    ///   panel applies).
    /// * `stats` — per-column forward outcomes (length ≥ B).
    ///
    /// Allocation-free once the engine is warm (see the module contract).
    pub fn process(
        &mut self,
        g: impl FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        cotangents: &[E],
        w_out: &mut [E],
        stats: &mut [ColStats],
    ) -> BatchReport {
        let d = self.d;
        assert_eq!(zs.len() % d, 0, "state block must be a whole number of columns");
        let b = zs.len() / d;
        assert!(b <= self.cfg.max_batch, "batch {b} exceeds max_batch {}", self.cfg.max_batch);
        assert_eq!(cotangents.len(), b * d);
        assert_eq!(w_out.len(), b * d);
        assert!(stats.len() >= b);
        let sw = Stopwatch::start();
        match self.cfg.solver {
            ForwardSolver::Picard { tau } => {
                picard_solve_batch(
                    g,
                    zs,
                    d,
                    tau,
                    self.cfg.tol,
                    self.cfg.max_iters,
                    &mut self.ws,
                    stats,
                );
            }
            ForwardSolver::Anderson { .. } => {
                let anderson = self.anderson.as_mut().expect("Anderson state for Anderson solver");
                anderson.solve(g, zs, self.cfg.tol, self.cfg.max_iters, &mut self.ws, stats);
            }
        }
        let fwd_seconds = sw.elapsed();

        let sw = Stopwatch::start();
        // Backward: the whole batch of cotangents through ONE multi-RHS
        // panel sweep against the shared forward estimate — this is the
        // SHINE serving contract (uncalibrated engines answer with the
        // Jacobian-free identity direction).
        match &self.h {
            Some(h) => h.apply_t_multi_into(cotangents, w_out, &mut self.ws),
            None => w_out.copy_from_slice(cotangents),
        }
        let mut fallback_cols = 0usize;
        if let Some(ratio) = self.cfg.fallback_ratio {
            if self.h.is_some() {
                for j in 0..b {
                    let dzn = nrm2(&cotangents[j * d..(j + 1) * d]);
                    let wn = nrm2(&w_out[j * d..(j + 1) * d]);
                    if wn > ratio * dzn {
                        w_out[j * d..(j + 1) * d]
                            .copy_from_slice(&cotangents[j * d..(j + 1) * d]);
                        fallback_cols += 1;
                    }
                }
            }
        }
        let bwd_seconds = sw.elapsed();

        let mut fwd_iters_max = 0usize;
        let mut fwd_col_iters_total = 0usize;
        let mut all_converged = true;
        for s in stats.iter().take(b) {
            fwd_iters_max = fwd_iters_max.max(s.iters);
            fwd_col_iters_total += s.iters;
            all_converged &= s.converged;
        }
        BatchReport {
            batch: b,
            fwd_iters_max,
            fwd_col_iters_total,
            all_converged,
            fallback_cols,
            fwd_seconds,
            bwd_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::fixed_point::picard_solve;
    use crate::util::rng::Rng;

    /// Positional contractive residual shared by every column:
    /// g(z)[i] = z[i] − 0.3·z[(i+1) mod d] − bias[i mod d].
    fn test_g(bias: &[f64], block: &[f64], d: usize, out: &mut [f64]) {
        let k = block.len() / d;
        for p in 0..k {
            for i in 0..d {
                let zn = block[p * d + (i + 1) % d];
                out[p * d + i] = block[p * d + i] - 0.3 * zn - bias[i];
            }
        }
    }

    #[test]
    fn uncalibrated_engine_serves_jacobian_free() {
        let d = 16;
        let b = 3;
        let mut rng = Rng::new(1);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                tol: 1e-10,
                ..Default::default()
            },
        );
        let mut zs = vec![0.0; b * d];
        let cots: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(rep.all_converged);
        assert_eq!(w, cots); // identity backward without calibration
        // Forward parity with the sequential solver, column by column.
        for j in 0..b {
            let (z, _, it) = picard_solve(
                |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
                &vec![0.0; d],
                1.0,
                1e-10,
                200,
            );
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..]);
            assert_eq!(stats[j].iters, it);
        }
    }

    #[test]
    fn calibrated_backward_is_one_shared_sweep() {
        use crate::qn::InvOp;
        let d = 20;
        let b = 4;
        let mut rng = Rng::new(2);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                tol: 1e-11,
                calib_memory: 10,
                ..Default::default()
            },
        );
        let (it, rn) = eng.calibrate(
            |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
            &vec![0.0; d],
        );
        assert!(rn <= 1e-11, "probe residual {rn} after {it} iters");
        let mut zs = vec![0.0; b * d];
        let cots: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        // The one-sweep multi answer must equal per-column H^T applies.
        let h = eng.estimate().unwrap();
        for j in 0..b {
            let want = h.apply_t_vec(&cots[j * d..(j + 1) * d]);
            assert_eq!(&w[j * d..(j + 1) * d], &want[..], "col {j}");
        }
    }

    #[test]
    fn anderson_engine_converges_and_reuses_state() {
        let d = 14;
        let b = 3;
        let mut rng = Rng::new(3);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                tol: 1e-10,
                solver: ForwardSolver::Anderson { m: 4, beta: 1.0 },
                ..Default::default()
            },
        );
        let cots = vec![0.0; b * d];
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let mut zs1 = vec![0.0; b * d];
        let r1 = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs1,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(r1.all_converged);
        // Second batch through the SAME engine (persistent Anderson state)
        // must reproduce the first bit-for-bit.
        let mut zs2 = vec![0.0; b * d];
        let r2 = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs2,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(zs1, zs2);
        assert_eq!(r1.fwd_iters_max, r2.fwd_iters_max);
    }

    #[test]
    fn fallback_guard_reverts_blown_up_columns() {
        let d = 8;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 2,
                tol: 1e-9,
                fallback_ratio: Some(1.5),
                ..Default::default()
            },
        );
        // Hand the engine a pathological estimate: H = I + 10·e0 e0^T blows
        // up any cotangent with mass on coordinate 0.
        let mut h = LowRank::identity(d, 2, crate::qn::MemoryPolicy::Evict);
        let mut e0 = vec![0.0; d];
        e0[0] = 1.0;
        let u: Vec<f64> = e0.iter().map(|x| 10.0 * x).collect();
        h.push(&u, &e0);
        eng.h = Some(h);
        let mut zs = vec![0.0; 2 * d];
        let mut cots = vec![0.0; 2 * d];
        cots[0] = 1.0; // col 0: all mass on coordinate 0 → 11x growth
        cots[d + 1] = 1.0; // col 1: orthogonal to the factor → untouched
        let mut w = vec![0.0; 2 * d];
        let mut stats = vec![ColStats::default(); 2];
        let bias = vec![0.1; d];
        let rep = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(rep.fallback_cols, 1);
        assert_eq!(&w[..d], &cots[..d]); // reverted to Jacobian-free
        assert_eq!(w[d + 1], 1.0); // untouched column passes through
    }
}
