//! The batch-serving engine: batched fixed-point forward + one-sweep SHINE
//! backward over a shared calibration estimate (module-level contract in
//! [`crate::serve`]).
//!
//! Since the session-API redesign the engine is a consumer of
//! [`crate::solvers::session`]: [`EngineConfig`] carries two
//! [`SolverSpec`]s (the batched forward solver and the Broyden calibration
//! probe — the **single source of truth** for tolerances and iteration
//! budgets; nothing is restated here), the engine drives a built
//! [`FixedPointSolver`] trait object over the state block, and the shared
//! estimate is the [`EstimateHandle`] captured by the probe's
//! `SolveOutcome` — the serving-side instance of the SHINE hand-off.
//!
//! The engine also tracks **estimate staleness**: the cumulative §3
//! fallback-guard trip rate since the last calibration. A drifting model
//! makes the shared estimate blow up more cotangents; when the trip rate
//! crosses [`RecalibPolicy::trip_rate`] the estimate is flagged stale
//! ([`BatchReport::estimate_stale`], [`ServeEngine::estimate_stale`]) and
//! the owner — [`crate::serve::Router`] in the multi-model tier — evicts
//! and re-calibrates it.

use crate::linalg::vecops::{nrm2, Elem};
use crate::qn::InvOp;
use crate::solvers::fixed_point::ColStats;
use crate::solvers::session::{EstimateHandle, FixedPointSolver, Session, SolverSpec};
use crate::util::timer::Stopwatch;

/// Continuous re-calibration policy: when the fallback-guard trip rate
/// since calibration exceeds `trip_rate` (measured over at least
/// `min_cols` guarded columns, so one unlucky batch cannot evict a fresh
/// estimate), the shared estimate is considered stale.
#[derive(Clone, Copy, Debug)]
pub struct RecalibPolicy {
    /// Stale when trips / guarded columns exceeds this.
    pub trip_rate: f64,
    /// Minimum guarded columns before the rate is meaningful.
    pub min_cols: usize,
}

impl Default for RecalibPolicy {
    fn default() -> Self {
        RecalibPolicy {
            trip_rate: 0.25,
            min_cols: 8,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Widest batch `process` accepts (per-column solver state is sized for
    /// it up front).
    pub max_batch: usize,
    /// The batched forward solver — method, tolerance and iteration budget
    /// in one value (Picard/Anderson batch; a Broyden spec solves columns
    /// sequentially).
    pub solver: SolverSpec,
    /// The calibration probe whose captured inverse estimate the batch
    /// backward reuses (Broyden; paper memory 30).
    pub calib: SolverSpec,
    /// SHINE fallback guard per column (paper §3): a cotangent whose panel
    /// answer grows beyond `ratio · ‖dz‖` reverts to the Jacobian-free
    /// direction. `None` disables the guard.
    pub fallback_ratio: Option<f64>,
    /// Estimate-staleness policy driven by the guard trip rate. `None`
    /// never flags the estimate stale.
    pub recalib: Option<RecalibPolicy>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 32,
            solver: SolverSpec::picard(1.0).with_tol(1e-6).with_max_iters(200),
            calib: SolverSpec::broyden(30).with_tol(1e-6).with_max_iters(60),
            fallback_ratio: None,
            recalib: None,
        }
    }
}

impl EngineConfig {
    /// Set one tolerance on both the forward solver and the calibration
    /// probe (the common case; callers needing different tolerances set the
    /// specs directly).
    pub fn with_tol(mut self, tol: f64) -> EngineConfig {
        self.solver = self.solver.with_tol(tol);
        self.calib = self.calib.with_tol(tol);
        self
    }
}

/// Telemetry for one served batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchReport {
    /// Columns in this batch.
    pub batch: usize,
    /// Forward iterations of the slowest column (= solver sweeps run).
    pub fwd_iters_max: usize,
    /// Sum of per-column forward iterations (what a sequential server would
    /// have paid in residual evaluations).
    pub fwd_col_iters_total: usize,
    pub all_converged: bool,
    /// Columns reverted to the Jacobian-free direction by the guard.
    pub fallback_cols: usize,
    /// This batch's guard trip rate (`fallback_cols / batch`).
    pub fallback_rate: f64,
    /// Whether the shared estimate crossed the staleness threshold
    /// ([`RecalibPolicy`]) as of this batch — the owner should evict and
    /// re-calibrate.
    pub estimate_stale: bool,
    pub fwd_seconds: f64,
    pub bwd_seconds: f64,
}

/// Serves batches of DEQ requests against one residual map: batched forward
/// solve on a contiguous state block, then a single multi-RHS panel sweep
/// answering every SHINE cotangent. Holds the built forward solver (whose
/// per-column state persists across batches), the solve session and the
/// shared calibration estimate — nothing is allocated per batch once warm.
pub struct ServeEngine<E: Elem> {
    d: usize,
    cfg: EngineConfig,
    /// Shared SHINE estimate from the calibration probe; `None` serves the
    /// Jacobian-free direction (w = dz).
    h: Option<EstimateHandle<E>>,
    sess: Session<E>,
    solver: Box<dyn FixedPointSolver<E>>,
    /// Guarded columns / guard trips since the last calibration (the
    /// staleness counters the re-calibration policy reads).
    guard_cols: usize,
    guard_trips: usize,
    /// Calibrations performed over this engine's lifetime.
    calibrations: usize,
}

impl<E: Elem> ServeEngine<E> {
    pub fn new(d: usize, cfg: EngineConfig) -> ServeEngine<E> {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        // Fail at construction, not mid-service: only a quasi-Newton probe
        // captures the inverse estimate `calibrate` stores.
        assert!(
            matches!(cfg.calib.method, crate::solvers::session::SolverMethod::Broyden { .. }),
            "calibration spec must be a Broyden method (it must capture an inverse estimate)"
        );
        let mut sess = Session::new();
        let mut solver = cfg.solver.build::<E>();
        solver.prepare_batch(d, cfg.max_batch, &mut sess);
        ServeEngine {
            d,
            cfg,
            h: None,
            sess,
            solver,
            guard_cols: 0,
            guard_trips: 0,
            calibrations: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The shared inverse estimate (None until [`ServeEngine::calibrate`]).
    pub fn estimate(&self) -> Option<&EstimateHandle<E>> {
        self.h.as_ref()
    }

    /// Fallback-guard trip rate since the last calibration.
    pub fn trip_rate(&self) -> f64 {
        self.guard_trips as f64 / self.guard_cols.max(1) as f64
    }

    /// Whether the configured [`RecalibPolicy`] currently flags the shared
    /// estimate stale.
    pub fn estimate_stale(&self) -> bool {
        match self.cfg.recalib {
            Some(p) => {
                self.h.is_some()
                    && self.guard_cols >= p.min_cols
                    && self.trip_rate() > p.trip_rate
            }
            None => false,
        }
    }

    /// Drop the shared estimate (serving falls back to the Jacobian-free
    /// direction until the next [`ServeEngine::calibrate`]) and reset the
    /// staleness counters.
    pub fn invalidate_estimate(&mut self) {
        self.h = None;
        self.guard_cols = 0;
        self.guard_trips = 0;
    }

    /// Calibrations performed over this engine's lifetime.
    pub fn calibrations(&self) -> usize {
        self.calibrations
    }

    /// Install an externally captured estimate (the router's per-key cache
    /// hand-off; tests use it to inject adversarial estimates). Resets the
    /// staleness counters — a fresh estimate starts with a clean record.
    pub fn install_estimate(&mut self, h: EstimateHandle<E>) {
        self.h = Some(h);
        self.guard_cols = 0;
        self.guard_trips = 0;
    }

    /// Capture the shared SHINE estimate: one Broyden probe solve
    /// (`cfg.calib`) of the single-request residual `g1` from `z0`, whose
    /// captured [`EstimateHandle`] (`H ≈ J_g⁻¹`, exactly what SHINE shares
    /// with the backward pass) becomes the operator every batch backward
    /// applies. Returns the probe's (iterations, final residual).
    /// Re-calibrate whenever the served model's parameters move — or let
    /// the [`RecalibPolicy`] trip-rate tracking tell you when.
    pub fn calibrate(&mut self, g1: impl FnMut(&[E], &mut [E]), z0: &[E]) -> (usize, f64) {
        debug_assert_eq!(z0.len(), self.d);
        let mut probe = self.cfg.calib.build::<E>();
        let mut g1 = g1;
        let out = probe.solve(&mut self.sess, &mut g1, z0);
        let stats = (out.iters, out.residual);
        self.h = Some(
            out.estimate
                .expect("calibration probe must capture an inverse estimate"),
        );
        self.guard_cols = 0;
        self.guard_trips = 0;
        self.calibrations += 1;
        stats
    }

    /// Serve one batch.
    ///
    /// * `g` — batched residual: `g(block, ids, out)` evaluates
    ///   `ids.len()` active columns in one call (`ids[p]` = caller-side
    ///   column at physical position `p`, for per-request context lookup).
    /// * `zs` — d × B column-major initial iterates, overwritten with the
    ///   fixed points (submission order).
    /// * `cotangents` / `w_out` — d × B blocks: per-request backward seeds
    ///   `dz` and their SHINE directions `w ≈ J_g⁻ᵀ dz`, answered by ONE
    ///   `apply_t_multi` panel sweep for the whole batch (no per-request
    ///   panel applies).
    /// * `stats` — per-column forward outcomes (length ≥ B).
    ///
    /// Allocation-free once the engine is warm (see the module contract).
    pub fn process(
        &mut self,
        mut g: impl FnMut(&[E], &[usize], &mut [E]),
        zs: &mut [E],
        cotangents: &[E],
        w_out: &mut [E],
        stats: &mut [ColStats],
    ) -> BatchReport {
        let d = self.d;
        assert_eq!(zs.len() % d, 0, "state block must be a whole number of columns");
        let b = zs.len() / d;
        assert!(b <= self.cfg.max_batch, "batch {b} exceeds max_batch {}", self.cfg.max_batch);
        assert_eq!(cotangents.len(), b * d);
        assert_eq!(w_out.len(), b * d);
        assert!(stats.len() >= b);
        let sw = Stopwatch::start();
        let solver = &mut self.solver;
        let sess = &mut self.sess;
        solver.solve_batch(sess, &mut g, zs, d, stats);
        let fwd_seconds = sw.elapsed();

        let sw = Stopwatch::start();
        // Backward: the whole batch of cotangents through ONE multi-RHS
        // panel sweep against the shared forward estimate — this is the
        // SHINE serving contract (uncalibrated engines answer with the
        // Jacobian-free identity direction).
        match &self.h {
            Some(h) => h.apply_t_multi_into(cotangents, w_out, sess.workspace()),
            None => w_out.copy_from_slice(cotangents),
        }
        let mut fallback_cols = 0usize;
        if let Some(ratio) = self.cfg.fallback_ratio {
            if self.h.is_some() {
                for j in 0..b {
                    let dzn = nrm2(&cotangents[j * d..(j + 1) * d]);
                    let wn = nrm2(&w_out[j * d..(j + 1) * d]);
                    if wn > ratio * dzn {
                        w_out[j * d..(j + 1) * d]
                            .copy_from_slice(&cotangents[j * d..(j + 1) * d]);
                        fallback_cols += 1;
                    }
                }
                // Staleness tracking: every guarded column counts toward the
                // cumulative trip rate of this calibration.
                self.guard_cols += b;
                self.guard_trips += fallback_cols;
            }
        }
        let bwd_seconds = sw.elapsed();

        let mut fwd_iters_max = 0usize;
        let mut fwd_col_iters_total = 0usize;
        let mut all_converged = true;
        for s in stats.iter().take(b) {
            fwd_iters_max = fwd_iters_max.max(s.iters);
            fwd_col_iters_total += s.iters;
            all_converged &= s.converged;
        }
        BatchReport {
            batch: b,
            fwd_iters_max,
            fwd_col_iters_total,
            all_converged,
            fallback_cols,
            fallback_rate: fallback_cols as f64 / b.max(1) as f64,
            estimate_stale: self.estimate_stale(),
            fwd_seconds,
            bwd_seconds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qn::{LowRank, MemoryPolicy};
    use crate::solvers::fixed_point::picard_solve;
    use crate::util::rng::Rng;

    /// Positional contractive residual shared by every column:
    /// g(z)[i] = z[i] − 0.3·z[(i+1) mod d] − bias[i mod d].
    fn test_g(bias: &[f64], block: &[f64], d: usize, out: &mut [f64]) {
        let k = block.len() / d;
        for p in 0..k {
            for i in 0..d {
                let zn = block[p * d + (i + 1) % d];
                out[p * d + i] = block[p * d + i] - 0.3 * zn - bias[i];
            }
        }
    }

    #[test]
    fn uncalibrated_engine_serves_jacobian_free() {
        let d = 16;
        let b = 3;
        let mut rng = Rng::new(1);
        let bias = rng.normal_vec(d);
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                ..Default::default()
            }
            .with_tol(1e-10),
        );
        let mut zs = vec![0.0; b * d];
        let cots: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(rep.all_converged);
        assert_eq!(w, cots); // identity backward without calibration
        // Forward parity with the sequential solver, column by column.
        for j in 0..b {
            let (z, _, it) = picard_solve(
                |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
                &vec![0.0; d],
                1.0,
                1e-10,
                200,
            );
            assert_eq!(&zs[j * d..(j + 1) * d], &z[..]);
            assert_eq!(stats[j].iters, it);
        }
    }

    #[test]
    fn calibrated_backward_is_one_shared_sweep() {
        use crate::qn::InvOp;
        let d = 20;
        let b = 4;
        let mut rng = Rng::new(2);
        let bias = rng.normal_vec(d);
        let mut cfg = EngineConfig {
            max_batch: b,
            ..Default::default()
        }
        .with_tol(1e-11);
        cfg.calib = SolverSpec::broyden(10).with_tol(1e-11).with_max_iters(60);
        let mut eng: ServeEngine<f64> = ServeEngine::new(d, cfg);
        let (it, rn) = eng.calibrate(
            |z: &[f64], out: &mut [f64]| test_g(&bias, z, d, out),
            &vec![0.0; d],
        );
        assert!(rn <= 1e-11, "probe residual {rn} after {it} iters");
        assert_eq!(eng.calibrations(), 1);
        let mut zs = vec![0.0; b * d];
        let cots: Vec<f64> = (0..b * d).map(|_| rng.normal()).collect();
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        // The one-sweep multi answer must equal per-column H^T applies.
        let h = eng.estimate().unwrap();
        for j in 0..b {
            let want = h.low_rank().apply_t_vec(&cots[j * d..(j + 1) * d]);
            assert_eq!(&w[j * d..(j + 1) * d], &want[..], "col {j}");
        }
    }

    #[test]
    fn anderson_engine_converges_and_reuses_state() {
        let d = 14;
        let b = 3;
        let mut rng = Rng::new(3);
        let bias = rng.normal_vec(d);
        let mut cfg = EngineConfig {
            max_batch: b,
            ..Default::default()
        }
        .with_tol(1e-10);
        cfg.solver = SolverSpec::anderson(4, 1.0).with_tol(1e-10).with_max_iters(200);
        let mut eng: ServeEngine<f64> = ServeEngine::new(d, cfg);
        let cots = vec![0.0; b * d];
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let mut zs1 = vec![0.0; b * d];
        let r1 = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs1,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(r1.all_converged);
        // Second batch through the SAME engine (persistent Anderson state)
        // must reproduce the first bit-for-bit.
        let mut zs2 = vec![0.0; b * d];
        let r2 = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs2,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(zs1, zs2);
        assert_eq!(r1.fwd_iters_max, r2.fwd_iters_max);
    }

    /// An adversarial estimate: H = I + 10·e0 e0ᵀ blows up any cotangent
    /// with mass on coordinate 0.
    fn blown_estimate(d: usize) -> EstimateHandle<f64> {
        let mut h = LowRank::identity(d, 2, MemoryPolicy::Evict);
        let mut e0 = vec![0.0; d];
        e0[0] = 1.0;
        let u: Vec<f64> = e0.iter().map(|x| 10.0 * x).collect();
        h.push(&u, &e0);
        EstimateHandle::new(h)
    }

    #[test]
    fn fallback_guard_reverts_blown_up_columns() {
        let d = 8;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 2,
                fallback_ratio: Some(1.5),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        eng.install_estimate(blown_estimate(d));
        let mut zs = vec![0.0; 2 * d];
        let mut cots = vec![0.0; 2 * d];
        cots[0] = 1.0; // col 0: all mass on coordinate 0 → 11x growth
        cots[d + 1] = 1.0; // col 1: orthogonal to the factor → untouched
        let mut w = vec![0.0; 2 * d];
        let mut stats = vec![ColStats::default(); 2];
        let bias = vec![0.1; d];
        let rep = eng.process(
            |block, _ids, out| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(rep.fallback_cols, 1);
        assert!((rep.fallback_rate - 0.5).abs() < 1e-12);
        assert_eq!(&w[..d], &cots[..d]); // reverted to Jacobian-free
        assert_eq!(w[d + 1], 1.0); // untouched column passes through
    }

    #[test]
    fn trip_rate_staleness_flags_and_resets() {
        // Every cotangent has mass on coordinate 0, so the blown estimate
        // trips the guard on every column: after enough guarded columns the
        // policy must flag the estimate stale, and invalidation must reset
        // the counters and drop back to Jacobian-free serving.
        let d = 8;
        let b = 4;
        let mut eng: ServeEngine<f64> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: b,
                fallback_ratio: Some(1.5),
                recalib: Some(RecalibPolicy {
                    trip_rate: 0.5,
                    min_cols: 2 * b,
                }),
                ..Default::default()
            }
            .with_tol(1e-9),
        );
        eng.install_estimate(blown_estimate(d));
        let bias = vec![0.1; d];
        let mut cots = vec![0.0; b * d];
        for j in 0..b {
            cots[j * d] = 1.0;
        }
        let mut zs = vec![0.0; b * d];
        let mut w = vec![0.0; b * d];
        let mut stats = vec![ColStats::default(); b];
        let rep1 = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        // First batch trips 100% but has not reached min_cols yet.
        assert_eq!(rep1.fallback_cols, b);
        assert!((rep1.fallback_rate - 1.0).abs() < 1e-12);
        assert!(!rep1.estimate_stale, "min_cols not reached after one batch");
        zs.iter_mut().for_each(|z| *z = 0.0);
        let rep2 = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert!(rep2.estimate_stale, "2·b guarded columns at 100% trip rate");
        assert!(eng.estimate_stale());
        assert!(eng.trip_rate() > 0.99);
        eng.invalidate_estimate();
        assert!(!eng.estimate_stale());
        assert!(eng.estimate().is_none());
        assert_eq!(eng.trip_rate(), 0.0);
        // Uncalibrated serving is Jacobian-free again.
        zs.iter_mut().for_each(|z| *z = 0.0);
        let rep3 = eng.process(
            |block: &[f64], _ids: &[usize], out: &mut [f64]| test_g(&bias, block, d, out),
            &mut zs,
            &cots,
            &mut w,
            &mut stats,
        );
        assert_eq!(rep3.fallback_cols, 0);
        assert_eq!(w, cots);
    }
}
