//! Synthetic closed-loop load driver: N clients, each with one outstanding
//! request, pushed through [`Scheduler`] + [`ServeEngine`] against a
//! [`SynthDeq`] model. Shared by the `serve-bench` CLI subcommand and
//! `benches/serve_throughput.rs` so both report the same numbers.
//!
//! Closed-loop means a client resubmits the moment its previous request
//! completes, so the offered load self-paces to the server's capacity and
//! throughput is a clean function of batch width. The scheduler still runs
//! its real admission policy; the one concession to the closed loop is that
//! a partial batch is released immediately when the queue cannot grow
//! (every non-completed request is already queued — waiting out the
//! deadline would only add dead time to the measurement).
//!
//! The **open-loop** driver ([`run_open_loop`]) is the opposite discipline:
//! requests arrive on a fixed schedule ([`Arrivals`] — Poisson or
//! heavy-tailed Pareto interarrivals) whether or not the server keeps up,
//! which is what exposes queueing-delay tails. It runs the same schedule
//! through either **continuous batching**
//! ([`ServeEngine::process_streaming`]: arrivals admitted into freed
//! columns mid-solve) or **discrete batch formation** (the [`Scheduler`]'s
//! drain → solve cycle), so the two modes' p95/p99 are directly
//! comparable — same seed, same arrival instants, same cotangents.
//!
//! The **sharded** driver ([`run_sharded_open_loop`]) replays one open-loop
//! schedule through the [`ShardedRouter`] front door: the schedule (arrival
//! instants, per-request model choice with an optional hot-key skew, and
//! cotangents) is precomputed from the seed, so runs that differ only in
//! shard count measure the identical offered load — the shard-scaling cells
//! of `BENCH_serve.json`. It can also roll the hot model to a new version
//! mid-run ([`ShardedLoadConfig::swap_at`]) and report how the served
//! traffic partitioned across the cutover.
//!
//! The **loopback-HTTP** driver ([`run_http_open_loop`]) replays the same
//! kind of schedule through the full network edge: it boots a
//! [`Gateway`](crate::http::Gateway) + [`HttpServer`](crate::http::HttpServer)
//! on an ephemeral loopback port and drives it from keep-alive
//! [`HttpClient`](crate::http::HttpClient) threads over **real TCP**, so
//! serialization, framing, admission control and the typed status mapping
//! are all on the measured path. It reconciles three ledgers — client-side
//! statuses, the server's response counters, and the router's typed-outcome
//! stats — which is what the CI smoke gate asserts against.

use crate::http::{Gateway, HttpClient, HttpConfig, HttpServer, JsonBuilder, LazyDoc, SolveBackend};
use crate::linalg::vecops::Elem;
use crate::serve::engine::{Admission, EngineConfig, ServeEngine};
use crate::serve::router::{KeyedScheduler, ModelKey, Router};
use crate::serve::scheduler::{RetryPolicy, Scheduler, SchedulerConfig};
use crate::serve::shard::{
    ServeError, ShardConfig, ShardRequest, ShardedRouter, SharedModel,
};
use crate::serve::synth::{FaultPlan, FaultyModel, SynthDeq};
use crate::solvers::fixed_point::ColStats;
use crate::solvers::session::SolverSpec;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::timer::Stopwatch;
use std::cell::RefCell;
use std::collections::VecDeque;

#[derive(Clone, Copy, Debug)]
pub struct LoadConfig {
    /// Concurrent closed-loop clients (= maximum in-flight requests).
    pub clients: usize,
    /// Total requests to serve before stopping.
    pub total: usize,
    /// Scheduler batch cap (usually = clients; 1 gives the sequential
    /// baseline).
    pub max_batch: usize,
    /// Scheduler partial-batch deadline in seconds.
    pub max_wait: f64,
}

/// What one closed-loop run measured.
#[derive(Clone, Debug, Default)]
pub struct ThroughputReport {
    pub requests: usize,
    pub seconds: f64,
    /// Requests per second of wall time.
    pub rps: f64,
    pub batches: usize,
    /// Mean served batch width.
    pub mean_batch: f64,
    /// Median end-to-end request latency (queue wait + batch service), ms.
    pub p50_latency_ms: f64,
    /// p95 end-to-end request latency, ms.
    pub p95_latency_ms: f64,
    /// Mean forward iterations per request.
    pub fwd_iters_mean: f64,
    pub all_converged: bool,
}

/// Drive `lc.total` requests from `lc.clients` closed-loop clients through
/// scheduler + engine. Requests start from z₀ = 0 with a fixed random
/// cotangent per client; all heavy blocks are preallocated, so the loop
/// measures the serving path, not the harness.
pub fn run_closed_loop<E: Elem, EU: Elem, EV: Elem>(
    engine: &mut ServeEngine<E, EU, EV>,
    model: &SynthDeq<E>,
    lc: &LoadConfig,
    seed: u64,
) -> ThroughputReport {
    let d = engine.dim();
    assert_eq!(model.dim(), d);
    assert!(lc.clients >= 1 && lc.max_batch >= 1);
    assert!(lc.max_batch <= engine.config().max_batch);
    let mut rng = Rng::new(seed ^ 0x10AD);
    let cots: Vec<E> = (0..lc.clients * d).map(|_| E::from_f64(rng.normal())).collect();
    let mut zs = vec![E::ZERO; lc.max_batch * d];
    let mut cot_block = vec![E::ZERO; lc.max_batch * d];
    let mut w_block = vec![E::ZERO; lc.max_batch * d];
    let mut col_stats = vec![ColStats::default(); lc.max_batch];
    let mut sched: Scheduler<usize> = Scheduler::new(SchedulerConfig {
        max_batch: lc.max_batch,
        max_wait: lc.max_wait,
        queue_cap: lc.clients.max(lc.max_batch),
    });
    let mut batch_items: Vec<(f64, usize)> = Vec::with_capacity(lc.max_batch);
    let mut latencies: Vec<f64> = Vec::with_capacity(lc.total);

    let sw = Stopwatch::start();
    let initial = lc.clients.min(lc.total);
    for cid in 0..initial {
        sched
            .push(sw.elapsed(), cid)
            .unwrap_or_else(|_| panic!("queue sized for all clients"));
    }
    let mut submitted = initial;
    let mut completed = 0usize;
    let mut batches = 0usize;
    let mut iters_total = 0usize;
    let mut all_converged = true;
    while completed < lc.total {
        let now = sw.elapsed();
        let mut n = sched.ready(now);
        if n == 0 {
            // Closed loop: nothing new can arrive while we sit here, so
            // release the partial batch instead of sleeping out max_wait.
            n = sched.len().min(lc.max_batch);
        }
        assert!(n > 0, "closed loop drained with work outstanding");
        batch_items.clear();
        sched.drain_into(n, now, &mut batch_items);
        for (p, &(_, cid)) in batch_items.iter().enumerate() {
            for z in zs[p * d..(p + 1) * d].iter_mut() {
                *z = E::ZERO;
            }
            cot_block[p * d..(p + 1) * d].copy_from_slice(&cots[cid * d..(cid + 1) * d]);
        }
        let t0 = sw.elapsed();
        let report = engine.process(
            |block: &[E], _ids: &[usize], out: &mut [E]| {
                model.residual_batch(block, block.len() / d, out)
            },
            &mut zs[..n * d],
            &cot_block[..n * d],
            &mut w_block[..n * d],
            &mut col_stats[..n],
        );
        let t1 = sw.elapsed();
        batches += 1;
        iters_total += report.fwd_col_iters_total;
        all_converged &= report.all_converged;
        let service = t1 - t0;
        for &(wait, cid) in batch_items.iter() {
            latencies.push(wait + service);
            completed += 1;
            if submitted < lc.total {
                // The client's next request enters the queue immediately.
                let _ = sched.push(t1, cid);
                submitted += 1;
            }
        }
    }
    let seconds = sw.elapsed();
    ThroughputReport {
        requests: completed,
        seconds,
        rps: completed as f64 / seconds.max(1e-12),
        batches,
        mean_batch: completed as f64 / (batches.max(1)) as f64,
        p50_latency_ms: stats::median(&latencies) * 1e3,
        p95_latency_ms: stats::quantile(&latencies, 0.95) * 1e3,
        fwd_iters_mean: iters_total as f64 / (completed.max(1)) as f64,
        all_converged,
    }
}

/// One row of the batched-vs-sequential suite.
#[derive(Clone, Debug)]
pub struct SuiteRow {
    pub b: usize,
    pub report: ThroughputReport,
    /// Throughput relative to the suite's first row (conventionally B = 1,
    /// the sequential baseline).
    pub speedup_vs_baseline: f64,
}

/// Run the closed-loop load at each batch width in `batch_sizes` (first
/// entry = sequential baseline) against one shared [`SynthDeq`] model:
/// fresh engine per width, calibrated before timing, with a short warm-up
/// run so pools/caches don't bill the measured pass. `solver` is the
/// forward [`SolverSpec`] (its tolerance also drives the calibration
/// probe) — the CLI `--solver` flag lands here.
///
/// The `EU`/`EV` parameters select the panel-storage precision of every
/// engine in the suite (state stays `E`): `run_suite::<f32, Bf16, f32>`
/// measures the mixed reduced-precision layout under the identical load.
pub fn run_suite<E: Elem, EU: Elem, EV: Elem>(
    d: usize,
    block: usize,
    batch_sizes: &[usize],
    total_per_case: usize,
    solver: SolverSpec,
    seed: u64,
) -> Vec<SuiteRow> {
    let model: SynthDeq<E> = SynthDeq::new(d, block, seed);
    let mut rows: Vec<SuiteRow> = Vec::with_capacity(batch_sizes.len());
    let mut base_rps = 0.0;
    for &bsz in batch_sizes {
        let mut engine: ServeEngine<E, EU, EV> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: bsz,
                solver,
                calib: SolverSpec::broyden(30).with_tol(solver.tol).with_max_iters(60),
                fallback_ratio: None,
                recalib: None,
                col_budget: None,
                breaker: None,
            },
        );
        engine.calibrate(
            |z: &[E], out: &mut [E]| model.residual_batch(z, 1, out),
            &vec![E::ZERO; d],
        );
        let warm = LoadConfig {
            clients: bsz,
            total: 2 * bsz,
            max_batch: bsz,
            max_wait: 1e-3,
        };
        let _ = run_closed_loop(&mut engine, &model, &warm, seed ^ 1);
        let lc = LoadConfig {
            clients: bsz,
            total: total_per_case,
            max_batch: bsz,
            max_wait: 1e-3,
        };
        let report = run_closed_loop(&mut engine, &model, &lc, seed ^ 2);
        if rows.is_empty() {
            base_rps = report.rps;
        }
        let speedup_vs_baseline = report.rps / base_rps.max(1e-12);
        rows.push(SuiteRow {
            b: bsz,
            report,
            speedup_vs_baseline,
        });
    }
    rows
}

/// Interarrival process of the open-loop driver. Both variants offer the
/// same nominal rate; they differ in burstiness.
#[derive(Clone, Copy, Debug)]
pub enum Arrivals {
    /// Memoryless arrivals: exponential gaps with mean `1/rate`.
    Poisson { rate: f64 },
    /// Heavy-tailed arrivals: Lomax gaps
    /// ([`crate::util::rng::Rng::pareto_interarrival`]) with mean `1/rate`
    /// and tail index `alpha` (> 1). Bursts separated by occasional long
    /// gaps — the shape that punishes discrete batch formation.
    Pareto { rate: f64, alpha: f64 },
}

impl Arrivals {
    /// Nominal offered rate (requests per second).
    pub fn rate(&self) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => rate,
            Arrivals::Pareto { rate, .. } => rate,
        }
    }

    fn gap(&self, rng: &mut Rng) -> f64 {
        match *self {
            Arrivals::Poisson { rate } => rng.exponential(rate),
            Arrivals::Pareto { rate, alpha } => rng.pareto_interarrival(1.0 / rate, alpha),
        }
    }
}

/// Config of one open-loop run.
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopConfig {
    /// Total requests in the arrival schedule.
    pub total: usize,
    /// Interarrival process (both modes replay the identical schedule).
    pub arrivals: Arrivals,
    /// Block width cap (continuous) / batch cap (discrete); must not
    /// exceed the engine's `max_batch`.
    pub max_batch: usize,
    /// Discrete mode only: partial-batch deadline in seconds.
    pub max_wait: f64,
    /// `true` → continuous batching ([`ServeEngine::process_streaming`]);
    /// `false` → discrete drain → solve cycles through a [`Scheduler`].
    pub continuous: bool,
}

/// What one open-loop run measured.
#[derive(Clone, Debug, Default)]
pub struct OpenLoopReport {
    /// `"continuous"` or `"discrete"`.
    pub mode: &'static str,
    pub requests: usize,
    pub seconds: f64,
    /// Served requests per second of wall time.
    pub rps: f64,
    /// Nominal offered rate of the arrival schedule.
    pub offered_rps: f64,
    /// End-to-end latency quantiles (arrival → final retirement, across
    /// evict-and-retry residencies), ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Straggler evictions (continuous mode with a `col_budget` only).
    pub evictions: usize,
    /// Mean active block width (continuous) / mean served batch (discrete).
    pub mean_width: f64,
    /// Residual sweeps (continuous) / served batches (discrete).
    pub sweeps: usize,
    pub all_converged: bool,
}

/// Shared mutable state of the continuous-mode closures: the engine calls
/// `admit` and `retire` from inside one `&mut self` loop, so the driver
/// side hands out interior-mutable borrows per call (never held across
/// calls — the engine invokes the closures strictly sequentially).
struct OpenState<E> {
    /// Next unconsumed index into the arrival schedule.
    next: usize,
    /// Arrived-and-waiting request ids (evicted requests re-enter at the
    /// back with their preserved iterate).
    queue: VecDeque<usize>,
    /// Preserved iterates of evicted requests, by id.
    resume: Vec<Option<Vec<E>>>,
    /// Remaining iteration budget per request, by id.
    rem: Vec<usize>,
    latencies: Vec<f64>,
    evictions: usize,
    served: usize,
    all_converged: bool,
}

/// Drive one open-loop arrival schedule through the engine and report
/// latency quantiles. The schedule (arrival instants and per-request
/// cotangents) is precomputed from `seed`, so a continuous and a discrete
/// run with the same config-but-`continuous` and seed measure the same
/// offered load. Requests start from z₀ = 0.
pub fn run_open_loop<E: Elem, EU: Elem, EV: Elem>(
    engine: &mut ServeEngine<E, EU, EV>,
    model: &SynthDeq<E>,
    lc: &OpenLoopConfig,
    seed: u64,
) -> OpenLoopReport {
    let d = engine.dim();
    assert_eq!(model.dim(), d);
    assert!(lc.total >= 1 && lc.max_batch >= 1);
    assert!(lc.max_batch <= engine.config().max_batch);
    let mut rng = Rng::new(seed ^ 0x09E17);
    // Absolute arrival instants (prefix sums of the interarrival gaps; the
    // first request arrives after one gap) and per-request cotangents —
    // identical for both modes at one seed.
    let mut arrivals = Vec::with_capacity(lc.total);
    let mut t = 0.0f64;
    for _ in 0..lc.total {
        t += lc.arrivals.gap(&mut rng);
        arrivals.push(t);
    }
    let cots: Vec<E> = (0..lc.total * d).map(|_| E::from_f64(rng.normal())).collect();
    if lc.continuous {
        run_open_continuous(engine, model, lc, &arrivals, &cots)
    } else {
        run_open_discrete(engine, model, lc, &arrivals, &cots)
    }
}

fn run_open_continuous<E: Elem, EU: Elem, EV: Elem>(
    engine: &mut ServeEngine<E, EU, EV>,
    model: &SynthDeq<E>,
    lc: &OpenLoopConfig,
    arrivals: &[f64],
    cots: &[E],
) -> OpenLoopReport {
    let d = engine.dim();
    let budget0 = engine.config().solver.max_iters;
    let st = RefCell::new(OpenState::<E> {
        next: 0,
        queue: VecDeque::with_capacity(lc.max_batch),
        resume: vec![None; lc.total],
        rem: vec![budget0; lc.total],
        latencies: Vec::with_capacity(lc.total),
        evictions: 0,
        served: 0,
        all_converged: true,
    });
    let width = lc.max_batch;
    let sw = Stopwatch::start();
    let mut sweeps = 0usize;
    let mut occupancy = 0.0f64;
    loop {
        let rep = engine.process_streaming(
            |block: &[E], _ids: &[usize], out: &mut [E]| {
                model.residual_batch(block, block.len() / d, out)
            },
            || width,
            |z: &mut [E], c: &mut [E]| {
                let now = sw.elapsed();
                let mut s = st.borrow_mut();
                while s.next < arrivals.len() && arrivals[s.next] <= now {
                    let id = s.next;
                    s.queue.push_back(id);
                    s.next += 1;
                }
                let id = s.queue.pop_front()?;
                match s.resume[id].take() {
                    Some(zi) => z.copy_from_slice(&zi),
                    None => z.iter_mut().for_each(|x| *x = E::ZERO),
                }
                c.copy_from_slice(&cots[id * d..(id + 1) * d]);
                let budget = s.rem[id];
                Some(Admission { id, budget })
            },
            |id: usize, z: &[E], _w: &[E], cs: ColStats, evicted: bool| {
                let now = sw.elapsed();
                let mut s = st.borrow_mut();
                if evicted {
                    s.evictions += 1;
                    s.rem[id] = s.rem[id].saturating_sub(cs.iters).max(1);
                    s.resume[id] = Some(z.to_vec());
                    s.queue.push_back(id);
                } else {
                    s.latencies.push(now - arrivals[id]);
                    s.all_converged &= cs.converged;
                    s.served += 1;
                }
            },
        );
        sweeps += rep.sweeps;
        occupancy += rep.mean_width * rep.sweeps as f64;
        let (served, next) = {
            let s = st.borrow();
            (s.served, s.next)
        };
        if served >= lc.total {
            break;
        }
        // Block drained with requests still to come: sleep out the gap to
        // the next arrival (the open-loop idle period).
        if next < arrivals.len() {
            let gap = arrivals[next] - sw.elapsed();
            if gap > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(gap));
            }
        }
    }
    let seconds = sw.elapsed();
    let s = st.into_inner();
    OpenLoopReport {
        mode: "continuous",
        requests: s.served,
        seconds,
        rps: s.served as f64 / seconds.max(1e-12),
        offered_rps: lc.arrivals.rate(),
        p50_latency_ms: stats::median(&s.latencies) * 1e3,
        p95_latency_ms: stats::quantile(&s.latencies, 0.95) * 1e3,
        p99_latency_ms: stats::quantile(&s.latencies, 0.99) * 1e3,
        evictions: s.evictions,
        mean_width: occupancy / sweeps.max(1) as f64,
        sweeps,
        all_converged: s.all_converged,
    }
}

fn run_open_discrete<E: Elem, EU: Elem, EV: Elem>(
    engine: &mut ServeEngine<E, EU, EV>,
    model: &SynthDeq<E>,
    lc: &OpenLoopConfig,
    arrivals: &[f64],
    cots: &[E],
) -> OpenLoopReport {
    let d = engine.dim();
    let total = arrivals.len();
    let mut sched: Scheduler<usize> = Scheduler::new(SchedulerConfig {
        max_batch: lc.max_batch,
        max_wait: lc.max_wait,
        queue_cap: total.max(lc.max_batch),
    });
    let mut zs = vec![E::ZERO; lc.max_batch * d];
    let mut cot_block = vec![E::ZERO; lc.max_batch * d];
    let mut w_block = vec![E::ZERO; lc.max_batch * d];
    let mut col_stats = vec![ColStats::default(); lc.max_batch];
    let mut batch_items: Vec<(f64, usize)> = Vec::with_capacity(lc.max_batch);
    let mut latencies: Vec<f64> = Vec::with_capacity(total);
    let mut next = 0usize;
    let mut completed = 0usize;
    let mut batches = 0usize;
    let mut all_converged = true;
    let sw = Stopwatch::start();
    while completed < total {
        let now = sw.elapsed();
        while next < total && arrivals[next] <= now {
            sched
                .push(arrivals[next], next)
                .unwrap_or_else(|_| panic!("queue sized for the whole schedule"));
            next += 1;
        }
        let n = sched.ready(now);
        if n == 0 {
            // Nothing releasable: sleep to whichever comes first, the next
            // arrival or the oldest partial batch's deadline.
            let mut wake = f64::INFINITY;
            if next < total {
                wake = arrivals[next];
            }
            if let Some(dl) = sched.next_deadline() {
                wake = wake.min(dl);
            }
            assert!(wake.is_finite(), "open loop stalled with work outstanding");
            let gap = wake - sw.elapsed();
            if gap > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(gap));
            }
            continue;
        }
        batch_items.clear();
        sched.drain_into(n, now, &mut batch_items);
        for (p, &(_, id)) in batch_items.iter().enumerate() {
            for z in zs[p * d..(p + 1) * d].iter_mut() {
                *z = E::ZERO;
            }
            cot_block[p * d..(p + 1) * d].copy_from_slice(&cots[id * d..(id + 1) * d]);
        }
        let t0 = sw.elapsed();
        let report = engine.process(
            |block: &[E], _ids: &[usize], out: &mut [E]| {
                model.residual_batch(block, block.len() / d, out)
            },
            &mut zs[..n * d],
            &cot_block[..n * d],
            &mut w_block[..n * d],
            &mut col_stats[..n],
        );
        let t1 = sw.elapsed();
        batches += 1;
        all_converged &= report.all_converged;
        let service = t1 - t0;
        for &(wait, _) in batch_items.iter() {
            latencies.push(wait + service);
            completed += 1;
        }
    }
    let seconds = sw.elapsed();
    OpenLoopReport {
        mode: "discrete",
        requests: completed,
        seconds,
        rps: completed as f64 / seconds.max(1e-12),
        offered_rps: lc.arrivals.rate(),
        p50_latency_ms: stats::median(&latencies) * 1e3,
        p95_latency_ms: stats::quantile(&latencies, 0.95) * 1e3,
        p99_latency_ms: stats::quantile(&latencies, 0.99) * 1e3,
        evictions: 0,
        mean_width: completed as f64 / batches.max(1) as f64,
        sweeps: batches,
        all_converged,
    }
}

/// Config of one routed (multi-model) closed-loop run.
#[derive(Clone, Copy, Debug)]
pub struct RoutedLoadConfig {
    /// Closed-loop clients pinned to EACH registered key.
    pub clients_per_model: usize,
    /// Total requests across all keys.
    pub total: usize,
    /// Scheduler batch cap (per key — batches never cross keys).
    pub max_batch: usize,
    /// Scheduler partial-batch deadline in seconds.
    pub max_wait: f64,
}

/// What one routed closed-loop run measured.
#[derive(Clone, Debug, Default)]
pub struct RoutedReport {
    pub requests: usize,
    pub seconds: f64,
    pub rps: f64,
    pub batches: usize,
    /// Requests served per key, in the caller's key order.
    pub per_key_requests: Vec<(ModelKey, usize)>,
    /// Stale-estimate re-calibrations performed across all keys.
    pub recalibrations: usize,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub all_converged: bool,
}

/// Drive a closed-loop multi-model load through ONE [`KeyedScheduler`] and
/// a [`Router`]: `clients_per_model` clients per key, each pinned to its
/// key and resubmitting on completion. Batches are formed per key (never
/// cross-model) and served by that key's engine; the router's trip-rate
/// policy may evict and re-calibrate estimates mid-run. All registered
/// models must share one fixed-point dimension (one set of preallocated
/// blocks serves every key). A `Router<E, EU, EV>` with reduced-precision
/// panel storage drives the identical load through demoted estimates.
pub fn run_routed_closed_loop<E: Elem, EU: Elem, EV: Elem>(
    router: &mut Router<E, EU, EV>,
    keys: &[ModelKey],
    lc: &RoutedLoadConfig,
    seed: u64,
) -> RoutedReport {
    assert!(!keys.is_empty() && lc.clients_per_model >= 1 && lc.max_batch >= 1);
    let d = router
        .engine(keys[0])
        .expect("key registered")
        .dim();
    for &k in keys {
        assert_eq!(
            router.engine(k).expect("key registered").dim(),
            d,
            "routed driver requires one shared fixed-point dimension"
        );
    }
    let clients = keys.len() * lc.clients_per_model;
    let mut rng = Rng::new(seed ^ 0x2007ED);
    let cots: Vec<E> = (0..clients * d).map(|_| E::from_f64(rng.normal())).collect();
    let mut zs = vec![E::ZERO; lc.max_batch * d];
    let mut cot_block = vec![E::ZERO; lc.max_batch * d];
    let mut w_block = vec![E::ZERO; lc.max_batch * d];
    let mut col_stats = vec![ColStats::default(); lc.max_batch];
    let mut sched: KeyedScheduler<usize> = KeyedScheduler::new(SchedulerConfig {
        max_batch: lc.max_batch,
        max_wait: lc.max_wait,
        queue_cap: clients.max(lc.max_batch),
    });
    let client_key = |cid: usize| keys[cid % keys.len()];
    let mut batch_items: Vec<(f64, usize)> = Vec::with_capacity(lc.max_batch);
    let mut latencies: Vec<f64> = Vec::with_capacity(lc.total);
    let mut per_key: Vec<(ModelKey, usize)> = keys.iter().map(|&k| (k, 0)).collect();

    let sw = Stopwatch::start();
    let initial = clients.min(lc.total);
    for cid in 0..initial {
        sched
            .push(sw.elapsed(), client_key(cid), cid)
            .unwrap_or_else(|_| panic!("queue sized for all clients"));
    }
    let mut submitted = initial;
    let mut completed = 0usize;
    let mut batches = 0usize;
    let mut all_converged = true;
    while completed < lc.total {
        let now = sw.elapsed();
        let (key, n) = match sched.ready(now) {
            Some(kn) => kn,
            None => {
                // Closed loop: nothing new can arrive while we sit here, so
                // release the oldest key's partial batch immediately.
                let k = sched.front_key().expect("work outstanding");
                (k, sched.count_key(k).min(lc.max_batch))
            }
        };
        assert!(n > 0, "closed loop drained with work outstanding");
        batch_items.clear();
        sched.drain_key(key, n, now, &mut batch_items);
        for (p, &(_, cid)) in batch_items.iter().enumerate() {
            for z in zs[p * d..(p + 1) * d].iter_mut() {
                *z = E::ZERO;
            }
            cot_block[p * d..(p + 1) * d].copy_from_slice(&cots[cid * d..(cid + 1) * d]);
        }
        let t0 = sw.elapsed();
        let report = router
            .process(
                key,
                &mut zs[..n * d],
                &cot_block[..n * d],
                &mut w_block[..n * d],
                &mut col_stats[..n],
            )
            .expect("registered key");
        let t1 = sw.elapsed();
        batches += 1;
        all_converged &= report.all_converged;
        if let Some(e) = per_key.iter_mut().find(|(k, _)| *k == key) {
            e.1 += report.batch;
        }
        let service = t1 - t0;
        for &(wait, cid) in batch_items.iter() {
            latencies.push(wait + service);
            completed += 1;
            if submitted < lc.total {
                let _ = sched.push(t1, client_key(cid), cid);
                submitted += 1;
            }
        }
    }
    let seconds = sw.elapsed();
    let recalibrations: usize = keys.iter().map(|&k| router.recalibrations(k)).sum();
    RoutedReport {
        requests: completed,
        seconds,
        rps: completed as f64 / seconds.max(1e-12),
        batches,
        per_key_requests: per_key,
        recalibrations,
        p50_latency_ms: stats::median(&latencies) * 1e3,
        p95_latency_ms: stats::quantile(&latencies, 0.95) * 1e3,
        all_converged,
    }
}

/// Config of one sharded open-loop run.
#[derive(Clone, Copy, Debug)]
pub struct ShardedLoadConfig {
    /// Scheduler shards (worker threads) of the [`ShardedRouter`].
    pub shards: usize,
    /// Models registered up front (ids `0..models`, all at version 0).
    pub models: usize,
    /// Total requests in the arrival schedule.
    pub total: usize,
    /// Interarrival process (identical schedule across shard counts).
    pub arrivals: Arrivals,
    /// Per-shard scheduler batch cap; must not exceed the engine's.
    pub max_batch: usize,
    /// Partial-batch deadline in seconds.
    pub max_wait: f64,
    /// Probability a request targets model 0 (the rest spread uniformly
    /// over the others) — the skew knob that exercises work stealing.
    /// `None` spreads uniformly over all models.
    pub hot_share: Option<f64>,
    /// Submission index at which model 0 rolls to version 1 via the
    /// zero-downtime [`ShardedRouter::swap`]. `None` = no swap.
    pub swap_at: Option<usize>,
    /// Relative per-request deadline in seconds (absolute deadline =
    /// submission instant + this). `None` = requests never expire.
    pub deadline: Option<f64>,
}

/// How the served traffic of model 0 partitioned across a mid-run swap.
#[derive(Clone, Copy, Debug, Default)]
pub struct SwapTelemetry {
    /// Submission index at which the roll was requested.
    pub requested_at: usize,
    /// First submission index routed to the new version (`None` if the
    /// background calibration outlasted the schedule).
    pub cutover_at: Option<usize>,
    /// Requests served on the old / new version of the rolled model.
    pub old_served: usize,
    pub new_served: usize,
    /// The new version ended up the live route.
    pub completed: bool,
}

/// What one sharded open-loop run measured.
#[derive(Clone, Debug, Default)]
pub struct ShardedReport {
    pub shards: usize,
    pub requests: usize,
    pub seconds: f64,
    /// Served requests per second of wall time.
    pub rps: f64,
    /// Nominal offered rate of the arrival schedule.
    pub offered_rps: f64,
    /// End-to-end latency quantiles (admission → batch completion), ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Whole-queue steals across all shards.
    pub steals: usize,
    /// Engines built + calibrated across all shards.
    pub calibrations: usize,
    /// Trip-rate re-calibrations across all shards.
    pub recalibrations: usize,
    /// Requests served per shard, index = shard id.
    pub per_shard_served: Vec<usize>,
    /// Present when [`ShardedLoadConfig::swap_at`] was set.
    pub swap: Option<SwapTelemetry>,
    /// Every ok response's forward solve converged (failed responses are
    /// accounted separately below).
    pub all_converged: bool,
    /// Responses carrying a typed [`ServeError`], by kind.
    pub deadline_exceeded: usize,
    pub model_faults: usize,
    pub worker_lost: usize,
    pub unconverged: usize,
    /// `QueueFull` retries performed by the driver's bounded
    /// exponential-backoff policy.
    pub retries: usize,
    /// Requests shed after exhausting the retry budget (plus admissions
    /// bounced for an already-expired deadline).
    pub shed: usize,
    /// Worker respawns across all shards (supervision events).
    pub respawns: usize,
    /// Circuit breakers open across all shards at the end of the run.
    pub open_breakers: usize,
}

/// Replay one precomputed open-loop schedule through a [`ShardedRouter`]
/// built to `lc.shards`. `mk_model(model, version)` constructs the
/// parameter snapshot for a key — called for ids `0..models` at version 0
/// up front, and again for `(0, 1)` if a mid-run swap is configured. All
/// models must share one fixed-point dimension. The submission thread
/// paces itself to the arrival instants; responses are collected after the
/// full schedule is offered, so the router's own drain loops set the pace
/// (open-loop discipline). `EU`/`EV` select the panel-storage precision of
/// every worker-local engine (see [`ShardedRouter`]); requests, responses
/// and models stay in `E`.
pub fn run_sharded_open_loop<E: Elem, EU: Elem, EV: Elem>(
    engine: EngineConfig,
    mk_model: &dyn Fn(u32, u32) -> SharedModel<E>,
    lc: &ShardedLoadConfig,
    seed: u64,
) -> ShardedReport {
    run_sharded_open_loop_with::<E, EU, EV>(engine, mk_model, lc, None, seed)
}

/// [`run_sharded_open_loop`] with an optional chaos schedule: when `faults`
/// is set, every registered model is wrapped in a [`FaultyModel`] executing
/// the shared seeded [`FaultPlan`] (panics, NaN columns, stragglers keyed
/// by request id), and the report carries the typed failure counts. The
/// driver applies the bounded retry-with-exponential-backoff policy on
/// `QueueFull` and counts what it sheds — every request of the schedule is
/// accounted for exactly once, served or not.
pub fn run_sharded_open_loop_with<E: Elem, EU: Elem, EV: Elem>(
    engine: EngineConfig,
    mk_model: &dyn Fn(u32, u32) -> SharedModel<E>,
    lc: &ShardedLoadConfig,
    faults: Option<&FaultPlan>,
    seed: u64,
) -> ShardedReport {
    assert!(lc.shards >= 1 && lc.models >= 1 && lc.total >= 1 && lc.max_batch >= 1);
    if let Some(at) = lc.swap_at {
        assert!(at < lc.total, "swap_at must fall inside the schedule");
    }
    let sched = SchedulerConfig {
        max_batch: lc.max_batch,
        max_wait: lc.max_wait,
        // One shard could own (or steal) the whole schedule: never reject.
        queue_cap: lc.total.max(lc.max_batch),
    };
    let router: ShardedRouter<E, EU, EV> =
        ShardedRouter::new(ShardConfig::new(lc.shards, engine, sched));
    let wrap = |model: SharedModel<E>| -> SharedModel<E> {
        match faults {
            Some(plan) => std::sync::Arc::new(FaultyModel::new(model, plan.clone())),
            None => model,
        }
    };
    let d = mk_model(0, 0).dim();
    for m in 0..lc.models as u32 {
        let model = mk_model(m, 0);
        assert_eq!(
            model.dim(),
            d,
            "sharded driver requires one shared fixed-point dimension"
        );
        router.register(ModelKey::new(m, 0), wrap(model));
    }
    // Precompute the offered load — arrival instants, per-request model
    // choice, cotangents — identical across shard counts at one seed.
    let mut rng = Rng::new(seed ^ 0x54A2D);
    let mut arrivals = Vec::with_capacity(lc.total);
    let mut t = 0.0f64;
    for _ in 0..lc.total {
        t += lc.arrivals.gap(&mut rng);
        arrivals.push(t);
    }
    let model_of: Vec<u32> = (0..lc.total)
        .map(|_| match lc.hot_share {
            Some(p) if lc.models > 1 => {
                if rng.uniform() < p {
                    0
                } else {
                    1 + rng.below(lc.models - 1) as u32
                }
            }
            _ => rng.below(lc.models) as u32,
        })
        .collect();
    let cots: Vec<E> = (0..lc.total * d).map(|_| E::from_f64(rng.normal())).collect();

    let mut routed_key: Vec<Option<ModelKey>> = Vec::with_capacity(lc.total);
    let mut retries = 0usize;
    let mut shed = 0usize;
    let sw = Stopwatch::start();
    for i in 0..lc.total {
        let lead = arrivals[i] - sw.elapsed();
        if lead > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(lead));
        }
        if lc.swap_at == Some(i) {
            // Zero-downtime roll of the hot model: calibrates in the
            // background while version 0 keeps serving — submissions below
            // keep flowing and route to whichever version is live.
            router.swap(ModelKey::new(0, 1), wrap(mk_model(0, 1)));
        }
        let mut req = ShardRequest::new(i, vec![E::ZERO; d], cots[i * d..(i + 1) * d].to_vec());
        req.deadline = lc.deadline.map(|dl| router.now() + dl);
        // Bounded retry with exponential backoff under the shared
        // [`RetryPolicy`] (the same policy the HTTP front door echoes to
        // clients); a request that exhausts the budget (or whose deadline
        // lapses before admission) is shed and counted.
        let (res, attempts) = router.submit_with_retry(model_of[i], req, &RetryPolicy::standard());
        retries += attempts;
        let key = res.ok();
        if key.is_none() {
            shed += 1;
        }
        routed_key.push(key);
    }
    let submitted = routed_key.iter().filter(|k| k.is_some()).count();
    let responses = router.collect(submitted);
    let seconds = sw.elapsed();
    if lc.swap_at.is_some() {
        // Let a calibration that outlasted the schedule finish before the
        // telemetry snapshot (no request is waiting on it).
        router.wait_live(ModelKey::new(0, 1));
    }
    let shard_stats = router.shard_stats();
    let latencies: Vec<f64> = responses
        .iter()
        .filter(|r| r.ok())
        .map(|r| r.completed - r.enqueued)
        .collect();
    let all_converged = responses
        .iter()
        .filter(|r| r.ok())
        .all(|r| r.stats.converged);
    let count_err = |e: ServeError| responses.iter().filter(|r| r.error == Some(e)).count();
    let swap = lc.swap_at.map(|at| {
        let old = ModelKey::new(0, 0);
        let new = ModelKey::new(0, 1);
        SwapTelemetry {
            requested_at: at,
            cutover_at: routed_key.iter().position(|k| *k == Some(new)),
            old_served: responses.iter().filter(|r| r.key == old).count(),
            new_served: responses.iter().filter(|r| r.key == new).count(),
            completed: router.live_version(0) == Some(1),
        }
    });
    let served = responses.iter().filter(|r| r.ok()).count();
    let rep = ShardedReport {
        shards: lc.shards,
        requests: responses.len(),
        seconds,
        rps: served as f64 / seconds.max(1e-12),
        offered_rps: lc.arrivals.rate(),
        p50_latency_ms: stats::median(&latencies) * 1e3,
        p95_latency_ms: stats::quantile(&latencies, 0.95) * 1e3,
        p99_latency_ms: stats::quantile(&latencies, 0.99) * 1e3,
        steals: shard_stats.iter().map(|s| s.steals).sum(),
        calibrations: shard_stats.iter().map(|s| s.calibrations).sum(),
        recalibrations: shard_stats.iter().map(|s| s.recalibrations).sum(),
        per_shard_served: shard_stats.iter().map(|s| s.served).collect(),
        swap,
        all_converged,
        deadline_exceeded: count_err(ServeError::DeadlineExceeded),
        model_faults: count_err(ServeError::ModelFault),
        worker_lost: count_err(ServeError::WorkerLost),
        unconverged: count_err(ServeError::Unconverged),
        retries,
        shed,
        respawns: shard_stats.iter().map(|s| s.respawns).sum(),
        open_breakers: shard_stats.iter().map(|s| s.open_breakers).sum(),
    };
    router.shutdown();
    rep
}

/// Config of one loopback-HTTP open-loop run.
#[derive(Clone, Copy, Debug)]
pub struct HttpLoadConfig {
    /// Scheduler shards (worker threads) of the [`ShardedRouter`].
    pub shards: usize,
    /// Models registered up front (ids `0..models`, all at version 0).
    pub models: usize,
    /// Total requests in the arrival schedule.
    pub total: usize,
    /// Client threads, each holding one keep-alive connection with one
    /// request in flight (requests are striped round-robin, so `clients`
    /// also caps HTTP-side concurrency).
    pub clients: usize,
    /// Interarrival process of the precomputed schedule.
    pub arrivals: Arrivals,
    /// Per-shard scheduler batch cap.
    pub max_batch: usize,
    /// Partial-batch deadline in seconds.
    pub max_wait: f64,
    /// Per-shard queue cap; `None` sizes for the whole schedule (never
    /// reject). Set small to exercise the 429 path deliberately.
    pub queue_cap: Option<usize>,
    /// Probability a request targets model 0 (hot-key skew).
    pub hot_share: Option<f64>,
    /// Submission index at which model 0 rolls to version 1 mid-run.
    pub swap_at: Option<usize>,
    /// Relative per-request deadline, ms, carried in the request body.
    pub deadline_ms: Option<f64>,
    /// Network-layer knobs (worker pool, connection budget, body cap).
    pub http: HttpConfig,
}

/// What one loopback-HTTP run measured: the client-observed statuses, the
/// server's response ledger, and the router's typed-outcome ledger — three
/// views of the same traffic that must reconcile exactly-once.
#[derive(Clone, Debug, Default)]
pub struct HttpReport {
    /// Client-observed responses (exactly one per offered request).
    pub requests: usize,
    pub seconds: f64,
    /// Successful solves per second of wall time.
    pub rps: f64,
    /// Client-observed statuses: 200 / 429 / 422 / 502 / 503 / 504 /
    /// other 4xx.
    pub ok: usize,
    pub queue_full: usize,
    pub unconverged: usize,
    pub model_faults: usize,
    pub worker_lost: usize,
    pub deadline_exceeded: usize,
    pub other_4xx: usize,
    /// Transport-level failures seen by clients (0 in a healthy run).
    pub client_errors: usize,
    /// End-to-end (socket round-trip) latency quantiles of 200s, ms.
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    /// Every 200's forward solve converged.
    pub all_converged: bool,
    /// Total submit retries echoed in `x-shine-attempts`.
    pub attempts: usize,
    /// 200s served on the old / new version of model 0 (swap runs).
    pub old_served: usize,
    pub new_served: usize,
    /// The rolled version ended up the live route (swap runs).
    pub swap_completed: bool,
    /// Server response ledger: `(status, responses)` by status.
    pub server_responses: Vec<(u16, u64)>,
    /// Connections shed by the server's admission control.
    pub server_shed: usize,
    /// Router ledger (supervision + typed outcomes + quarantine).
    pub respawns: usize,
    pub steals: usize,
    pub ledger_worker_lost: usize,
    pub ledger_deadline_expired: usize,
    pub ledger_quarantined: usize,
    pub quarantined_keys: usize,
    pub open_breakers: usize,
    /// Typed outcomes delivered after their HTTP waiter gave up.
    pub orphans: usize,
}

/// One client-side observation (private to the driver).
struct HttpObs {
    status: u16,
    latency: f64,
    converged: bool,
    version: u32,
    model: u32,
    attempts: usize,
    err: bool,
}

/// Replay one precomputed open-loop schedule through the full HTTP edge
/// over loopback TCP: router + [`Gateway`] + [`HttpServer`] on an
/// ephemeral port, driven by `lc.clients` keep-alive [`HttpClient`]
/// threads. Same schedule idiom (and seed-mixing constant) as
/// [`run_sharded_open_loop_with`], so in-process and over-the-wire runs
/// offer identical load. `faults` wraps every registered model in the
/// seeded [`FaultPlan`] chaos harness — panics and NaNs travel through
/// supervision, the typed status mapping, and the client, and the report
/// carries all three ledgers for the exactly-once reconciliation.
pub fn run_http_open_loop<E: Elem, EU: Elem, EV: Elem>(
    engine: EngineConfig,
    mk_model: &dyn Fn(u32, u32) -> SharedModel<E>,
    lc: &HttpLoadConfig,
    faults: Option<&FaultPlan>,
    seed: u64,
) -> HttpReport {
    assert!(lc.shards >= 1 && lc.models >= 1 && lc.total >= 1 && lc.clients >= 1);
    if let Some(at) = lc.swap_at {
        assert!(at < lc.total, "swap_at must fall inside the schedule");
    }
    let sched = SchedulerConfig {
        max_batch: lc.max_batch,
        max_wait: lc.max_wait,
        queue_cap: lc.queue_cap.unwrap_or_else(|| lc.total.max(lc.max_batch)),
    };
    let router: ShardedRouter<E, EU, EV> =
        ShardedRouter::new(ShardConfig::new(lc.shards, engine, sched));
    let wrap = |model: SharedModel<E>| -> SharedModel<E> {
        match faults {
            Some(plan) => std::sync::Arc::new(FaultyModel::new(model, plan.clone())),
            None => model,
        }
    };
    let d = mk_model(0, 0).dim();
    for m in 0..lc.models as u32 {
        let model = mk_model(m, 0);
        assert_eq!(model.dim(), d, "http driver requires one shared dimension");
        router.register(ModelKey::new(m, 0), wrap(model));
    }
    // HTTP admission uses the fail-fast policy: 429s reach the client with
    // a Retry-After instead of parking connection handlers in backoff.
    let gateway = std::sync::Arc::new(Gateway::new(router, d, RetryPolicy::none()));
    let backend: std::sync::Arc<dyn SolveBackend> = gateway.clone();
    let mut server = HttpServer::bind(backend, "127.0.0.1:0", lc.http).expect("bind loopback");
    let addr = server.local_addr();

    // Precompute the offered load — same idiom and seed mix as the
    // in-process sharded driver, but cotangents stay f64 (the wire format).
    let mut rng = Rng::new(seed ^ 0x54A2D);
    let mut arrivals = Vec::with_capacity(lc.total);
    let mut t = 0.0f64;
    for _ in 0..lc.total {
        t += lc.arrivals.gap(&mut rng);
        arrivals.push(t);
    }
    let model_of: Vec<u32> = (0..lc.total)
        .map(|_| match lc.hot_share {
            Some(p) if lc.models > 1 => {
                if rng.uniform() < p {
                    0
                } else {
                    1 + rng.below(lc.models - 1) as u32
                }
            }
            _ => rng.below(lc.models) as u32,
        })
        .collect();
    let cots: Vec<f64> = (0..lc.total * d).map(|_| rng.normal()).collect();

    let sw = Stopwatch::start();
    let obs: Vec<HttpObs> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(lc.clients);
        for c in 0..lc.clients {
            let (sw, arrivals, model_of, cots) = (&sw, &arrivals, &model_of, &cots);
            handles.push(scope.spawn(move || {
                let mut out: Vec<HttpObs> = Vec::new();
                let mut client = match HttpClient::connect(addr) {
                    Ok(cl) => cl,
                    Err(_) => {
                        let mut i = c;
                        while i < lc.total {
                            out.push(HttpObs {
                                status: 0,
                                latency: 0.0,
                                converged: false,
                                version: 0,
                                model: model_of[i],
                                attempts: 0,
                                err: true,
                            });
                            i += lc.clients;
                        }
                        return out;
                    }
                };
                let mut i = c;
                while i < lc.total {
                    let lead = arrivals[i] - sw.elapsed();
                    if lead > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(lead));
                    }
                    let mut b = JsonBuilder::obj()
                        .uint("model", model_of[i] as u64)
                        .nums("cotangent", cots[i * d..(i + 1) * d].iter().copied());
                    if let Some(ms) = lc.deadline_ms {
                        b = b.num("deadline_ms", ms);
                    }
                    let body = b.finish();
                    let t0 = sw.elapsed();
                    match client.post_json("/v1/solve", &body, &[]) {
                        Ok(resp) => {
                            let doc = LazyDoc::new(&resp.body);
                            out.push(HttpObs {
                                status: resp.status,
                                latency: sw.elapsed() - t0,
                                converged: doc.path(&["converged"]).ok().flatten()
                                    == Some(b"true".as_slice()),
                                version: doc.u32_at(&["version"]).ok().flatten().unwrap_or(0),
                                model: model_of[i],
                                attempts: resp
                                    .header("x-shine-attempts")
                                    .and_then(|v| v.parse().ok())
                                    .unwrap_or(0),
                                err: false,
                            });
                        }
                        Err(_) => out.push(HttpObs {
                            status: 0,
                            latency: 0.0,
                            converged: false,
                            version: 0,
                            model: model_of[i],
                            attempts: 0,
                            err: true,
                        }),
                    }
                    i += lc.clients;
                }
                out
            }));
        }
        // The main thread drives the mid-run roll (clients only see HTTP;
        // version management stays a control-plane operation).
        if let Some(at) = lc.swap_at {
            let lead = arrivals[at] - sw.elapsed();
            if lead > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(lead));
            }
            gateway.router().swap(ModelKey::new(0, 1), wrap(mk_model(0, 1)));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("client thread"))
            .collect()
    });
    let seconds = sw.elapsed();
    if lc.swap_at.is_some() {
        gateway.router().wait_live(ModelKey::new(0, 1));
    }

    // Snapshot every ledger before teardown.
    let shard_stats = gateway.router().shard_stats();
    let quarantined = gateway.router().quarantined_keys();
    let server_responses = server.counters().by_status();
    let server_shed = server.counters().shed();
    let orphans = gateway.orphans();
    let swap_completed = lc.swap_at.is_some() && gateway.router().live_version(0) == Some(1);
    server.shutdown();
    drop(server);
    drop(gateway);

    let latencies: Vec<f64> = obs
        .iter()
        .filter(|o| o.status == 200)
        .map(|o| o.latency)
        .collect();
    let count = |s: u16| obs.iter().filter(|o| o.status == s).count();
    let ok = count(200);
    HttpReport {
        requests: obs.len(),
        seconds,
        rps: ok as f64 / seconds.max(1e-12),
        ok,
        queue_full: count(429),
        unconverged: count(422),
        model_faults: count(502),
        worker_lost: count(503),
        deadline_exceeded: count(504),
        other_4xx: obs
            .iter()
            .filter(|o| (400..500).contains(&o.status) && o.status != 429 && o.status != 422)
            .count(),
        client_errors: obs.iter().filter(|o| o.err).count(),
        p50_latency_ms: stats::median(&latencies) * 1e3,
        p95_latency_ms: stats::quantile(&latencies, 0.95) * 1e3,
        p99_latency_ms: stats::quantile(&latencies, 0.99) * 1e3,
        all_converged: obs.iter().filter(|o| o.status == 200).all(|o| o.converged),
        attempts: obs.iter().map(|o| o.attempts).sum(),
        swap_completed,
        old_served: obs
            .iter()
            .filter(|o| o.status == 200 && o.model == 0 && o.version == 0)
            .count(),
        new_served: obs
            .iter()
            .filter(|o| o.status == 200 && o.model == 0 && o.version == 1)
            .count(),
        server_responses,
        server_shed,
        respawns: shard_stats.iter().map(|s| s.respawns).sum(),
        steals: shard_stats.iter().map(|s| s.steals).sum(),
        ledger_worker_lost: shard_stats.iter().map(|s| s.worker_lost).sum(),
        ledger_deadline_expired: shard_stats.iter().map(|s| s.deadline_expired).sum(),
        ledger_quarantined: shard_stats.iter().map(|s| s.quarantined).sum(),
        quarantined_keys: quarantined.len(),
        open_breakers: shard_stats.iter().map(|s| s.open_breakers).sum(),
        orphans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn closed_loop_serves_every_request() {
        let d = 64;
        let model: SynthDeq<f32> = SynthDeq::new(d, 16, 21);
        let mut engine: ServeEngine<f32> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 4,
                ..Default::default()
            }
            .with_tol(1e-4),
        );
        engine.calibrate(
            |z: &[f32], out: &mut [f32]| model.residual_batch(z, 1, out),
            &vec![0.0f32; d],
        );
        let lc = LoadConfig {
            clients: 4,
            total: 13, // not a multiple of the batch: exercises partial tail
            max_batch: 4,
            max_wait: 1e-4,
        };
        let rep = run_closed_loop(&mut engine, &model, &lc, 1);
        assert_eq!(rep.requests, 13);
        assert!(rep.all_converged);
        assert!(rep.rps > 0.0);
        assert!(rep.batches >= 4); // at least ceil(13/4)
        assert!(rep.p50_latency_ms >= 0.0);
        assert!(rep.fwd_iters_mean > 1.0);
    }

    #[test]
    fn suite_reports_baseline_relative_speedups() {
        let solver = SolverSpec::picard(1.0).with_tol(1e-4).with_max_iters(200);
        let rows = run_suite::<f32, f32, f32>(64, 16, &[1, 2], 8, solver, 5);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].b, 1);
        assert!((rows[0].speedup_vs_baseline - 1.0).abs() < 1e-12);
        assert!(rows[1].report.requests == 8);
        assert!(rows[1].speedup_vs_baseline > 0.0);
    }

    #[test]
    fn routed_closed_loop_serves_both_keys_without_cross_batching() {
        let d = 48;
        let cfg = EngineConfig {
            max_batch: 4,
            ..Default::default()
        }
        .with_tol(1e-4);
        let mut router: Router<f32> = Router::new(cfg);
        let ka = ModelKey::new(0, 0);
        let kb = ModelKey::new(1, 0);
        router.register(ka, Box::new(SynthDeq::<f32>::new(d, 12, 31)));
        router.register(kb, Box::new(SynthDeq::<f32>::new(d, 12, 32)));
        let lc = RoutedLoadConfig {
            clients_per_model: 3,
            total: 17, // odd total exercises the partial tail on both keys
            max_batch: 4,
            max_wait: 1e-4,
        };
        let rep = run_routed_closed_loop(&mut router, &[ka, kb], &lc, 9);
        assert_eq!(rep.requests, 17);
        assert!(rep.all_converged);
        assert!(rep.rps > 0.0);
        let served: usize = rep.per_key_requests.iter().map(|(_, n)| n).sum();
        assert_eq!(served, 17);
        // Both keys actually served traffic.
        for (k, n) in &rep.per_key_requests {
            assert!(*n > 0, "key {k} starved");
        }
    }

    #[test]
    fn fewer_clients_than_batch_cap_still_completes() {
        // clients < max_batch: the scheduler would wait max_wait for a full
        // batch; the closed-loop driver releases the partial batch instead.
        let d = 48;
        let model: SynthDeq<f32> = SynthDeq::new(d, 12, 2);
        let mut engine: ServeEngine<f32> = ServeEngine::new(
            d,
            EngineConfig {
                max_batch: 8,
                ..Default::default()
            }
            .with_tol(1e-4),
        );
        let lc = LoadConfig {
            clients: 3,
            total: 9,
            max_batch: 8,
            max_wait: 10.0, // would stall for seconds if honored blindly
        };
        let sw = crate::util::timer::Stopwatch::start();
        let rep = run_closed_loop(&mut engine, &model, &lc, 7);
        assert_eq!(rep.requests, 9);
        assert!(sw.elapsed() < 5.0, "partial batches must not wait out max_wait");
        assert!(rep.mean_batch <= 3.0 + 1e-9);
    }

    #[test]
    fn sharded_open_loop_serves_schedule_and_swaps() {
        let d = 32;
        let engine = EngineConfig {
            max_batch: 4,
            ..Default::default()
        }
        .with_tol(1e-6);
        let mk = |m: u32, v: u32| -> SharedModel<f64> {
            Arc::new(SynthDeq::<f64>::new(d, 8, 7 + 13 * m as u64 + 101 * v as u64))
        };
        let lc = ShardedLoadConfig {
            shards: 2,
            models: 2,
            total: 24,
            arrivals: Arrivals::Poisson { rate: 50_000.0 },
            max_batch: 4,
            max_wait: 1e-4,
            hot_share: Some(0.75),
            swap_at: Some(12),
            deadline: None,
        };
        let rep = run_sharded_open_loop::<f64, f64, f64>(engine, &mk, &lc, 3);
        assert_eq!(rep.requests, 24);
        assert!(rep.all_converged);
        assert!(rep.rps > 0.0);
        assert_eq!(rep.shards, 2);
        assert_eq!(rep.per_shard_served.iter().sum::<usize>(), 24);
        let swap = rep.swap.expect("swap telemetry present");
        assert!(swap.completed, "cutover must finish before the report");
        assert_eq!(swap.requested_at, 12);
        assert!(swap.old_served >= 1, "old version served the early hot traffic");
        let hot_total = swap.old_served + swap.new_served;
        assert!((1..=24).contains(&hot_total));
        // Two models at v0 plus the rolled version ⇒ at least three
        // calibrations (steals may add re-homed copies on top).
        assert!(rep.calibrations >= 3);
        // Clean run: no typed failures, nothing shed, no respawns.
        assert_eq!(rep.model_faults + rep.worker_lost + rep.deadline_exceeded, 0);
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.respawns, 0);
    }

    #[test]
    fn sharded_chaos_run_accounts_for_every_request() {
        use crate::serve::engine::BreakerConfig;
        let d = 32;
        let engine = EngineConfig {
            max_batch: 4,
            breaker: Some(BreakerConfig {
                threshold: 2,
                cooldown: 2,
            }),
            ..Default::default()
        }
        .with_tol(1e-6);
        let mk = |m: u32, v: u32| -> SharedModel<f64> {
            Arc::new(SynthDeq::<f64>::new(d, 8, 7 + 13 * m as u64 + 101 * v as u64))
        };
        let total = 32;
        let lc = ShardedLoadConfig {
            shards: 2,
            models: 2,
            total,
            arrivals: Arrivals::Poisson { rate: 50_000.0 },
            max_batch: 4,
            max_wait: 1e-4,
            hot_share: None,
            swap_at: None,
            deadline: None,
        };
        let plan = FaultPlan::seeded(11, total, 1, 2, 1);
        let rep = run_sharded_open_loop_with::<f64, f64, f64>(engine, &mk, &lc, Some(&plan), 3);
        // Every submitted request resolved to exactly one typed outcome.
        assert_eq!(rep.requests, total - rep.shed);
        assert_eq!(rep.shed, 0, "queues sized for the schedule");
        assert!(rep.worker_lost >= 1, "panic victim's batch reported");
        assert!(rep.respawns >= 1, "supervision respawned the worker");
        // 1 panic + 2 NaN victims: each resolves as WorkerLost or
        // ModelFault (a NaN victim sharing the panicked batch is lost, not
        // faulted — batch composition is timing-dependent).
        assert!(rep.model_faults + rep.worker_lost >= 3);
        assert!(rep.all_converged, "surviving traffic converged");
    }
}
